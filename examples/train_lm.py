"""End-to-end training driver: a ~100M-param Qwen2-family LM trained
for a few hundred steps on synthetic data, with WSD schedule, async
checkpointing, and crash-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    # kill it anytime; rerun the same command to resume exactly.

Arch selection works for any assigned architecture:
    PYTHONPATH=src python examples/train_lm.py --arch xlstm-350m --smoke
"""
import argparse

import jax.numpy as jnp

from repro.configs import SMOKES, get_arch
from repro.configs.base import ModelConfig
from repro.train.loop import TrainConfig, train

# ~102M params: the "train a ~100M model" driver config
QWEN2_100M = ModelConfig(
    name="qwen2-100m",
    family="dense",
    n_layers=12,
    d_model=640,
    n_heads=10,
    n_kv_heads=2,
    d_ff=2560,
    vocab_size=50304,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-arch smoke config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt", default="results/ckpt_train_lm")
    args = ap.parse_args()

    if args.arch == "qwen2-100m":
        cfg = QWEN2_100M
    else:
        cfg = get_arch(args.arch, smoke=args.smoke)
    print(f"[train_lm] arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")

    out = train(cfg, TrainConfig(
        steps=args.steps, batch=args.batch, seq=args.seq,
        peak_lr=args.lr, warmup=max(args.steps // 20, 5),
        ckpt_dir=args.ckpt, ckpt_every=50, log_every=10,
        dtype=jnp.float32))
    print(f"[train_lm] done: final loss {out['final_loss']:.4f} "
          f"({out['wall_s']:.0f}s wall)")
    first = sum(out["losses"][:5]) / max(len(out["losses"][:5]), 1)
    print(f"[train_lm] loss {first:.3f} -> {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()
