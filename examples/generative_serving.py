"""Phase-aware LLM serving on virtualized NPUs.

Each request is a *phase chain*: one prefill over the prompt, then a
generation-length-distributed run of decode steps with context-
bucketed cost. Decode steps from a tenant's in-flight requests
coalesce into shared decode iterations (continuous batching), and the
session reports TTFT / TBT tails next to end-to-end latency — with
SLOs on all three.

    PYTHONPATH=src python examples/generative_serving.py
"""
from repro.configs import SMOKES
from repro.core.mapper import ReconfigureError
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, ServingSession)


def main() -> None:
    cfg = SMOKES["qwen2-0.5b"]
    for policy in ("pmt", "v10", "neu10"):
        cluster = NPUCluster(policy=policy)
        sess = ServingSession(cluster)

        # decode-heavy chat traffic: short prompts, geometric gen lens
        chat = sess.register_generative(
            "chat", cfg, prompt_len=128,
            gen_lens=GenLenDistribution(mean=24.0, max_len=96, seed=3),
            eu_budget=4, slo_ttft_ms=0.05, slo_tbt_ms=0.01)
        # prefill-heavy summarization: 2k-token prompts, 2 tokens out
        doc = sess.register_generative(
            "doc", cfg, prompt_len=2048, gen_lens=2, eu_budget=4)

        sess.submit_arrivals(chat, PoissonArrivals(rate_rps=20_000.0,
                                                   n=16, seed=0))
        sess.submit_arrivals(doc, PoissonArrivals(rate_rps=3_000.0,
                                                  n=6, seed=1))
        sess.run_until(0.001)
        # live resize mid-generation: in-flight decodes keep running.
        # With both tenants sized at 4 EUs the core may be full — a
        # failed resize restores the old mapping and serving continues.
        try:
            sess.resize(chat, 6)
        except ReconfigureError as exc:
            print(f"[{policy}] resize held at current shape: {exc}")
        sess.drain()

        print(f"=== {policy} "
              f"(programs cached: {len(cluster.programs)}, "
              f"hits: {cluster.programs.hits}) ===")
        for r in sess.report():
            st = sess.sim.tenants[
                next(h for h in cluster.tenants
                     if h.name == r.name).sim_idx].stats
            print(f"  {r.name:5s} reqs={r.requests_done:3d} "
                  f"tokens={r.tokens_done:4d} "
                  f"ttft_p95={r.ttft_p95_ms:7.4f}ms "
                  f"tbt_p95={r.tbt_p95_ms:7.4f}ms "
                  f"e2e_p95={r.p95_ms:7.4f}ms "
                  f"max_batch={st.max_decode_batch} "
                  f"slo_ttft={r.slo_ttft_ok} slo_tbt={r.slo_tbt_ok}")


if __name__ == "__main__":
    main()
