"""Multi-tenant serving of the ASSIGNED architectures on virtualized
NPUs — the paper's §V-F scenario with our model zoo, on the online
control plane.

    PYTHONPATH=src python examples/multi_tenant_serving.py

Three layers run side by side:
1. FUNCTIONAL: real token generation (greedy) through the JAX serving
   engine for each tenant (reduced configs on CPU).
2. TIMING/SLO (closed loop): the policy registry schedules the same
   tenants' traces on one NPU core under all four paper policies.
3. ONLINE (open loop): a ServingSession admits Poisson request
   traffic, registers a second tenant mid-run, re-sizes it under an
   SLO autoscale hook, and deregisters it — without restarting the
   simulation.
"""
import numpy as np

from repro.configs import ARCHS, SMOKES
from repro.core.policies import available_policies
from repro.npu.trace import lm_trace
from repro.serve.engine import ServeEngine
from repro.serve.session import (NPUCluster, PoissonArrivals, SLOAutoscaler,
                                 ServingSession, run_closed_loop)


def functional_layer() -> None:
    print("=== functional layer: real generation (reduced configs) ===")
    for arch in ("qwen2-0.5b", "zamba2-7b", "musicgen-large"):
        cfg = SMOKES[arch]
        eng = ServeEngine(cfg, max_seq=96)
        B, S = 2, 24
        shape = ((B, cfg.n_codebooks, S) if cfg.family == "audio"
                 else (B, S))
        prompt = np.random.default_rng(0).integers(
            0, cfg.vocab_size, shape).astype(np.int32)
        out = eng.generate(prompt, n_new=8)
        print(f"  {arch:16s} prefill {out.prefill_s*1e3:7.1f}ms "
              f"decode {out.tokens_per_s:7.1f} tok/s "
              f"tokens={out.tokens.reshape(B, -1)[0][:6]}")


def timing_layer() -> None:
    print("\n=== timing/SLO layer: every registered policy ===")
    # qwen3-14b decode (a §V-F-style memory-bound LLM that fits one
    # 64 GB pNPU next to its neighbor) + qwen2-0.5b prefill
    llm = lm_trace(ARCHS["qwen3-14b"], batch=8, seq=2048, phase="decode")
    small = lm_trace(ARCHS["qwen2-0.5b"], batch=8, seq=512, phase="prefill")
    for policy in available_policies():
        cluster = NPUCluster(policy=policy)
        cluster.register("qwen3-14b/decode", llm, eu_budget=4)
        cluster.register("qwen2-0.5b/prefill", small, eu_budget=4)
        res, reports = run_closed_loop(cluster, n_requests=5)
        line = " | ".join(
            f"{r.name}: p95={r.p95_ms:9.2f}ms thr={r.throughput_rps:7.1f}/s"
            for r in reports)
        print(f"  {policy:9s} {line}")


def online_layer() -> None:
    print("\n=== online layer: open-loop session, mid-run lifecycle ===")
    llm = lm_trace(ARCHS["qwen3-14b"], batch=8, seq=2048, phase="decode")
    small = lm_trace(ARCHS["qwen2-0.5b"], batch=8, seq=512, phase="prefill")

    cluster = NPUCluster(policy="neu10")
    sess = ServingSession(cluster, autoscaler=SLOAutoscaler(max_eus=6))
    t_llm = sess.register("qwen3-14b/decode", llm, eu_budget=4)
    sess.submit_arrivals(t_llm, PoissonArrivals(rate_rps=6.0, n=120, seed=0))
    sess.run_until(5.0)
    r = sess.report(t_llm)[0]
    print(f"  t=5s   {r.name}: {r.requests_done} done, "
          f"p95={r.p95_ms:.2f}ms thr={r.throughput_rps:.1f}/s")

    # a second tenant shows up mid-run with a latency SLO
    t_sm = sess.register("qwen2-0.5b/prefill", small, eu_budget=2,
                         slo_p95_ms=1.0)
    sess.submit_arrivals(t_sm, PoissonArrivals(
        rate_rps=400.0, n=2000, seed=1, start_s=sess.now_s))
    before = t_sm.eu_budget
    sess.run_until(sess.now_s + 5.0)
    sess.run_until(sess.now_s + 5.0)  # second window lets the hook act
    r = sess.report(t_sm)[0]
    print(f"  +10s   {r.name}: {r.requests_done} done, p95={r.p95_ms:.2f}ms "
          f"(SLO 1.0ms) autoscaled {before}->{t_sm.eu_budget} EUs "
          f"({t_sm.vnpu.config.n_me}ME/{t_sm.vnpu.config.n_ve}VE)")

    # ... and leaves again; the LLM keeps serving, never restarted
    sess.deregister(t_sm)
    sess.drain()
    r = sess.report(t_llm)[0]
    print(f"  drain  {r.name}: {r.requests_done} done, "
          f"p95={r.p95_ms:.2f}ms thr={r.throughput_rps:.1f}/s "
          f"(t={sess.now_s:.1f}s)")


if __name__ == "__main__":
    functional_layer()
    timing_layer()
    online_layer()
