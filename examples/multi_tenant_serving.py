"""Multi-tenant serving of the ASSIGNED architectures on virtualized
NPUs — the paper's §V-F scenario with our model zoo.

    PYTHONPATH=src python examples/multi_tenant_serving.py

Two layers run side by side:
1. FUNCTIONAL: real token generation (greedy) through the JAX serving
   engine for each tenant (reduced configs on CPU).
2. TIMING/SLO: the Neu10 simulator schedules the same tenants' traces
   on one NPU core under all four policies, with the allocator
   choosing each tenant's ME/VE split and the autoscaler growing a
   violating tenant's EU budget.
"""
import numpy as np

from repro.configs import ARCHS, SMOKES
from repro.npu.trace import lm_trace
from repro.serve.engine import ServeEngine
from repro.serve.vserve import MultiTenantServer


def functional_layer() -> None:
    print("=== functional layer: real generation (reduced configs) ===")
    for arch in ("qwen2-0.5b", "zamba2-7b", "musicgen-large"):
        cfg = SMOKES[arch]
        eng = ServeEngine(cfg, max_seq=96)
        B, S = 2, 24
        shape = ((B, cfg.n_codebooks, S) if cfg.family == "audio"
                 else (B, S))
        prompt = np.random.default_rng(0).integers(
            0, cfg.vocab_size, shape).astype(np.int32)
        out = eng.generate(prompt, n_new=8)
        print(f"  {arch:16s} prefill {out.prefill_s*1e3:7.1f}ms "
              f"decode {out.tokens_per_s:7.1f} tok/s "
              f"tokens={out.tokens.reshape(B, -1)[0][:6]}")


def timing_layer() -> None:
    print("\n=== timing/SLO layer: Neu10 scheduling of the tenants ===")
    # qwen3-14b decode (a §V-F-style memory-bound LLM that fits one
    # 64 GB pNPU next to its neighbor) + qwen2-0.5b prefill
    llm = lm_trace(ARCHS["qwen3-14b"], batch=8, seq=2048, phase="decode")
    small = lm_trace(ARCHS["qwen2-0.5b"], batch=8, seq=512, phase="prefill")
    for policy in ("pmt", "v10", "neu10_nh", "neu10"):
        srv = MultiTenantServer(policy=policy)
        srv.register("qwen3-14b/decode", llm, eu_budget=4)
        srv.register("qwen2-0.5b/prefill", small, eu_budget=4)
        res, reports = srv.simulate(n_requests=5)
        line = " | ".join(
            f"{r.name}: p95={r.p95_ms:9.2f}ms thr={r.throughput_rps:7.1f}/s"
            for r in reports)
        print(f"  {policy:9s} {line}")

    print("\n=== autoscale-to-SLO (pay-as-you-go loop) ===")
    srv = MultiTenantServer(policy="neu10_nh")
    t = srv.register("qwen2-0.5b/prefill", small, eu_budget=2)
    _, reports = srv.simulate(n_requests=4)
    base = reports[0].p95_ms
    t.slo_p95_ms = base * 0.6
    reports = srv.autoscale_to_slo(n_requests=4, max_eus=8)
    print(f"  p95 {base:.2f}ms -> {reports[0].p95_ms:.2f}ms after "
          f"autoscaling to {t.eu_budget} EUs "
          f"({t.allocation.n_me}ME/{t.allocation.n_ve}VE)")


if __name__ == "__main__":
    functional_layer()
    timing_layer()
