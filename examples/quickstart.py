"""Quickstart: virtualize one NPU core between two ML services.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's whole pipeline in ~30 lines: profile two workloads
(ME-heavy BERT vs VE/HBM-heavy DLRM), let the Eq.-4 allocator split a
pay-as-you-go EU budget, map the vNPUs, compile to NeuISA μTOps, and
run the harvesting scheduler — then compare against the PMT baseline.
"""
from repro.npu.workloads import get_workload
from repro.serve.vserve import MultiTenantServer


def main() -> None:
    bert = get_workload("BERT")
    dlrm = get_workload("DLRM")
    m, v = bert.profile_mv()
    print(f"BERT profile: ME active {m:.2f}, VE active {v:.2f}")
    m, v = dlrm.profile_mv()
    print(f"DLRM profile: ME active {m:.2f}, VE active {v:.2f}\n")

    for policy in ("pmt", "neu10"):
        srv = MultiTenantServer(policy=policy)
        srv.register("bert", bert, eu_budget=4)
        srv.register("dlrm", dlrm, eu_budget=4)
        res, reports = srv.simulate(n_requests=6)
        print(f"--- policy={policy} ---")
        for r in reports:
            print(f"  {r.name:5s} vNPU={r.n_me}ME/{r.n_ve}VE "
                  f"p95={r.p95_ms:8.2f}ms thr={r.throughput_rps:8.1f}req/s "
                  f"harvested={r.harvested_me_ms:6.1f}ms "
                  f"blocked={r.blocked_ms:5.2f}ms")
        print(f"  core utilization: ME {res.me_utilization():.2f} "
              f"VE {res.ve_utilization():.2f}\n")


if __name__ == "__main__":
    main()
