"""Fleet capacity planning with the vectorized fluid simulator
(beyond-paper): every (workload-pair x HBM-bandwidth) collocation
cell evaluated in ONE jitted XLA program.

    PYTHONPATH=src python examples/fleet_sweep.py

A cloud operator uses this to pick which tenants to collocate on
which NPU SKU: the fluid model is exact for static partitions and an
optimistic bound under harvesting (tests/test_sim_jax.py), evaluated
orders of magnitude faster than the discrete-event oracle.
"""
import time

import numpy as np

from repro.core import compile_neuisa
from repro.core.sim_jax import fleet_sweep
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.workloads import PAPER_PAIRS, get_workload
from repro.serve.session import NPUCluster, PoissonArrivals, ServingSession


def main() -> None:
    core = DEFAULT_CORE
    pairs = [(w1, w2) for w1, w2, _ in PAPER_PAIRS]
    progs = [
        (compile_neuisa(get_workload(a, core), core),
         compile_neuisa(get_workload(b, core), core))
        for a, b in pairs
    ]
    scales = (0.75, 1.0, 1.33, 2.0)

    t0 = time.time()
    harv = fleet_sweep(progs, hbm_scales=scales, n_requests=5,
                       harvest=True, core=core)
    stat = fleet_sweep(progs, hbm_scales=scales, n_requests=5,
                       harvest=False, core=core)
    wall = time.time() - t0
    n_cells = len(pairs) * len(scales) * 2

    print(f"evaluated {n_cells} collocation cells in {wall:.1f}s "
          f"(one jit'd vmap nest)\n")
    print(f"{'pair':14s}" + "".join(f"  bw x{s:<5}" for s in scales)
          + "   (harvest speedup over static partition)")
    ms_h = np.asarray(harv["makespan"])
    ms_s = np.asarray(stat["makespan"])
    for i, (a, b) in enumerate(pairs):
        row = "".join(f"  {ms_s[i, j] / ms_h[i, j]:7.2f}x"
                      for j in range(len(scales)))
        print(f"{a+'+'+b:14s}{row}")
    best = np.unravel_index(np.argmax(ms_s / ms_h), ms_h.shape)
    best_pair = pairs[best[0]]
    print(f"\nbest collocation candidate: {best_pair} at "
          f"bw x{scales[best[1]]} "
          f"({(ms_s/ms_h)[best]:.2f}x harvest benefit)")
    validate_online(best_pair, scales[best[1]])


def validate_online(pair, hbm_scale: float) -> None:
    """Confirm the fluid model's pick with the discrete-event oracle:
    serve the pair open-loop through a ServingSession and report true
    per-request tails (the fluid bound has no queueing)."""
    cluster = NPUCluster(core=DEFAULT_CORE, policy="neu10")
    sess = ServingSession(cluster, hbm_scale=hbm_scale)
    handles = [
        sess.register(name, get_workload(name, DEFAULT_CORE), eu_budget=4)
        for name in pair
    ]
    for i, h in enumerate(handles):
        sess.submit_arrivals(h, PoissonArrivals(rate_rps=3.0, n=20, seed=i))
    sess.drain()
    print("\nonline validation of the pick (open-loop Poisson, "
          "discrete-event):")
    for r in sess.report():
        print(f"  {r.name:8s} {r.requests_done:3d} reqs  "
              f"p95={r.p95_ms:9.2f}ms  thr={r.throughput_rps:6.2f}/s  "
              f"harvested={r.harvested_me_ms:8.1f}ms")


if __name__ == "__main__":
    main()
