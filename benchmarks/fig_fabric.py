"""Cluster fabric sweep: core count x topology, topology-aware vs
random placement, and disaggregated prefill/decode vs single-core
colocation.

A chat tenant (qwen2-0.5b SMOKE) is served three ways on a cluster of
shrunken pNPU cores joined by a deliberately slow inter-core link (so
hand-off pricing is visible next to the smoke model's tiny compute):

* ``colocated`` — the pre-fabric baseline: one core, one vNPU pool,
  prefill and decode share the same engines;
* ``disagg/topo`` — ``register_generative(placement=Placement())``
  splits the tenant into a prefill pool and a decode pool placed by
  the topology-aware allocator (neighboring cores), every request's
  KV migrating over the priced link after prefill;
* ``disagg/random`` — the seeded random-placement baseline on the
  same fabric (averaged over seeds): more hops per hand-off, the
  cost model's point.

A fourth arm runs the heterogeneous colocation mix: the disaggregated
chat pair sharing a 4-core mesh with a ``qwen2-moe-a2.7b`` MoE tenant
and an ``xlstm-350m`` SSM tenant (kv-free recurrent state) colocated
on the remaining capacity.

Assertions (simulator counters, not derived latency):

* every arm completes all chat requests with ZERO KV leak — both
  pools' ledgers drain to zero and peak occupancy never exceeds the
  per-core ``hbm_bytes`` allocation — and every disaggregated arm
  performs real migration round-trips (``kv_migrations == N_CHAT``);
* topology-aware placement beats random by >= ``TOPO_GAIN`` (1.2x)
  on chat e2e p95 at >= 4 cores;
* the disaggregated pair beats single-core colocation on chat TBT
  p95 by >= ``TBT_GAIN`` — the decode pool never stalls behind a
  neighbor request's prefill.

    PYTHONPATH=src python -m benchmarks.run fig_fabric
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from benchmarks.common import BenchRow, timed
from repro.configs import SMOKES
from repro.core.fabric import FabricLink, FabricTopology, Placement
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import (NPUCluster, PoissonArrivals,
                                 ServingSession)

CHAT, MOE, SSM = "qwen2-0.5b", "qwen2-moe-a2.7b", "xlstm-350m"
SEG = 64 * 1024                  # shrunken HBM isolation segment
CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)
# slow fabric link: per-hop cost visible next to smoke-model compute
LINK = FabricLink(bandwidth=16.0, latency=400_000.0)

PROMPT = 256                     # tokens
GEN = 32                         # decode tokens per request
N_CHAT = 24
RATE_RPS = 100_000.0             # burst that overlaps decode w/ prefill
HBM = 256 * SEG                  # per-pool HBM pin (bytes)

SWEEP: Tuple[Tuple[str, int], ...] = (
    ("mesh", 4), ("ring", 4), ("mesh", 8), ("ring", 8))
RANDOM_SEEDS = (0, 1, 2)         # random-placement draws (averaged)
TOPO_GAIN = 1.2                  # topo vs random, chat e2e p95
TBT_GAIN = 1.05                  # disagg vs colocated, chat TBT p95


def _topology(kind: str, n: int) -> FabricTopology:
    builder = {"mesh": FabricTopology.mesh, "ring": FabricTopology.ring}
    return builder[kind](n, LINK)


def serve_chat(topo: Optional[FabricTopology],
               placement: Optional[Placement],
               hetero: bool = False) -> Dict[str, float]:
    """One open-loop chat run; ``topo=None`` is the single-core
    colocated baseline. ``hetero`` adds the MoE + SSM colocation mix
    on the same fabric. Returns tail metrics (ms) plus the raw
    migration / ledger counters."""
    cluster = NPUCluster(core=CORE, policy="neu10", topology=topo)
    sess = ServingSession(cluster)
    chat = sess.register_generative(
        "chat", SMOKES[CHAT], prompt_len=PROMPT, gen_lens=GEN,
        eu_budget=4, placement=placement,
        kv_policy="evict", hbm_bytes=HBM)
    others = []
    if hetero:
        moe = sess.register_generative(
            "moe", SMOKES[MOE], prompt_len=128, gen_lens=16, eu_budget=2,
            kv_policy="evict", hbm_bytes=HBM)
        # recurrent state, no KV cache: no ledger to account
        ssm = sess.register_generative(
            "ssm", SMOKES[SSM], prompt_len=128, gen_lens=16, eu_budget=2)
        sess.submit_arrivals(moe, PoissonArrivals(rate_rps=400.0, n=8,
                                                  seed=21))
        sess.submit_arrivals(ssm, PoissonArrivals(rate_rps=400.0, n=8,
                                                  seed=22))
        others = [moe, ssm]
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=RATE_RPS,
                                               n=N_CHAT, seed=1))
    sess.drain()
    r = sess.report(chat)[0]
    if placement is not None:
        pools = (chat.prefill, chat.decode)
        hops = float(chat.hops)
    else:
        pools = (chat,)
        hops = 0.0
    leak = sum(h.vnpu.kv_ledger.in_use for h in pools)
    peak_ok = all(h.vnpu.kv_ledger.peak_bytes <= h.vnpu.kv_ledger.capacity
                  for h in pools)
    out = {
        "done": float(r.requests_done),
        "e2e_p95": r.p95_ms,
        "ttft_p95": r.ttft_p95_ms,
        "tbt_p95": r.tbt_p95_ms,
        "migrations": float(r.kv_migrations),
        "migrated_kb": r.kv_migrated_bytes / 1024.0,
        "hops_per_req": hops,
        "rejects": float(r.kv_migration_rejects),
        "kv_leak_bytes": float(leak),
        "peak_ok": float(peak_ok),
    }
    out["others_done"] = float(sum(sess.report(h)[0].requests_done
                                   for h in others))
    return out


def _check(m: Dict[str, float], arm: str, disagg: bool) -> None:
    """Per-arm fabric invariants: everything completes, the ledgers
    drain, capacity held, and disaggregated arms really migrated."""
    assert m["done"] == N_CHAT, (arm, m)
    assert m["kv_leak_bytes"] == 0, (arm, m)
    assert m["peak_ok"] == 1.0, (arm, m)
    if disagg:
        assert m["migrations"] == N_CHAT, (arm, m)


def run(sweep: Sequence[Tuple[str, int]] = SWEEP) -> List[BenchRow]:
    rows: List[BenchRow] = []

    # single-core colocation baseline
    us, colo = timed(lambda: serve_chat(None, None))
    _check(colo, "colocated", disagg=False)
    rows.append(BenchRow(
        "fig_fabric/colocated/1core", us,
        f"e2e_p95={colo['e2e_p95']:.4f}ms tbt_p95={colo['tbt_p95']:.4f}ms "
        f"migrations=0"))

    # core count x topology sweep, topology-aware placement
    topo_e2e: Dict[Tuple[str, int], Dict[str, float]] = {}
    for kind, n in sweep:
        us, m = timed(lambda k=kind, c=n: serve_chat(
            _topology(k, c), Placement()))
        _check(m, f"{kind}{n}", disagg=True)
        topo_e2e[(kind, n)] = m
        rows.append(BenchRow(
            f"fig_fabric/disagg_topo/{kind}/{n}core", us,
            f"e2e_p95={m['e2e_p95']:.4f}ms tbt_p95={m['tbt_p95']:.4f}ms "
            f"ttft_p95={m['ttft_p95']:.4f}ms "
            f"migrations={m['migrations']:.0f} "
            f"hops_per_req={m['hops_per_req']:.0f} "
            f"migrated_kb={m['migrated_kb']:.0f}"))

    # random placement baseline on the biggest ring (worst hop spread)
    kind, n = "ring", max(c for k, c in sweep if k == "ring")
    rand_e2e, rand_hops = [], []
    for seed in RANDOM_SEEDS:
        us, m = timed(lambda s=seed: serve_chat(
            _topology(kind, n), Placement(strategy="random", seed=s)))
        _check(m, f"random{seed}", disagg=True)
        rand_e2e.append(m["e2e_p95"])
        rand_hops.append(m["hops_per_req"])
        rows.append(BenchRow(
            f"fig_fabric/disagg_random/{kind}/{n}core/seed{seed}", us,
            f"e2e_p95={m['e2e_p95']:.4f}ms "
            f"hops_per_req={m['hops_per_req']:.0f}"))
    rand_mean = sum(rand_e2e) / len(rand_e2e)
    topo = topo_e2e[(kind, n)]
    gain = rand_mean / max(topo["e2e_p95"], 1e-9)
    rows.append(BenchRow(
        f"fig_fabric/topo_vs_random/{kind}/{n}core", 0.0,
        f"e2e_gain={gain:.2f}x topo_hops={topo['hops_per_req']:.0f} "
        f"random_hops_mean={sum(rand_hops) / len(rand_hops):.1f}"))
    # headline (a): neighbor placement prices fewer hops into every
    # hand-off than the random baseline's path spread
    assert gain >= TOPO_GAIN, (gain, topo, rand_e2e)

    # headline (c): the decode pool never stalls behind a prefill
    best = topo_e2e[("mesh", 4)]
    tbt_gain = colo["tbt_p95"] / max(best["tbt_p95"], 1e-9)
    rows.append(BenchRow(
        "fig_fabric/disagg_vs_colocated/mesh/4core", 0.0,
        f"tbt_gain={tbt_gain:.2f}x "
        f"disagg_tbt_p95={best['tbt_p95']:.4f}ms "
        f"colocated_tbt_p95={colo['tbt_p95']:.4f}ms"))
    assert tbt_gain >= TBT_GAIN, (tbt_gain, best, colo)

    # heterogeneous colocation mix on the 4-core mesh
    us, mix = timed(lambda: serve_chat(_topology("mesh", 4), Placement(),
                                       hetero=True))
    _check(mix, "hetero", disagg=True)
    assert mix["others_done"] == 16, mix   # MoE + SSM all completed
    rows.append(BenchRow(
        "fig_fabric/hetero_mix/mesh/4core", us,
        f"e2e_p95={mix['e2e_p95']:.4f}ms tbt_p95={mix['tbt_p95']:.4f}ms "
        f"migrations={mix['migrations']:.0f} "
        f"others_done={mix['others_done']:.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
