"""Chunked prefill sweep: chunk size x tenant mix (SARATHI-style,
paper §V-F / Fig. 2/6 scheduling granularity).

Same co-location mix as ``fig_colocation`` (decode-heavy ``chat`` +
prefill-heavy ``doc`` with 2k-token prompts), but the generative
tenants register with ``prefill_chunk_tokens`` swept over
{monolithic, 256, 512}. Chunking splits doc's 2k prefill into a chain
of chunk phases, so:

* doc's OWN in-flight decodes interleave between its prefill chunks —
  the tenant's token cadence (TBT) no longer waits out a whole prompt
  (``TenantStats.chunk_interleaved_decodes`` counts exactly this);
* the scheduler sees finer units, so the chat tenant's first token
  stops queueing behind monolithic prompt ingestion (TTFT tail);
* under ``neu10``, prefill-chunk ME μTOps fuse with co-tenant decode
  VE μTOps into shared issue groups (``TenantStats.fused_groups``).

The cost is per-chunk KV re-read + weight re-streaming, so doc
throughput dips slightly — the sweep asserts the dip stays inside a
small bound while the latency wins are large.

A second comparison pits PR 3's interleave-BETWEEN-chunks scheduler
against SARATHI-SF **piggybacked iterations** (the adaptive
``iteration_token_budget``): on a mix where the chat tenant is itself
chunk-bound (512-token prompts > the 256-token chunk), a decoding
request's TBT under PR 3 is floored at whole-chunk granularity, while
piggybacking fuses a budget-capped prefill slice WITH the live decode
batch into one program. The arm asserts, under ``neu10``: chat TBT
p95 >= 1.2x better at (near-)equal doc throughput, doc TTFT within
10%, and — via the simulator's counters — that iterations really
carried a prefill slice plus >= 2 decode tokens, with fused issue
groups still forming off piggyback ME anchors.

    PYTHONPATH=src python -m benchmarks.run fig_chunked_prefill
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from benchmarks.common import BenchRow, timed
from repro.configs import SMOKES
from repro.core.stats import percentile
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, ServingSession)

POLICIES = ("pmt", "v10", "neu10")
CHUNKS = (0, 256, 512)           # prompt tokens per prefill chunk (0 = off)
N_CHAT = 24
N_DOC = 10

# headline assertions (chunk = 256 vs monolithic)
TTFT_GAIN = 1.3                  # chat TTFT p95 must drop >= 1.3x
DOC_TBT_GAIN = 5.0               # doc TBT p95 must drop >= 5x
DOC_THR_BOUND = 0.85             # doc throughput must keep >= 85%

# piggyback arm (adaptive budget vs PR 3 interleave-between-chunks)
PIGGY_CHAT_PROMPT = 512          # chat must be chunk-bound for the arm
PIGGY_CHAT_BUDGET = 128          # tokens per chat iteration
PIGGY_DOC_BUDGET = 384           # tokens per doc iteration
PIGGY_TBT_GAIN = 1.2             # chat TBT p95 must beat PR 3 >= 1.2x
PIGGY_DOC_TTFT_BOUND = 1.10      # doc TTFT p95 must not regress > 10%
PIGGY_DOC_THR_BOUND = 0.95       # "equal doc throughput" tolerance


def serve_mix(policy: str, chunk: int,
              model: str = "qwen2-0.5b",
              chat_prompt: int = 128,
              chat_budget: int = 0,
              doc_budget: int = 0) -> Dict[str, float]:
    """One co-location run at a given prefill chunk size (or, for the
    piggyback arm, per-tenant iteration token budgets); returns the
    tail metrics (ms / requests-per-second / counters)."""
    cluster = NPUCluster(policy=policy)
    sess = ServingSession(cluster)
    cfg = SMOKES[model]
    chat = sess.register_generative(
        "chat", cfg, prompt_len=chat_prompt,
        gen_lens=GenLenDistribution(mean=24.0, max_len=96, seed=11),
        eu_budget=4, slo_ttft_ms=5.0, slo_tbt_ms=1.0,
        prefill_chunk_tokens=chunk, iteration_token_budget=chat_budget)
    doc = sess.register_generative(
        "doc", cfg, prompt_len=2048, gen_lens=2, eu_budget=4,
        prefill_chunk_tokens=chunk, iteration_token_budget=doc_budget)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=30_000.0, n=N_CHAT,
                                               seed=1))
    sess.submit_arrivals(doc, PoissonArrivals(rate_rps=4_000.0, n=N_DOC,
                                              seed=2))
    sess.drain()
    ms = 1e3 / cluster.core.freq_hz
    stc = sess.sim.tenants[chat.sim_idx].stats
    std = sess.sim.tenants[doc.sim_idx].stats
    span_s = sess.sim.now / cluster.core.freq_hz
    assert stc.requests_done == N_CHAT and std.requests_done == N_DOC
    return {
        "chat_ttft_p95": percentile(stc.ttft, 0.95) * ms,
        "chat_tbt_p95": percentile(stc.tbt, 0.95) * ms,
        "doc_ttft_p95": percentile(std.ttft, 0.95) * ms,
        "doc_e2e_p95": percentile(std.latencies, 0.95) * ms,
        "doc_tbt_p95": percentile(std.tbt, 0.95) * ms,
        "doc_thr_rps": std.requests_done / span_s,
        "doc_prefill_chunks": float(std.prefill_chunks),
        "doc_interleaved": float(std.chunk_interleaved_decodes),
        "piggyback_iterations": float(stc.piggyback_iterations
                                      + std.piggyback_iterations),
        "piggyback_max_batch": float(max(stc.max_piggyback_batch,
                                         std.max_piggyback_batch)),
        "piggyback_decode_tokens": float(stc.piggyback_decode_tokens
                                         + std.piggyback_decode_tokens),
        "fused_groups": float(stc.fused_groups + std.fused_groups),
        "span_ms": span_s * 1e3,
    }


def run(policies: Sequence[str] = POLICIES,
        chunks: Sequence[int] = CHUNKS) -> List[BenchRow]:
    rows: List[BenchRow] = []
    grid: Dict[str, Dict[int, Dict[str, float]]] = {}
    for policy in policies:
        grid[policy] = {}
        for chunk in chunks:
            us, m = timed(lambda p=policy, c=chunk: serve_mix(p, c))
            grid[policy][chunk] = m
            rows.append(BenchRow(
                f"fig_chunked_prefill/{policy}/chunk{chunk or 'mono'}", us,
                f"chat_ttft_p95={m['chat_ttft_p95']:.4f}ms "
                f"doc_tbt_p95={m['doc_tbt_p95']:.4f}ms "
                f"doc_e2e_p95={m['doc_e2e_p95']:.4f}ms "
                f"doc_thr={m['doc_thr_rps']:.0f}rps "
                f"interleaved={m['doc_interleaved']:.0f} "
                f"fused={m['fused_groups']:.0f}"))
        if 0 not in grid[policy] or 256 not in grid[policy]:
            continue
        mono, c256 = grid[policy][0], grid[policy][256]
        ttft_gain = mono["chat_ttft_p95"] / max(c256["chat_ttft_p95"], 1e-9)
        tbt_gain = mono["doc_tbt_p95"] / max(c256["doc_tbt_p95"], 1e-9)
        thr_keep = c256["doc_thr_rps"] / max(mono["doc_thr_rps"], 1e-9)
        rows.append(BenchRow(
            f"fig_chunked_prefill/{policy}/chunk256_vs_mono", 0.0,
            f"chat_ttft_gain={ttft_gain:.2f}x doc_tbt_gain={tbt_gain:.2f}x "
            f"doc_thr_keep={thr_keep:.2f}x"))
        # monolithic runs must never interleave; chunked runs must show
        # a decode iteration landing BETWEEN two prefill chunks of the
        # same tenant (the simulator counts it, so this is asserted on
        # engine state, not on derived latency)
        assert mono["doc_interleaved"] == 0, mono
        assert c256["doc_interleaved"] >= 1, c256
        assert c256["doc_prefill_chunks"] == N_DOC * (2048 // 256), c256
        # chunking must not cost doc more than a small throughput dip
        assert thr_keep >= DOC_THR_BOUND, (policy, thr_keep)
        # the same-tenant interleave win: doc token cadence
        assert tbt_gain >= DOC_TBT_GAIN, (policy, tbt_gain)
        # the cross-tenant win: chat's first token stops queueing
        # behind monolithic prompt ingestion. PMT is excluded — its
        # whole-core hand-offs dominate chat TTFT, which is exactly
        # the baseline pathology fig_colocation pins.
        if policy in ("v10", "neu10"):
            assert ttft_gain >= TTFT_GAIN, (policy, ttft_gain)
        if policy == "neu10":
            # Fig. 6 fused issue groups actually formed
            assert c256["fused_groups"] > 0, c256
    rows.extend(run_piggyback(policies))
    return rows


def run_piggyback(policies: Sequence[str] = POLICIES) -> List[BenchRow]:
    """Piggyback arm: PR 3 interleave-between-chunks (static chunk
    256) vs adaptive-budget piggybacked iterations, on the mix with a
    chunk-bound chat tenant. Assertions are pinned on ``neu10`` (the
    paper's system); the VLIW baselines are reported for contrast —
    whole-operator temporal sharing can't exploit the finer slices
    (v10's doc throughput collapses, exactly the Fig. 9 rigidity)."""
    rows: List[BenchRow] = []
    for policy in policies:
        us_c, chunked = timed(lambda p=policy: serve_mix(
            p, 256, chat_prompt=PIGGY_CHAT_PROMPT))
        us_p, piggy = timed(lambda p=policy: serve_mix(
            p, 0, chat_prompt=PIGGY_CHAT_PROMPT,
            chat_budget=PIGGY_CHAT_BUDGET, doc_budget=PIGGY_DOC_BUDGET))
        tbt_gain = chunked["chat_tbt_p95"] / max(piggy["chat_tbt_p95"], 1e-9)
        ttft_ratio = piggy["doc_ttft_p95"] / max(chunked["doc_ttft_p95"],
                                                 1e-9)
        thr_keep = piggy["doc_thr_rps"] / max(chunked["doc_thr_rps"], 1e-9)
        rows.append(BenchRow(
            f"fig_chunked_prefill/{policy}/piggyback", us_p,
            f"chat_tbt_p95={piggy['chat_tbt_p95']:.4f}ms "
            f"doc_ttft_p95={piggy['doc_ttft_p95']:.4f}ms "
            f"doc_thr={piggy['doc_thr_rps']:.0f}rps "
            f"piggy_iters={piggy['piggyback_iterations']:.0f} "
            f"piggy_max_batch={piggy['piggyback_max_batch']:.0f} "
            f"fused={piggy['fused_groups']:.0f}"))
        rows.append(BenchRow(
            f"fig_chunked_prefill/{policy}/piggyback_vs_chunk256", 0.0,
            f"chat_tbt_gain={tbt_gain:.2f}x doc_ttft_ratio={ttft_ratio:.2f}x "
            f"doc_thr_keep={thr_keep:.2f}x"))
        # the budget never touches the static-chunk arm: PR 3 counters
        # stay exactly PR 3 (no piggybacked iterations)
        assert chunked["piggyback_iterations"] == 0, chunked
        if policy != "neu10":
            continue
        # engine-state proof, not derived latency: iterations really
        # fused a prefill slice with a live decode batch (>= 2 tokens)
        assert piggy["piggyback_iterations"] >= 1, piggy
        assert piggy["piggyback_max_batch"] >= 2, piggy
        # fused issue groups still form with piggyback ME anchors
        assert piggy["fused_groups"] > 0, piggy
        # headline: finer-than-chunk TBT at (near-)equal doc
        # throughput, doc TTFT within 10%
        assert tbt_gain >= PIGGY_TBT_GAIN, (policy, tbt_gain)
        assert ttft_ratio <= PIGGY_DOC_TTFT_BOUND, (policy, ttft_ratio)
        assert thr_keep >= PIGGY_DOC_THR_BOUND, (policy, thr_keep)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
