"""Paper Fig. 22: total ME / VE utilization of the NPU core per
policy across the 9 pairs.

Accepts any set of registry policies: ``run(policies=(..., "mine"))``.

Also carries the fluid-grid row: the same 9-pair utilization grid
(harvest on vs off — the neu10 vs neu10_nh axis) evaluated as ONE
jitted ``sweep_collocations`` program, timed against the discrete
grid above it.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence

from benchmarks.common import (BenchRow, PAPER_PAIRS, POLICIES, geomean,
                               run_pair, timed)
from repro.core.policies import resolve_policy
from repro.core.sim_jax import sweep_collocations
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.workloads import get_workload


def _fluid_grid_row(discrete_wall_s: float) -> BenchRow:
    """The 9-pair utilization grid through the fluid fleet model: one
    ``sweep_collocations`` dispatch per harvest setting (the spatial
    policies differ only in that flag there), vs the wall the
    discrete per-cell grid above took. The discrete run stays the
    oracle — this row tracks how much of the figure's outer loop the
    vectorized path absorbs."""
    core = DEFAULT_CORE
    pol = resolve_policy("neu10")
    progs = {}
    for w1, w2, _ in PAPER_PAIRS:
        for w in (w1, w2):
            if w not in progs:
                progs[w] = pol.compile_program(get_workload(w, core), core)
    pairs = [(progs[w1], progs[w2]) for w1, w2, _ in PAPER_PAIRS]
    splits = (((2, 2), (2, 2)),)   # the §V-A half/half split

    def sweep():
        outs = [sweep_collocations(pairs, splits, bw_points=(1.0,),
                                   n_requests=6, harvest=h, core=core)
                for h in (True, False)]
        outs[0]["makespan"].block_until_ready()
        outs[1]["makespan"].block_until_ready()
        return outs

    sweep()   # warm-up: XLA compilation paid once
    wall = 1e9
    for _ in range(3):
        t0 = time.time()
        harvest_on, harvest_off = sweep()
        wall = min(wall, time.time() - t0)
    me_on = float(harvest_on["me_util"].mean())
    me_off = float(harvest_off["me_util"].mean())
    speedup = discrete_wall_s / max(wall, 1e-9)
    return BenchRow(
        "fig22/fluid_grid", wall * 1e6,
        f"speedup={speedup:.1f}x meU_harvest={me_on:.3f} "
        f"meU_nh={me_off:.3f} pairs={len(pairs)}")


def run(policies: Sequence[str] = POLICIES) -> List[BenchRow]:
    rows: List[BenchRow] = []
    me: Dict[str, List[float]] = {p: [] for p in policies}
    ve: Dict[str, List[float]] = {p: [] for p in policies}
    n_pairs = len(PAPER_PAIRS)
    t0 = time.time()
    for w1, w2, _ in PAPER_PAIRS:
        for p in policies:
            us, r = timed(lambda a=w1, b=w2, pp=p: run_pair(a, b, pp))
            me[p].append(r.me_utilization())
            ve[p].append(r.ve_utilization())
            rows.append(BenchRow(
                f"fig22/{w1}+{w2}/{p}", us,
                f"meU={r.me_utilization():.3f} veU={r.ve_utilization():.3f}"))
    discrete_wall_s = time.time() - t0
    for p in policies:
        rows.append(BenchRow(
            f"fig22/mean/{p}", 0.0,
            f"meU={sum(me[p])/n_pairs:.3f} veU={sum(ve[p])/n_pairs:.3f}"))
    # §V-C: Neu10 improves ME util over PMT (paper: 1.26x)
    if {"pmt", "neu10"} <= set(policies):
        ratio = ((sum(me["neu10"]) / n_pairs)
                 / max(sum(me["pmt"]) / n_pairs, 1e-9))
        rows.append(BenchRow("fig22/neu10_vs_pmt_meU", 0.0, f"{ratio:.3f}x"))
        assert ratio > 1.1
    rows.append(_fluid_grid_row(discrete_wall_s))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
