"""Paper Fig. 22: total ME / VE utilization of the NPU core per
policy across the 9 pairs."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import (BenchRow, PAPER_PAIRS, POLICIES, geomean,
                               run_pair, timed)


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    me: Dict[str, List[float]] = {p: [] for p in POLICIES}
    ve: Dict[str, List[float]] = {p: [] for p in POLICIES}
    for w1, w2, _ in PAPER_PAIRS:
        for p in POLICIES:
            us, r = timed(lambda a=w1, b=w2, pp=p: run_pair(a, b, pp))
            me[p].append(r.me_utilization())
            ve[p].append(r.ve_utilization())
            rows.append(BenchRow(
                f"fig22/{w1}+{w2}/{p}", us,
                f"meU={r.me_utilization():.3f} veU={r.ve_utilization():.3f}"))
    for p in POLICIES:
        rows.append(BenchRow(
            f"fig22/mean/{p}", 0.0,
            f"meU={sum(me[p])/9:.3f} veU={sum(ve[p])/9:.3f}"))
    # §V-C: Neu10 improves ME util over PMT (paper: 1.26x)
    ratio = (sum(me["neu10"]) / 9) / max(sum(me["pmt"]) / 9, 1e-9)
    rows.append(BenchRow("fig22/neu10_vs_pmt_meU", 0.0, f"{ratio:.3f}x"))
    assert ratio > 1.1
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
