"""Paper Fig. 22: total ME / VE utilization of the NPU core per
policy across the 9 pairs.

Accepts any set of registry policies: ``run(policies=(..., "mine"))``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from benchmarks.common import (BenchRow, PAPER_PAIRS, POLICIES, geomean,
                               run_pair, timed)


def run(policies: Sequence[str] = POLICIES) -> List[BenchRow]:
    rows: List[BenchRow] = []
    me: Dict[str, List[float]] = {p: [] for p in policies}
    ve: Dict[str, List[float]] = {p: [] for p in policies}
    n_pairs = len(PAPER_PAIRS)
    for w1, w2, _ in PAPER_PAIRS:
        for p in policies:
            us, r = timed(lambda a=w1, b=w2, pp=p: run_pair(a, b, pp))
            me[p].append(r.me_utilization())
            ve[p].append(r.ve_utilization())
            rows.append(BenchRow(
                f"fig22/{w1}+{w2}/{p}", us,
                f"meU={r.me_utilization():.3f} veU={r.ve_utilization():.3f}"))
    for p in policies:
        rows.append(BenchRow(
            f"fig22/mean/{p}", 0.0,
            f"meU={sum(me[p])/n_pairs:.3f} veU={sum(ve[p])/n_pairs:.3f}"))
    # §V-C: Neu10 improves ME util over PMT (paper: 1.26x)
    if {"pmt", "neu10"} <= set(policies):
        ratio = ((sum(me["neu10"]) / n_pairs)
                 / max(sum(me["pmt"]) / n_pairs, 1e-9))
        rows.append(BenchRow("fig22/neu10_vs_pmt_meU", 0.0, f"{ratio:.3f}x"))
        assert ratio > 1.1
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
