"""Paper Table III: per-workload harvesting overhead — time a
workload is blocked because its EUs were being harvested (reclaim
context-switch windows), over end-to-end execution time. Paper range:
<0.01% .. 10.63%; always outweighed by the harvesting benefit."""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchRow, PAPER_PAIRS, run_pair, timed


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    worst = 0.0
    for w1, w2, _ in PAPER_PAIRS:
        us, pair = timed(lambda a=w1, b=w2: (run_pair(a, b, "neu10"),
                                             run_pair(a, b, "neu10_nh")))
        neu, nh = pair
        ovh = [t.reclaim_blocked / neu.makespan for t in neu.tenants]
        speedup = nh.makespan / neu.makespan
        worst = max(worst, *ovh)
        rows.append(BenchRow(
            f"table3/{w1}+{w2}", us,
            f"W1={ovh[0]:.4%} W2={ovh[1]:.4%} harvest_speedup={speedup:.2f}x"))
        # the benefit must outweigh the blocking overhead
        assert speedup >= 1.0 - 1e-6
    rows.append(BenchRow("table3/worst_overhead", 0.0, f"{worst:.4%}"))
    # paper worst: 10.63% (MNIST). Our analytic traces have ~100x
    # shorter operators than real-TPU profiles, and blocked fraction
    # scales as ctx/op-length, so VE/HBM-heavy tenants (DLRM/NCF)
    # reclaim more often (NCF+RsNt ~20%). The CLAIM under test is the
    # paper's: harvesting benefit always outweighs the blocking cost
    # (speedup assert above); gate the fraction at 25%.
    assert worst < 0.25
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
