"""Paper Fig. 16: NeuISA single-tenant overhead vs the VLIW baseline.

Each workload runs SOLO on the full core twice — compiled to VLIW
(op-granular, ME control flow coupled) and to NeuISA (μTOps) — and we
report the makespan ratio. Paper claim: <1% average overhead, worst
cases from reduction-dimension partitioning.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchRow, timed
from repro.core import (TenantSpec, VNPUConfig, VNPUManager,
                        compile_neuisa, compile_vliw)
from repro.core.simulator import Simulator
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.workloads import WORKLOADS, get_workload


def _reduction_probe():
    """Synthetic reduction-dominated workload: skinny GEMVs with tiny
    outputs force the compiler to partition the K dimension — the one
    case where NeuISA pays (the cross-μTOp sum cannot pipeline with
    the MEs, §III-D 'NeuISA Overhead')."""
    from repro.npu.cost_model import WorkloadTrace, matmul_op

    core = DEFAULT_CORE
    # m=128, n=384 -> only 2 output tiles (< 4 MEs) with k=2048 (16
    # blocks): the compiler K-partitions. m=128 rows amortize the
    # weight stream so the op is COMPUTE-bound, and the cross-
    # partition sum (which cannot pipeline with the MEs, §III-D)
    # shows up as a few % — the Fig. 16 bar.
    ops = [matmul_op(f"gemv{i}", 128, 2048, 384, core) for i in range(20)]
    assert any(o.reduction_split for o in ops)
    return WorkloadTrace("REDC", ops, core=core)


def _get(name: str):
    if name == "REDC":
        return _reduction_probe()
    return get_workload(name, DEFAULT_CORE)


def _solo(name: str, isa: str) -> float:
    core = DEFAULT_CORE
    mgr = VNPUManager(core=core)
    tr = _get(name)
    v = mgr.create(VNPUConfig(core.n_me, core.n_ve,
                              hbm_bytes=core.hbm_bytes))
    prog = (compile_neuisa(tr, core) if isa == "neuisa"
            else compile_vliw(tr, core))
    res = Simulator([TenantSpec(prog, v, 3)],
                    policy="neu10" if isa == "neuisa" else "v10",
                    core=core).run()
    return res.makespan


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    overheads = []
    names = sorted(n for n in WORKLOADS if n != "LLaMA") + ["REDC"]
    for name in names:
        us, pair = timed(lambda n=name: (_solo(n, "vliw"),
                                         _solo(n, "neuisa")))
        t_vliw, t_neu = pair
        ovh = t_neu / t_vliw - 1.0
        overheads.append(ovh)
        rows.append(BenchRow(f"fig16/{name}", us, f"overhead={ovh:+.4f}"))
    # REDC (reduction-split probe) is excluded from the paper average:
    # it's the adversarial case, reported separately like Fig. 16's
    # worst bar.
    avg = sum(overheads[:-1]) / (len(overheads) - 1)
    rows.append(BenchRow("fig16/avg_overhead", 0.0, f"{avg:+.4f}"))
    rows.append(BenchRow("fig16/reduction_probe_overhead", 0.0,
                         f"{overheads[-1]:+.4f}"))
    assert abs(avg) < 0.02, f"NeuISA overhead {avg:.3f} exceeds 2%"
    assert overheads[-1] > 0.0, "reduction probe must show the cost"
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
