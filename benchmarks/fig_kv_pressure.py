"""KV-cache memory pressure sweep: HBM segments x decode-heavy chat
load, under live per-request KV accounting (the vNPU manager ledger).

A decode-heavy chat tenant (short prompts, long sampled generations)
runs on a vNPU whose HBM allocation is pinned to the model's resident
weights plus a swept number of isolation segments. With live KV
accounting on, decode context growth consumes those segments as it
happens; when the continuous batch outgrows them the simulator must
respond, and the sweep compares the two pressure policies:

* ``kv_policy="evict"`` — a PREMA-style victim (largest
  tokens-remaining x bucket-cost service estimate) is swapped out and
  later resumed through an HBM re-read program: the victim pays one
  bounded gap, everyone else keeps their token cadence;
* ``kv_policy="reject"`` — the victim is aborted back to admission
  and restarts from token 0 (prefill re-run, tokens re-generated):
  under sustained pressure the restart tax lands squarely on the
  time-between-tokens tail.

Assertions (on the simulator's own counters, not derived latency):

* ledger safety — peak segment occupancy NEVER exceeds the vNPU's
  ``hbm_bytes`` allocation, and the ledger drains to zero (exact
  frees) on every arm;
* under the tightest budget at least one full eviction + swap-resume
  round trip occurred (``kv_evictions >= 1`` and ``kv_swapins >= 1``)
  and every request still completed;
* neu10-with-eviction beats admission-reject on chat TBT p95 by
  >= ``TBT_GAIN`` (1.3x) at the tightest budget — eviction keeps the
  token cadence bounded where restarts blow it up.

A ledger-off baseline row (static ``hbm_footprint``, the pre-ledger
engine) is reported for context.

    PYTHONPATH=src python -m benchmarks.run fig_kv_pressure
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from benchmarks.common import BenchRow, timed
from repro.configs import SMOKES
from repro.core.stats import percentile
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, ServingSession)

MODEL = "qwen2-0.5b"
SEG = 64 * 1024                  # HBM isolation segment (bytes): small
                                 # segments so the smoke model's KV can
                                 # actually pressure the allocation
CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)
KV_SEGS = (2, 4, 8)              # KV budget beyond the weights, in segments
N_CHAT = 24
PROMPT = 128                     # tokens
GEN_MEAN, GEN_MAX = 96.0, 256    # decode-heavy: ~2/3 of KV is growth
RATE_RPS = 200_000.0             # arrival burst that stacks the batch

TBT_GAIN = 1.3                   # evict must beat reject by >= 1.3x
                                 # on chat TBT p95 at the tightest budget


def serve_chat(kv_policy: str, kv_segs: int) -> Dict[str, float]:
    """One decode-heavy chat run at a pinned HBM allocation of
    (weights rounded up) + ``kv_segs`` segments; ``kv_policy=""``
    disables the ledger (static-footprint baseline). Returns tail
    metrics (ms) and the raw ledger counters."""
    cfg = SMOKES[MODEL]
    cluster = NPUCluster(core=CORE, policy="neu10")
    sess = ServingSession(cluster)
    weights = cfg.param_count() * 2          # bf16 resident params
    hbm = (-(-weights // SEG)) * SEG + kv_segs * SEG
    chat = sess.register_generative(
        "chat", cfg, prompt_len=PROMPT,
        gen_lens=GenLenDistribution(mean=GEN_MEAN, max_len=GEN_MAX, seed=11),
        eu_budget=4, slo_tbt_ms=1.0,
        kv_policy=kv_policy or None, hbm_bytes=hbm)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=RATE_RPS,
                                               n=N_CHAT, seed=1))
    sess.drain()
    ms = 1e3 / CORE.freq_hz
    st = sess.sim.tenants[chat.sim_idx].stats
    led = chat.vnpu.kv_ledger
    return {
        "done": float(st.requests_done),
        "tokens": float(st.tokens),
        "tbt_p95": percentile(st.tbt, 0.95) * ms,
        "e2e_p95": percentile(st.latencies, 0.95) * ms,
        "kv_evictions": float(st.kv_evictions),
        "kv_swapins": float(st.kv_swapins),
        "kv_restarts": float(st.kv_restarts),
        "kv_swapped_kb": st.kv_swapped_bytes / 1024.0,
        "kv_peak_segments": float(st.kv_peak_segments),
        "cap_segments": float(led.capacity // SEG),
        "kv_leak_bytes": float(led.in_use),   # must drain to 0
    }


def run(kv_segs: Sequence[int] = KV_SEGS) -> List[BenchRow]:
    rows: List[BenchRow] = []
    grid: Dict[int, Dict[str, Dict[str, float]]] = {}
    for segs in kv_segs:
        grid[segs] = {}
        for policy in ("evict", "reject"):
            us, m = timed(lambda p=policy, s=segs: serve_chat(p, s))
            grid[segs][policy] = m
            rows.append(BenchRow(
                f"fig_kv_pressure/{policy}/kv{segs}seg", us,
                f"tbt_p95={m['tbt_p95']:.4f}ms e2e_p95={m['e2e_p95']:.4f}ms "
                f"evictions={m['kv_evictions']:.0f} "
                f"swapins={m['kv_swapins']:.0f} "
                f"restarts={m['kv_restarts']:.0f} "
                f"peak_seg={m['kv_peak_segments']:.0f} "
                f"cap_seg={m['cap_segments']:.0f}"))
            # ledger safety on EVERY arm: occupancy never exceeded the
            # vNPU's segment allocation, frees were exact (no leak),
            # and no request was dropped or force-finished
            assert m["kv_peak_segments"] <= m["cap_segments"], (policy, m)
            assert m["kv_leak_bytes"] == 0, (policy, m)
            assert m["done"] == N_CHAT, (policy, m)
    # static-footprint baseline for context (no ledger, no counters)
    us, base = timed(lambda: serve_chat("", max(kv_segs)))
    rows.append(BenchRow(
        f"fig_kv_pressure/off/kv{max(kv_segs)}seg", us,
        f"tbt_p95={base['tbt_p95']:.4f}ms e2e_p95={base['e2e_p95']:.4f}ms "
        f"evictions=0"))

    tight = grid[min(kv_segs)]
    ev, rj = tight["evict"], tight["reject"]
    # under pressure the evict arm must complete at least one full
    # eviction -> swap-out -> swap-in round trip, and the reject arm
    # must actually restart victims (the two responses really differ)
    assert ev["kv_evictions"] >= 1 and ev["kv_swapins"] >= 1, ev
    assert rj["kv_restarts"] >= 1, rj
    gain = rj["tbt_p95"] / max(ev["tbt_p95"], 1e-9)
    rows.append(BenchRow(
        f"fig_kv_pressure/evict_vs_reject/kv{min(kv_segs)}seg", 0.0,
        f"tbt_gain={gain:.2f}x "
        f"evict_roundtrips={ev['kv_swapins']:.0f} "
        f"reject_restarts={rj['kv_restarts']:.0f}"))
    # headline: swap-resume keeps the token cadence bounded where
    # admission-reject restarts blow up the TBT tail
    assert gain >= TBT_GAIN, (gain, ev, rj)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
