"""Credit-based SLO admission at fleet scale: a bursty/diurnal
open-loop trace (~100k requests by default, the full million behind
``--full``) replayed on a 4-core mesh, credit gate vs always-admit.

Two long-lived tenants — ``chat`` (tight p95 SLO, credit-rich: its
declared strictness sets a high accrual rate) and ``doc`` (batch,
loose SLO) — serve steady Poisson load for the whole horizon while
``N_WAVES`` diurnal waves of short-lived burst tenants (no SLOs, so
credit-poor) arrive, each asking for a big EU slice, burst for a
fraction of the wave period, and retire. The driver registers every
burst tenant in BOTH arms and retires it as soon as its work drains
(that creates the troughs); total offered work is identical.

* **naive** (``admission=None``): every burst that physically fits is
  admitted immediately and squats engines next to chat — chat loses
  its harvest headroom and any hope of autoscaling, and its p99 melts
  during every wave.
* **credit** (:class:`~repro.core.admission.AdmissionController`):
  burst asks are priced by fleet pressure; the credit-poor newcomers
  defer to the re-admission queue and drain in the troughs instead
  (time-shifted, not rejected — the same requests complete), while
  the credit-rich incumbents keep their tails.

Assertions (the acceptance criteria, both scales):

* credit beats naive by >= ``GAIN_FLOOR`` (1.3x) on chat e2e p99 at
  equal-or-better aggregate throughput (same total completed work,
  same-or-smaller makespan);
* the credit arm holds BOTH declared SLOs (chat p99 <= its SLO, doc
  p99 <= its SLO) where the naive arm blows chat's;
* EVERY arm: all offered requests complete, the loan table is empty,
  and HBM segment census conserves (free + resident + faulted ==
  total on every core);
* the credit arm actually gated something (>= 1 deferral) and the
  naive arm never consulted a gate.

    PYTHONPATH=src python -m benchmarks.run fig_admission
    PYTHONPATH=src python -m benchmarks.fig_admission --full   # 1M
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import BenchRow, timed
from repro.core.admission import AdmissionController
from repro.core.fabric import FabricLink, FabricTopology
from repro.npu.cost_model import Operator, WorkloadTrace
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import (AdmissionTicket, NPUCluster,
                                 PoissonArrivals, SLOAutoscaler,
                                 ServingSession)

SEG = 64 * 1024
CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)
LINK = FabricLink(bandwidth=16.0, latency=400_000.0)
N_CORES = 4

# ~100k requests at scale 1.0 (the CI smoke arm); --full runs 10x
CHAT_N = 30_000
DOC_N = 20_000
BURST_N = 1_250                  # per burst tenant
CHAT_RPS = 6_000.0
DOC_RPS = 4_000.0
BURST_RPS = 6_000.0
N_WAVES = 10                     # diurnal waves over the horizon
WAVE_TENANTS = 4                 # burst tenants per wave
BURST_EUS = 6                    # each asks a big slice of 32 EUs
STEP_S = 0.01                    # driver window (autoscale + admission)

CHAT_SLO_MS = 0.25               # declared AND asserted (credit arm)
DOC_SLO_MS = 1.0
GAIN_FLOOR = 1.3                 # credit vs naive, chat e2e p99


def _trace(name: str, me: float = 200_000.0, ve: float = 50_000.0,
           n_ops: int = 4) -> WorkloadTrace:
    return WorkloadTrace(name, [
        Operator(f"{name}_op{i}", me_cycles=me / n_ops,
                 ve_cycles=ve / n_ops, n_tiles=8)
        for i in range(n_ops)], core=CORE)


def serve(credit: bool, scale: float = 1.0) -> Dict[str, float]:
    """One full bursty/diurnal replay; returns tails + conservation
    counters. ``scale`` multiplies every request count (1.0 = ~100k
    total, 10.0 = the million-request arm)."""
    chat_n = int(CHAT_N * scale)
    doc_n = int(DOC_N * scale)
    burst_n = int(BURST_N * scale)
    horizon = chat_n / CHAT_RPS
    wave_period = horizon / N_WAVES

    topo = FabricTopology.mesh(N_CORES, LINK)
    cluster = NPUCluster(core=CORE, policy="neu10", topology=topo)
    ctl = (AdmissionController(initial_credit=0.05, free_level=0.5,
                               base_rate=0.05)
           if credit else None)
    sess = ServingSession(cluster, admission=ctl,
                          autoscaler=SLOAutoscaler(step_eus=2,
                                                   max_eus=12,
                                                   min_samples=4))
    chat = sess.register("chat", _trace("chat"), eu_budget=4,
                         slo_p95_ms=CHAT_SLO_MS, core_hint=0)
    doc = sess.register("doc", _trace("doc", me=400_000.0), eu_budget=4,
                        slo_p95_ms=DOC_SLO_MS, core_hint=1)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=CHAT_RPS,
                                               n=chat_n, seed=1))
    sess.submit_arrivals(doc, PoissonArrivals(rate_rps=DOC_RPS,
                                              n=doc_n, seed=2))

    # the diurnal burst schedule: every tenant exists in BOTH arms
    pending = [(f"burst{w}_{b}", w * wave_period, 100 + w * 10 + b)
               for w in range(N_WAVES) for b in range(WAVE_TENANTS)]
    tickets: List = []           # credit arm: deferred registrations
    handles: Dict = {}           # admitted, still serving
    burst_done = 0
    n_bursts = len(pending)

    t = 0.0
    while (t < horizon + 2 * wave_period
           and (pending or tickets or handles)):
        t += STEP_S
        sess.run_until(t)
        for name, at, seed in list(pending):
            if at > t:
                continue
            try:
                h = sess.register(name, _trace(name, me=300_000.0),
                                  eu_budget=BURST_EUS)
            except RuntimeError:
                continue         # naive: physically full — retry later
            pending.remove((name, at, seed))
            arr = PoissonArrivals(rate_rps=BURST_RPS, n=burst_n,
                                  seed=seed, start_s=max(t, at))
            sess.submit_arrivals(h, arr)   # ticket queues it, handle runs
            if isinstance(h, AdmissionTicket):
                tickets.append((name, h))
            else:
                handles[name] = h
        for name, tk in list(tickets):
            if tk.admitted:
                handles[name] = tk.handle
                tickets.remove((name, tk))
        # retire drained bursts — the trough the next wave admits into
        for name, h in list(handles.items()):
            r = sess.report(h)[0]
            if r.requests_done >= burst_n and r.queued == 0:
                burst_done += r.requests_done
                sess.deregister(h)
                del handles[name]
    sess.drain()                 # drain() keeps retrying the queue
    for name, tk in list(tickets):
        if tk.admitted:
            handles[name] = tk.handle
            tickets.remove((name, tk))
    for name, h in list(handles.items()):
        burst_done += sess.report(h)[0].requests_done
        sess.deregister(h)
        del handles[name]

    chat_lat = np.asarray(sess.latencies_ms(chat))
    doc_lat = np.asarray(sess.latencies_ms(doc))
    total = len(chat_lat) + len(doc_lat) + burst_done
    makespan_s = max(s.now for s in sess.sims) / CORE.freq_hz
    census = all(free + res + flt == tot
                 for free, res, flt, tot in cluster.manager.hbm_census())
    accounts_ok = (all(a.conserved() for a in ctl.accounts.values())
                   if ctl else True)
    return {
        "chat_p99": float(np.percentile(chat_lat, 99)),
        "chat_p95": float(np.percentile(chat_lat, 95)),
        "doc_p99": float(np.percentile(doc_lat, 99)),
        "total_done": float(total),
        "offered": float(chat_n + doc_n + n_bursts * burst_n),
        "makespan_s": makespan_s,
        "tput_rps": total / makespan_s,
        "unserved_tenants": float(len(tickets) + len(pending)),
        "deferrals": float(sum(a.deferrals for a in ctl.accounts.values())
                           if ctl else 0),
        "denied_scaleups": float(sum(a.scaleups_denied
                                     for a in ctl.accounts.values())
                                 if ctl else 0),
        "census_ok": float(census),
        "accounts_ok": float(accounts_ok),
        "loans_open": float(len(cluster.manager._loans)),
    }


def _check(m: Dict[str, float], arm: str) -> None:
    """Per-arm conservation invariants: every offered request was
    served, no burst tenant stranded, the loan table settled, and HBM
    segment census + credit-account conservation hold."""
    assert m["total_done"] == m["offered"], (arm, m)
    assert m["unserved_tenants"] == 0, (arm, m)
    assert m["census_ok"] == 1.0, (arm, m)
    assert m["accounts_ok"] == 1.0, (arm, m)
    assert m["loans_open"] == 0, (arm, m)


def _row(name: str, us: float, m: Dict[str, float]) -> BenchRow:
    return BenchRow(name, us, (
        f"chat_p99={m['chat_p99']:.4f}ms doc_p99={m['doc_p99']:.4f}ms "
        f"done={m['total_done']:.0f} tput={m['tput_rps']:.0f}rps "
        f"makespan={m['makespan_s']:.3f}s "
        f"deferrals={m['deferrals']:.0f} "
        f"denied_scaleups={m['denied_scaleups']:.0f} "
        f"census_ok={m['census_ok']:.0f} loans_open={m['loans_open']:.0f} "
        f"accounts_ok={m['accounts_ok']:.0f}"))


def run(full: bool = False) -> List[BenchRow]:
    scale = 10.0 if full else 1.0
    tag = "1m" if full else "100k"
    rows: List[BenchRow] = []
    us_n, naive = timed(lambda: serve(credit=False, scale=scale))
    _check(naive, "naive")
    assert naive["deferrals"] == 0, naive     # no gate, no deferrals
    rows.append(_row(f"fig_admission/{tag}/naive", us_n, naive))
    us_c, cred = timed(lambda: serve(credit=True, scale=scale))
    _check(cred, "credit")
    assert cred["deferrals"] >= 1, cred       # the gate actually acted
    rows.append(_row(f"fig_admission/{tag}/credit", us_c, cred))

    gain = naive["chat_p99"] / max(cred["chat_p99"], 1e-9)
    rows.append(BenchRow(
        f"fig_admission/{tag}/credit_vs_naive", 0.0,
        f"chat_p99_gain={gain:.2f}x "
        f"naive_chat_p99={naive['chat_p99']:.4f}ms "
        f"credit_chat_p99={cred['chat_p99']:.4f}ms "
        f"tput_ratio={cred['tput_rps'] / naive['tput_rps']:.4f} "
        f"chat_slo_ms={CHAT_SLO_MS} doc_slo_ms={DOC_SLO_MS}"))
    # headline: >= 1.3x better chat p99 at equal-or-better throughput
    assert gain >= GAIN_FLOOR, (gain, naive, cred)
    assert cred["tput_rps"] >= naive["tput_rps"] * 0.99, (naive, cred)
    # credit holds BOTH declared SLOs; always-admit blows chat's
    assert cred["chat_p99"] <= CHAT_SLO_MS, cred
    assert cred["doc_p99"] <= DOC_SLO_MS, cred
    assert naive["chat_p99"] > CHAT_SLO_MS, naive
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        prog="benchmarks.fig_admission",
        description="credit admission vs always-admit, bursty fleet")
    ap.add_argument("--full", action="store_true",
                    help="the million-request arm (~10 min wall) "
                         "instead of the ~100k smoke")
    for r in run(full=ap.parse_args().full):
        print(r.csv())
