"""Paper Fig. 26 + Fig. 27: throughput vs HBM bandwidth, and the LLM
(memory-bound decode) collocation case study.

Fig. 26: memory-intensive pairs under 900/1200/1600/2400 GB/s.
Fig. 27: LLaMA decode + compute-intensive workloads — V10's temporal
sharing strands the memory-stalled MEs; Neu10's spatial μTOp
scheduling lets the collocated tenant use them (paper: up to 1.6x)."""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchRow, geomean, run_pair, timed

MEM_PAIRS = [("DLRM", "NCF"), ("NCF", "TFMR")]
LLM_PARTNERS = ("BERT", "RsNt", "RtNt")
BWS = (0.75, 1.0, 1.33, 2.0)   # x1200 GB/s -> 900..2400


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    for w1, w2 in MEM_PAIRS:
        for s in BWS:
            us, pair = timed(lambda a=w1, b=w2, ss=s: (
                run_pair(a, b, "neu10", hbm_scale=ss),
                run_pair(a, b, "v10", hbm_scale=ss)))
            neu, v10 = pair
            g = neu.total_throughput() / max(v10.total_throughput(), 1e-9)
            rows.append(BenchRow(
                f"fig26/{w1}+{w2}/bw{int(1200*s)}GBs", us,
                f"neu10/v10={g:.3f}"))
    # Fig. 27: LLM decode collocation
    gains = []
    for partner in LLM_PARTNERS:
        us, pair = timed(lambda p=partner: (
            run_pair("LLaMA", p, "neu10"),
            run_pair("LLaMA", p, "v10")))
        neu, v10 = pair
        # partner throughput gain (the harvester) + LLM overhead
        g = neu.throughput(1) / max(v10.throughput(1), 1e-9)
        llm_pen = v10.throughput(0) / max(neu.throughput(0), 1e-9)
        gains.append(g)
        rows.append(BenchRow(
            f"fig27/LLaMA+{partner}", us,
            f"partner_gain={g:.3f} llm_slowdown={llm_pen:.3f}"))
    rows.append(BenchRow("fig27/geomean_partner_gain", 0.0,
                         f"{geomean(gains):.3f}"))
    assert geomean(gains) > 1.0
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
