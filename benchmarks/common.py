"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import VNPUConfig, available_policies
from repro.core.policies import PolicyLike
from repro.core.simulator import SimResult
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig
from repro.npu.workloads import PAPER_PAIRS, get_workload
from repro.serve.session import (NPUCluster, build_closed_loop_specs,
                                 run_closed_loop)

# the paper's four disciplines, in §V-A presentation order (all
# resolved through the scheduler registry; extra registered policies
# can be swept by passing an explicit `policies` list to the figures)
POLICIES = ("pmt", "v10", "neu10_nh", "neu10")
assert all(p in available_policies() for p in POLICIES)


def run_pair(
    w1: str,
    w2: str,
    policy: PolicyLike,
    core: NPUCoreConfig = DEFAULT_CORE,
    n_requests: int = 6,
    hbm_scale: float = 1.0,
    me_ve: Tuple[int, int] = (2, 2),
    fast_path: bool = True,
    incremental: bool = True,
) -> SimResult:
    """Paper §V-A setup: two vNPUs of 2ME/2VE on a 4ME/4VE core,
    SRAM/HBM split evenly. The policy (any registry entry) picks the
    mapping scheme and compiler front-end — temporal baselines compile
    whole VLIW operators for the full physical core; the false
    contention (Fig. 9) comes from operators whose own tiling can't
    fill it (n_tiles < n_me)."""
    cluster = NPUCluster(core=core, policy=policy)
    for name in (w1, w2):
        cluster.register_vnpu(
            name, get_workload(name, core),
            VNPUConfig(*me_ve, hbm_bytes=core.hbm_bytes // 2,
                       sram_bytes=core.sram_bytes // 2))
    res, _ = run_closed_loop(cluster, n_requests=n_requests,
                             hbm_scale=hbm_scale, fast_path=fast_path,
                             incremental=incremental)
    return res


def build_pair_specs(
    w1: str,
    w2: str,
    policy: PolicyLike,
    core: NPUCoreConfig = DEFAULT_CORE,
    n_requests: int = 6,
    me_ve: Tuple[int, int] = (2, 2),
):
    """Compile the :func:`run_pair` setup into reusable simulator
    specs WITHOUT running — benchmark A/B rows compile once and time
    only ``Simulator(specs, ...).run()`` per variant."""
    cluster = NPUCluster(core=core, policy=policy)
    for name in (w1, w2):
        cluster.register_vnpu(
            name, get_workload(name, core),
            VNPUConfig(*me_ve, hbm_bytes=core.hbm_bytes // 2,
                       sram_bytes=core.sram_bytes // 2))
    return build_closed_loop_specs(cluster, n_requests)


def geomean(xs) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return float(np.exp(np.mean(np.log(xs))))


@dataclass
class BenchRow:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn) -> Tuple[float, object]:
    t0 = time.time()
    out = fn()
    return (time.time() - t0) * 1e6, out
