"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (TenantSpec, VNPUConfig, VNPUManager,
                        compile_neuisa, compile_vliw)
from repro.core.simulator import SimResult, Simulator
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig
from repro.npu.workloads import PAPER_PAIRS, get_workload

POLICIES = ("pmt", "v10", "neu10_nh", "neu10")


def run_pair(
    w1: str,
    w2: str,
    policy: str,
    core: NPUCoreConfig = DEFAULT_CORE,
    n_requests: int = 6,
    hbm_scale: float = 1.0,
    me_ve: Tuple[int, int] = (2, 2),
) -> SimResult:
    """Paper §V-A setup: two vNPUs of 2ME/2VE on a 4ME/4VE core,
    SRAM/HBM split evenly."""
    mgr = VNPUManager(core=core)
    mapping = "spatial" if policy.startswith("neu10") else "temporal"
    specs = []
    for name in (w1, w2):
        tr = get_workload(name, core)
        v = mgr.create(
            VNPUConfig(*me_ve, hbm_bytes=core.hbm_bytes // 2,
                       sram_bytes=core.sram_bytes // 2),
            name=name, mapping=mapping)
        if policy.startswith("neu10"):
            prog = compile_neuisa(tr, core)
        else:
            # temporal baselines compile for the full physical core;
            # the false contention (Fig. 9) comes from operators whose
            # own tiling can't fill it (n_tiles < n_me).
            prog = compile_vliw(tr, core)
        specs.append(TenantSpec(prog, v, n_requests))
    return Simulator(specs, policy=policy, core=core,
                     hbm_scale=hbm_scale).run()


def geomean(xs) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return float(np.exp(np.mean(np.log(xs))))


@dataclass
class BenchRow:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn) -> Tuple[float, object]:
    t0 = time.time()
    out = fn()
    return (time.time() - t0) * 1e6, out
