"""Cross-request shared-prefix KV sweep: prefix-share ratio x KV
budget, under refcounted prefix caching in the vNPU manager ledger.

A chat tenant whose prompts share a long leading template (system
prompt / few-shot preamble) runs a saturating burst. Each arrival
draws a prefix-group key from a :class:`PrefixProfile`: with
probability ``share_ratio`` the request shares its leading
``PREFIX_LEN`` prompt tokens with one of the hot groups, otherwise
the whole prompt is unique. Same-key requests refcount ONE resident
copy of the prefix KV — a hit admits charging only the unshared
suffix and prefills only the suffix positions, so under a queued
burst both the ledger and the MEs stop paying for the template over
and over.

The sweep crosses share ratio x KV budget (segments beyond the
resident weights) against a sharing-off baseline on the SAME budget:

* TTFT p95 collapses as the ratio rises — queued requests skip
  ``PREFIX_LEN/PROMPT`` of their prefill compute on a hit;
* effective admitted capacity (KV budget / average *charged* prompt
  bytes) rises monotonically with the ratio — hits charge the suffix
  only, so the same segments admit more concurrent requests.

Assertions (on the simulator's own counters, not derived latency):

* zero-leak + exact refcount drain on EVERY arm: per-rid bytes AND
  the shared entries drain to zero after the burst completes;
* the ratio-0.0 arm records exactly 0 prefix hits (the machinery is
  on, the workload just never shares) and stays within counter noise
  of the sharing-off baseline;
* at ``share_ratio >= 0.5`` the sharing arm beats the sharing-off
  baseline on chat TTFT p95 by >= ``TTFT_GAIN`` (1.3x) on the same
  KV budget;
* effective capacity is monotone non-decreasing in the ratio.

    PYTHONPATH=src python -m benchmarks.run fig_prefix_cache
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from benchmarks.common import BenchRow, timed
from repro.configs import SMOKES
from repro.core.stats import percentile
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, PrefixProfile,
                                 ServingSession)

MODEL = "qwen2-0.5b"
SEG = 64 * 1024                  # HBM isolation segment (bytes)
CORE = DEFAULT_CORE.with_(hbm_bytes=2048 * SEG, hbm_segment=SEG)
KV_SEGS = (12, 24)               # KV budget beyond the weights
RATIOS = (0.0, 0.25, 0.5, 0.75)  # swept prefix-share ratio
N_CHAT = 32
PROMPT = 512                     # tokens
PREFIX_LEN = 448                 # shared template: 7/8 of the prompt
GEN_MEAN, GEN_MAX = 16.0, 48     # prefill-heavy chat turns
RATE_RPS = 200_000.0             # burst that stacks the queue

TTFT_GAIN = 1.3                  # sharing at ratio >= 0.5 must beat
                                 # sharing-off TTFT p95 by >= 1.3x


def serve_chat(share_ratio: float, kv_segs: int,
               sharing: bool = True) -> Dict[str, float]:
    """One saturated chat burst at a pinned HBM allocation of
    (weights rounded up) + ``kv_segs`` segments. ``sharing=False``
    drops the prefix profile entirely (the sharing-off baseline on
    the same budget). Returns tail metrics (ms) plus the raw ledger
    and prefix counters."""
    cfg = SMOKES[MODEL]
    cluster = NPUCluster(core=CORE, policy="neu10")
    sess = ServingSession(cluster)
    weights = cfg.param_count() * 2          # bf16 resident params
    hbm = (-(-weights // SEG)) * SEG + kv_segs * SEG
    profile = PrefixProfile(prefix_len=PREFIX_LEN,
                            share_ratio=share_ratio,
                            n_prefixes=1, seed=7) if sharing else None
    chat = sess.register_generative(
        "chat", cfg, prompt_len=PROMPT,
        gen_lens=GenLenDistribution(mean=GEN_MEAN, max_len=GEN_MAX, seed=11),
        eu_budget=4, kv_policy="evict", hbm_bytes=hbm,
        prefix_profile=profile)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=RATE_RPS,
                                               n=N_CHAT, seed=1))
    sess.drain()
    ms = 1e3 / CORE.freq_hz
    st = sess.sims[0].tenants[chat.sim_idx].stats
    led = chat.vnpu.kv_ledger
    per_tok = chat.plan.kv_token_bytes
    # average bytes actually CHARGED per admitted prompt: hits skip
    # the shared prefix, so the same segments go further
    done = max(st.requests_done, 1)
    avg_charged = PROMPT * per_tok - st.kv_shared_bytes / done
    return {
        "done": float(st.requests_done),
        "ttft_p95": percentile(st.ttft, 0.95) * ms,
        "tbt_p95": percentile(st.tbt, 0.95) * ms,
        "prefix_hits": float(st.kv_prefix_hits),
        "shared_kb": st.kv_shared_bytes / 1024.0,
        "kv_evictions": float(st.kv_evictions),
        "eff_capacity": (kv_segs * SEG) / max(avg_charged, 1e-9),
        "kv_leak_bytes": float(led.in_use + led.shared_in_use),
        "shared_entries": float(len(led.shared)),
    }


def run(kv_segs: Sequence[int] = KV_SEGS,
        ratios: Sequence[float] = RATIOS) -> List[BenchRow]:
    rows: List[BenchRow] = []
    for segs in kv_segs:
        us, off = timed(lambda s=segs: serve_chat(0.0, s, sharing=False))
        rows.append(BenchRow(
            f"fig_prefix_cache/off/kv{segs}seg", us,
            f"ttft_p95={off['ttft_p95']:.4f}ms "
            f"tbt_p95={off['tbt_p95']:.4f}ms hits=0 "
            f"eff_capacity={off['eff_capacity']:.2f}"))
        assert off["kv_leak_bytes"] == 0 and off["done"] == N_CHAT, off
        caps = []
        for ratio in ratios:
            us, m = timed(lambda r=ratio, s=segs: serve_chat(r, s))
            caps.append(m["eff_capacity"])
            gain = off["ttft_p95"] / max(m["ttft_p95"], 1e-9)
            rows.append(BenchRow(
                f"fig_prefix_cache/share{ratio:.2f}/kv{segs}seg", us,
                f"ttft_p95={m['ttft_p95']:.4f}ms "
                f"tbt_p95={m['tbt_p95']:.4f}ms "
                f"hits={m['prefix_hits']:.0f} "
                f"shared_kb={m['shared_kb']:.0f} "
                f"eff_capacity={m['eff_capacity']:.2f} "
                f"ttft_gain={gain:.2f}x "
                f"leak_bytes={m['kv_leak_bytes']:.0f}"))
            # zero-leak + exact refcount drain on EVERY arm: per-rid
            # bytes, the shared byte pool AND the entry table itself
            assert m["kv_leak_bytes"] == 0, (ratio, segs, m)
            assert m["shared_entries"] == 0, (ratio, segs, m)
            assert m["done"] == N_CHAT, (ratio, segs, m)
            if ratio == 0.0:
                # never-shared workload: the machinery must not
                # manufacture hits out of thin air
                assert m["prefix_hits"] == 0, (segs, m)
            if ratio >= 0.5:
                # headline: the shared template collapses the queued
                # TTFT tail vs sharing-off on the SAME KV budget
                assert gain >= TTFT_GAIN, (ratio, segs, gain, off, m)
        # effective admitted capacity rises with the share ratio —
        # hits charge the suffix only (tiny float tolerance)
        for lo, hi in zip(caps, caps[1:]):
            assert hi >= lo - 1e-9, (segs, caps)
        assert caps[-1] > caps[0], (segs, caps)
        rows.append(BenchRow(
            f"fig_prefix_cache/capacity/kv{segs}seg", 0.0,
            "eff_capacity_sweep=" +
            "/".join(f"{c:.2f}" for c in caps)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
