"""Paper Figs. 19/20/21: p95 tail latency, average latency, and
throughput for the 9 workload pairs under PMT / V10 / Neu10-NH /
Neu10 (all normalized to PMT, as in the paper).

Any policy registered via ``@register_policy`` can join the sweep:
``run(policies=("pmt", "neu10", "my_policy"))`` — results stay
normalized to the first (baseline) entry.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from benchmarks.common import (BenchRow, PAPER_PAIRS, POLICIES, geomean,
                               run_pair, timed)


def run(policies: Sequence[str] = POLICIES) -> List[BenchRow]:
    base_policy = policies[0]
    rows: List[BenchRow] = []
    agg: Dict[str, Dict[str, List[float]]] = {
        p: {"p95": [], "mean": [], "thr": []} for p in policies}
    for w1, w2, contention in PAPER_PAIRS:
        us, results = timed(lambda a=w1, b=w2: {
            p: run_pair(a, b, p) for p in policies})
        base = results[base_policy]
        for p in policies:
            r = results[p]
            for i in range(2):
                # normalized to the baseline: latency ratios <1 are
                # better; throughput ratios >1 are better
                p95 = r.tenants[i].p95() / max(base.tenants[i].p95(), 1e-9)
                mean = r.tenants[i].mean() / max(base.tenants[i].mean(), 1e-9)
                thr = r.throughput(i) / max(base.throughput(i), 1e-9)
                agg[p]["p95"].append(p95)
                agg[p]["mean"].append(mean)
                agg[p]["thr"].append(thr)
            rows.append(BenchRow(
                f"fig19_21/{w1}+{w2}/{contention}/{p}", us / len(policies),
                f"p95x={r.tenants[0].p95()/max(base.tenants[0].p95(),1e-9):.2f}"
                f"/{r.tenants[1].p95()/max(base.tenants[1].p95(),1e-9):.2f} "
                f"thrx={r.throughput(0)/max(base.throughput(0),1e-9):.2f}"
                f"/{r.throughput(1)/max(base.throughput(1),1e-9):.2f}"))
    for p in policies:
        rows.append(BenchRow(
            f"fig19_21/geomean/{p}", 0.0,
            f"p95={geomean(agg[p]['p95']):.3f} "
            f"mean={geomean(agg[p]['mean']):.3f} "
            f"thr={geomean(agg[p]['thr']):.3f}"))
    # headline orderings (qualitative reproduction gates) — only
    # meaningful for the paper's own policy set
    if {"pmt", "neu10", "neu10_nh"} <= set(policies) and base_policy == "pmt":
        assert geomean(agg["neu10"]["thr"]) > 1.1       # beats PMT
        assert geomean(agg["neu10"]["p95"]) < 1.0       # better tail than PMT
        assert geomean(agg["neu10"]["thr"]) > geomean(agg["neu10_nh"]["thr"])
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
