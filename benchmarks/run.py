"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig19_21   # one figure
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    fig12_allocator,
    fig16_neuisa_overhead,
    fig19_21_latency_throughput,
    fig22_utilization,
    fig25_scaling,
    fig26_hbm,
    fig_chunked_prefill,
    fig_colocation,
    table3_harvest_overhead,
)

SUITES = {
    "fig12": fig12_allocator,
    "fig16": fig16_neuisa_overhead,
    "fig19_21": fig19_21_latency_throughput,
    "fig22": fig22_utilization,
    "table3": table3_harvest_overhead,
    "fig25": fig25_scaling,
    "fig26": fig26_hbm,
    "fig_colocation": fig_colocation,
    "fig_chunked_prefill": fig_chunked_prefill,
}


def main() -> None:
    selected = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    failures = []
    for key in selected:
        mod = SUITES[key]
        t0 = time.time()
        try:
            for row in mod.run():
                print(row.csv(), flush=True)
            print(f"{key}/TOTAL,{(time.time()-t0)*1e6:.0f},ok", flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"{key}/TOTAL,0,FAILED: {e}", flush=True)
            failures.append(key)
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
