"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig19_21   # one figure

``--json [PATH]`` additionally writes the rows as structured records
(default ``BENCH_serving.json``) so the perf trajectory is
machine-readable: each record carries the suite, row name,
``us_per_call``, the raw derived string AND a ``metrics`` dict parsed
from its ``key=value`` pairs (numeric values with their unit suffixes
stripped). The top-level ``wall_s`` map records each suite's total
wall-clock seconds, so suite-level runtime regressions are tracked
alongside the per-row numbers. CI's benchmark-smoke job uploads the
file as an artifact.
"""
from __future__ import annotations

import argparse
import json
import re
import time
import traceback
from typing import Dict, List, Optional

from benchmarks import (
    fig12_allocator,
    fig16_neuisa_overhead,
    fig19_21_latency_throughput,
    fig22_utilization,
    fig25_scaling,
    fig26_hbm,
    fig_admission,
    fig_chunked_prefill,
    fig_colocation,
    fig_fabric,
    fig_fault,
    fig_kv_pressure,
    fig_prefix_cache,
    table3_harvest_overhead,
)

SUITES = {
    "fig12": fig12_allocator,
    "fig16": fig16_neuisa_overhead,
    "fig19_21": fig19_21_latency_throughput,
    "fig22": fig22_utilization,
    "table3": table3_harvest_overhead,
    "fig25": fig25_scaling,
    "fig26": fig26_hbm,
    "fig_colocation": fig_colocation,
    "fig_chunked_prefill": fig_chunked_prefill,
    "fig_fabric": fig_fabric,
    "fig_fault": fig_fault,
    "fig_kv_pressure": fig_kv_pressure,
    "fig_prefix_cache": fig_prefix_cache,
    "fig_admission": fig_admission,
}

# "chat_ttft_p95=0.0063ms" / "speedup=1.50x" / "interleaved=9" ->
# numeric value with the unit suffix stripped; non-numeric values
# (e.g. "identical=True") are kept as strings
_NUM = re.compile(r"^(-?\d+(?:\.\d+)?(?:e-?\d+)?)([a-zA-Z%/]*)$")


def parse_metrics(derived: str) -> Dict[str, object]:
    """Parse a row's ``key=value`` derived string into a dict (other
    tokens are ignored); numbers lose their unit suffix."""
    out: Dict[str, object] = {}
    for tok in derived.split():
        if "=" not in tok:
            continue
        key, _, val = tok.partition("=")
        m = _NUM.match(val)
        out[key] = float(m.group(1)) if m else val
    return out


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="run paper-figure benchmark suites")
    ap.add_argument("suites", nargs="*", metavar="suite", default=[],
                    help=f"suites to run (default: all): {', '.join(SUITES)}")
    ap.add_argument("--json", nargs="?", const="BENCH_serving.json",
                    default=None, metavar="PATH",
                    help="also write rows as JSON records "
                         "(default path: BENCH_serving.json)")
    args = ap.parse_args(argv)
    unknown = [s for s in args.suites if s not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; known: {', '.join(SUITES)}")
    selected = args.suites or list(SUITES)
    print("name,us_per_call,derived")
    failures = []
    records = []
    wall_s: Dict[str, float] = {}
    for key in selected:
        mod = SUITES[key]
        t0 = time.time()
        try:
            for row in mod.run():
                print(row.csv(), flush=True)
                records.append({
                    "suite": key,
                    "name": row.name,
                    "us_per_call": round(row.us_per_call, 1),
                    "derived": row.derived,
                    "metrics": parse_metrics(row.derived),
                })
            wall_s[key] = round(time.time() - t0, 3)
            print(f"{key}/TOTAL,{(time.time()-t0)*1e6:.0f},ok", flush=True)
        except Exception as e:
            traceback.print_exc()
            wall_s[key] = round(time.time() - t0, 3)
            print(f"{key}/TOTAL,0,FAILED: {e}", flush=True)
            failures.append(key)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "suites": selected,
                       "failures": failures, "wall_s": wall_s,
                       "rows": records},
                      f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(records)} records to {args.json}", flush=True)
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
