"""Paper Fig. 25: Neu10 throughput improvement over V10 as the core
grows (2ME/2VE .. 8ME/8VE, split evenly between the two vNPUs).
Paper claim: more MEs/VEs -> more benefit from μTOp scheduling.

Also carries the simulator perf micro-benchmarks:

* fast path — the largest sweep (8ME/8VE, the heaviest event load)
  re-runs with ``fast_path=False`` (reference implementations:
  unmemoized dispatch durations, engine-scan HBM pressure, reference
  neu10 schedule pass) vs the default fast path, asserting the
  SimResults are IDENTICAL and the wall-clock speedup is >= 1.3x
  (min-of-N timings to reject machine noise).
* sched_incremental — the same sweep with the dirty-set incremental
  scheduling core (cohort dispatch + free-engine index) vs both the
  reference and the PR-4 fast-path baselines, again with a 3-way
  SimResult identity proof.
* fleet_sweep — the fig25 outer grid (pairs × EU splits × bandwidth
  points) as ONE jitted ``sweep_collocations`` XLA program vs the
  discrete simulator running the same grid one cell at a time; this
  is the row CI's benchmark-smoke asserts a >= 3x floor on (locally
  the single-dispatch sweep is >= 10x)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import BenchRow, build_pair_specs, geomean, \
    run_pair, timed
from repro.core.policies import resolve_policy
from repro.core.sim_jax import sweep_collocations
from repro.core.simulator import Simulator
from repro.core.vnpu import VNPUConfig
from repro.npu.hw_config import NPUCoreConfig
from repro.npu.workloads import get_workload
from repro.serve.session import NPUCluster, run_closed_loop

PAIRS = [("ENet", "TFMR"), ("RNRS", "RtNt"), ("BERT", "ENet")]
SIZES = [2, 4, 8]

FAST_PATH_GAIN = 1.3   # required wall-clock speedup, largest sweep
FAST_PATH_REPS = 5     # min-of-N per variant (noise rejection)

INCREMENTAL_GAIN = 1.05   # incremental core vs the fast-path baseline
FLEET_SWEEP_GAIN = 3.0    # jitted grid vs per-cell discrete (CI floor)

# fleet-sweep grid: pairs × EU splits × HBM-bandwidth points on the
# 8ME/8VE core (per-tenant (ME, VE) engine counts; asymmetric splits
# exercise the collocation-search question the sweep exists for)
SWEEP_SPLITS = (((4, 4), (4, 4)), ((6, 2), (6, 2)), ((2, 6), (2, 6)))
SWEEP_BW = (0.5, 1.0, 2.0)
SWEEP_REQUESTS = 4


def _fast_path_row() -> BenchRow:
    """Time the largest sweep (8ME/8VE BERT+ENet under neu10) with the
    reference vs fast simulator paths; prove identical results."""
    core = NPUCoreConfig(n_me=8, n_ve=8)
    times = {True: [], False: []}
    results = {}
    for _ in range(FAST_PATH_REPS):
        for fast in (False, True):
            t0 = time.time()
            res = run_pair("BERT", "ENet", "neu10", core=core,
                           me_ve=(4, 4), fast_path=fast)
            times[fast].append(time.time() - t0)
            results[fast] = res
    ref, opt = results[False], results[True]
    identical = (ref.makespan == opt.makespan
                 and ref.tenants == opt.tenants)
    assert identical, "fast path diverged from the reference simulator"
    speedup = min(times[False]) / max(min(times[True]), 1e-9)
    assert speedup >= FAST_PATH_GAIN, (
        f"fast path {speedup:.2f}x < required {FAST_PATH_GAIN}x")
    return BenchRow(
        "fig25/fast_path/BERT+ENet/8ME8VE",
        min(times[True]) * 1e6,
        f"speedup={speedup:.2f}x identical=True "
        f"ref_us={min(times[False]) * 1e6:.0f}")


def _sched_incremental_row() -> BenchRow:
    """The incremental dirty-set scheduling core on the largest sweep
    vs BOTH baselines — the reference schedule pass and the PR-4 fast
    path — with a 3-way SimResult identity proof. Specs compile ONCE
    (``build_pair_specs``) so only ``Simulator(...).run()`` is
    timed."""
    core = NPUCoreConfig(n_me=8, n_ve=8)
    specs = build_pair_specs("BERT", "ENet", "neu10", core=core,
                             me_ve=(4, 4), n_requests=6)
    variants = {"ref": (False, False), "fast": (True, False),
                "inc": (True, True)}
    times = {k: [] for k in variants}
    results = {}
    for _ in range(FAST_PATH_REPS):
        for k, (fast, inc) in variants.items():
            t0 = time.time()
            res = Simulator(specs, policy="neu10", core=core,
                            fast_path=fast, incremental=inc).run()
            times[k].append(time.time() - t0)
            results[k] = res
    assert results["inc"] == results["ref"] == results["fast"], (
        "incremental scheduling core diverged from the reference")
    t = {k: min(v) for k, v in times.items()}
    vs_ref = t["ref"] / max(t["inc"], 1e-9)
    vs_fast = t["fast"] / max(t["inc"], 1e-9)
    assert vs_fast >= INCREMENTAL_GAIN, (
        f"incremental core {vs_fast:.2f}x < required "
        f"{INCREMENTAL_GAIN}x over the fast path")
    return BenchRow(
        "fig25/sched_incremental/BERT+ENet/8ME8VE",
        t["inc"] * 1e6,
        f"speedup_vs_ref={vs_ref:.2f}x speedup_vs_fast={vs_fast:.2f}x "
        f"identical=True ref_us={t['ref'] * 1e6:.0f} "
        f"fast_us={t['fast'] * 1e6:.0f}")


def _fleet_sweep_row() -> BenchRow:
    """The fig25 outer grid (pair × EU split × HBM bandwidth) two
    ways: the discrete simulator one cell at a time vs ONE jitted
    ``sweep_collocations`` dispatch covering the whole lattice. The
    sweep call is warmed once (XLA compile amortizes over every later
    grid at these shapes) and timed min-of-N; the discrete grid runs
    each cell as a fresh event loop, exactly how the figures drive
    it. Both sides answer the same capacity-planning query — the
    discrete engine stays the validated oracle (the fluid model's
    ordering is pinned against it in tests/test_sim_jax.py)."""
    core = NPUCoreConfig(n_me=8, n_ve=8)
    pol = resolve_policy("neu10")
    progs = {n: pol.compile_program(get_workload(n, core), core)
             for n in {w for pair in PAIRS for w in pair}}
    prog_pairs = [(progs[a], progs[b]) for a, b in PAIRS]

    def discrete_cell(pair, split, bw):
        (m1, m2), (v1, v2) = split
        cluster = NPUCluster(core=core, policy="neu10")
        for name, m, v in ((pair[0], m1, v1), (pair[1], m2, v2)):
            cluster.register_vnpu(
                name, get_workload(name, core),
                VNPUConfig(n_me=m, n_ve=v,
                           hbm_bytes=core.hbm_bytes // 2,
                           sram_bytes=core.sram_bytes // 2))
        res, _ = run_closed_loop(cluster, n_requests=SWEEP_REQUESTS,
                                 hbm_scale=bw)
        return res

    t0 = time.time()
    for pair in PAIRS:
        for split in SWEEP_SPLITS:
            for bw in SWEEP_BW:
                discrete_cell(pair, split, bw)
    discrete_wall = time.time() - t0

    def sweep():
        out = sweep_collocations(prog_pairs, SWEEP_SPLITS,
                                 bw_points=SWEEP_BW,
                                 n_requests=SWEEP_REQUESTS, core=core)
        out["makespan"].block_until_ready()
        return out

    sweep()   # warm-up: XLA compilation paid once
    sweep_wall = 1e9
    for _ in range(3):
        t0 = time.time()
        out = sweep()
        sweep_wall = min(sweep_wall, time.time() - t0)
    n_cells = len(PAIRS) * len(SWEEP_SPLITS) * len(SWEEP_BW)
    assert out["makespan"].shape == (len(PAIRS), len(SWEEP_SPLITS),
                                     len(SWEEP_BW))
    speedup = discrete_wall / max(sweep_wall, 1e-9)
    assert speedup >= FLEET_SWEEP_GAIN, (
        f"vectorized fleet sweep {speedup:.1f}x < required "
        f"{FLEET_SWEEP_GAIN}x over the per-cell discrete grid")
    return BenchRow(
        "fig25/fleet_sweep/grid",
        sweep_wall * 1e6,
        f"speedup={speedup:.1f}x cells={n_cells} "
        f"discrete_us={discrete_wall * 1e6:.0f}")


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    gains_by_size = {}
    for n in SIZES:
        core = NPUCoreConfig(n_me=n, n_ve=n)
        half = (n // 2, n // 2)
        gains = []
        for w1, w2 in PAIRS:
            us, pair = timed(lambda a=w1, b=w2: (
                run_pair(a, b, "neu10", core=core, me_ve=half),
                run_pair(a, b, "v10", core=core, me_ve=half)))
            neu, v10 = pair
            g = neu.total_throughput() / max(v10.total_throughput(), 1e-9)
            gains.append(g)
            rows.append(BenchRow(
                f"fig25/{w1}+{w2}/{n}ME{n}VE", us, f"neu10/v10={g:.3f}"))
        gains_by_size[n] = geomean(gains)
        rows.append(BenchRow(f"fig25/geomean/{n}ME{n}VE", 0.0,
                             f"{gains_by_size[n]:.3f}"))
    # scaling trend: benefit at 8 engines >= benefit at 2 engines
    assert gains_by_size[8] >= gains_by_size[2] - 0.05
    rows.append(_fast_path_row())
    rows.append(_sched_incremental_row())
    rows.append(_fleet_sweep_row())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
