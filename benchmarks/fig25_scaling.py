"""Paper Fig. 25: Neu10 throughput improvement over V10 as the core
grows (2ME/2VE .. 8ME/8VE, split evenly between the two vNPUs).
Paper claim: more MEs/VEs -> more benefit from μTOp scheduling."""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchRow, geomean, run_pair, timed
from repro.npu.hw_config import NPUCoreConfig

PAIRS = [("ENet", "TFMR"), ("RNRS", "RtNt"), ("BERT", "ENet")]
SIZES = [2, 4, 8]


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    gains_by_size = {}
    for n in SIZES:
        core = NPUCoreConfig(n_me=n, n_ve=n)
        half = (n // 2, n // 2)
        gains = []
        for w1, w2 in PAIRS:
            us, pair = timed(lambda a=w1, b=w2: (
                run_pair(a, b, "neu10", core=core, me_ve=half),
                run_pair(a, b, "v10", core=core, me_ve=half)))
            neu, v10 = pair
            g = neu.total_throughput() / max(v10.total_throughput(), 1e-9)
            gains.append(g)
            rows.append(BenchRow(
                f"fig25/{w1}+{w2}/{n}ME{n}VE", us, f"neu10/v10={g:.3f}"))
        gains_by_size[n] = geomean(gains)
        rows.append(BenchRow(f"fig25/geomean/{n}ME{n}VE", 0.0,
                             f"{gains_by_size[n]:.3f}"))
    # scaling trend: benefit at 8 engines >= benefit at 2 engines
    assert gains_by_size[8] >= gains_by_size[2] - 0.05
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
