"""Paper Fig. 25: Neu10 throughput improvement over V10 as the core
grows (2ME/2VE .. 8ME/8VE, split evenly between the two vNPUs).
Paper claim: more MEs/VEs -> more benefit from μTOp scheduling.

Also carries the simulator fast-path micro-benchmark: the largest
sweep (8ME/8VE, the heaviest event load) re-runs with
``fast_path=False`` (reference implementations: unmemoized dispatch
durations, engine-scan HBM pressure, reference neu10 schedule pass)
vs the default fast path, asserting the SimResults are IDENTICAL and
the wall-clock speedup is >= 1.3x (min-of-N timings to reject
machine noise)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import BenchRow, geomean, run_pair, timed
from repro.npu.hw_config import NPUCoreConfig

PAIRS = [("ENet", "TFMR"), ("RNRS", "RtNt"), ("BERT", "ENet")]
SIZES = [2, 4, 8]

FAST_PATH_GAIN = 1.3   # required wall-clock speedup, largest sweep
FAST_PATH_REPS = 5     # min-of-N per variant (noise rejection)


def _fast_path_row() -> BenchRow:
    """Time the largest sweep (8ME/8VE BERT+ENet under neu10) with the
    reference vs fast simulator paths; prove identical results."""
    core = NPUCoreConfig(n_me=8, n_ve=8)
    times = {True: [], False: []}
    results = {}
    for _ in range(FAST_PATH_REPS):
        for fast in (False, True):
            t0 = time.time()
            res = run_pair("BERT", "ENet", "neu10", core=core,
                           me_ve=(4, 4), fast_path=fast)
            times[fast].append(time.time() - t0)
            results[fast] = res
    ref, opt = results[False], results[True]
    identical = (ref.makespan == opt.makespan
                 and ref.tenants == opt.tenants)
    assert identical, "fast path diverged from the reference simulator"
    speedup = min(times[False]) / max(min(times[True]), 1e-9)
    assert speedup >= FAST_PATH_GAIN, (
        f"fast path {speedup:.2f}x < required {FAST_PATH_GAIN}x")
    return BenchRow(
        "fig25/fast_path/BERT+ENet/8ME8VE",
        min(times[True]) * 1e6,
        f"speedup={speedup:.2f}x identical=True "
        f"ref_us={min(times[False]) * 1e6:.0f}")


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    gains_by_size = {}
    for n in SIZES:
        core = NPUCoreConfig(n_me=n, n_ve=n)
        half = (n // 2, n // 2)
        gains = []
        for w1, w2 in PAIRS:
            us, pair = timed(lambda a=w1, b=w2: (
                run_pair(a, b, "neu10", core=core, me_ve=half),
                run_pair(a, b, "v10", core=core, me_ve=half)))
            neu, v10 = pair
            g = neu.total_throughput() / max(v10.total_throughput(), 1e-9)
            gains.append(g)
            rows.append(BenchRow(
                f"fig25/{w1}+{w2}/{n}ME{n}VE", us, f"neu10/v10={g:.3f}"))
        gains_by_size[n] = geomean(gains)
        rows.append(BenchRow(f"fig25/geomean/{n}ME{n}VE", 0.0,
                             f"{gains_by_size[n]:.3f}"))
    # scaling trend: benefit at 8 engines >= benefit at 2 engines
    assert gains_by_size[8] >= gains_by_size[2] - 0.05
    rows.append(_fast_path_row())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
