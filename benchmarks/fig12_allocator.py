"""Paper Fig. 12: allocator cost-effectiveness.

Sweep the EU budget from 2 to 16 for representative workloads; for
each budget compare the Eq.-4 allocator's (n_me, n_ve) pick against
every alternative split, scoring by simulated solo throughput on a
matching (8ME/8VE-max) core with harvesting off (so the allocation —
not the scheduler — is what's measured).

Success criterion (paper: "selects a configuration with better
performance than others ... a sub-optimal pick still achieves similar
performance"): allocator pick within 5% of the best split.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import BenchRow, timed
from repro.core import TenantSpec, VNPUConfig, VNPUManager, compile_neuisa
from repro.core.allocator import allocate_for_trace
from repro.core.simulator import Simulator
from repro.npu.hw_config import NPUCoreConfig
from repro.npu.workloads import get_workload

WORKLOADS = ("BERT", "DLRM", "RsNt", "ENet", "NCF", "RtNt")


def _solo_throughput(name: str, n_me: int, n_ve: int,
                     core: NPUCoreConfig) -> float:
    mgr = VNPUManager(core=core)
    tr = get_workload(name, core)
    v = mgr.create(VNPUConfig(n_me, n_ve, hbm_bytes=core.hbm_bytes))
    res = Simulator([TenantSpec(compile_neuisa(tr, core), v, 4)],
                    policy="neu10_nh", core=core).run()
    return res.throughput(0)


def run() -> List[BenchRow]:
    core = NPUCoreConfig(n_me=8, n_ve=8)
    rows: List[BenchRow] = []
    regrets: List[float] = []
    for name in WORKLOADS:
        tr = get_workload(name, core)
        for budget in (4, 8, 12, 16):
            us, _ = timed(lambda: None)
            alloc = allocate_for_trace(tr, budget, core)
            picked = _solo_throughput(name, alloc.n_me, alloc.n_ve, core)
            best = picked
            best_split = (alloc.n_me, alloc.n_ve)
            for n_me in range(1, budget):
                n_ve = budget - n_me
                if n_me > core.n_me or n_ve > core.n_ve:
                    continue
                thr = _solo_throughput(name, n_me, n_ve, core)
                if thr > best:
                    best, best_split = thr, (n_me, n_ve)
            regret = 1.0 - picked / best
            regrets.append(regret)
            rows.append(BenchRow(
                f"fig12/{name}/eu{budget}", us,
                f"picked=({alloc.n_me},{alloc.n_ve}) best={best_split} "
                f"regret={regret:.3f}"))
    avg = sum(regrets) / len(regrets)
    rows.append(BenchRow("fig12/avg_regret", 0.0, f"{avg:.4f}"))
    assert avg < 0.05, f"allocator avg regret {avg:.3f} exceeds 5%"
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
