"""Chaos sweep: fault rate x topology, whole-vNPU evacuation vs
kill-and-restart failover.

A bursty chat tenant (qwen2-0.5b SMOKE, deadline/retry admission on)
is served on a 4-core cluster while a seeded
:class:`~repro.core.faults.FaultSchedule` injects core failures, HBM
segment faults, and link degradation. Each (topology, fault-rate) arm
runs twice — ``failover="evacuate"`` (the tentpole: the whole vNPU,
live KV included, migrates to a surviving core over the priced
fabric) against ``failover="restart"`` (the classic baseline: the
vNPU dies, live requests re-enter admission through the bounded
retry/backoff path) — plus a fault-free reference.

Every chaos schedule is anchored by one deterministic transient
core-down on the chat tenant's home core in the middle of the burst,
so each faulted arm provably exercises a failover round-trip (the
Poisson noise alone could miss the tenant's core at low rates).

Assertions (simulator counters, not derived latency):

* EVERY arm — fault-free, moderate, high, both failover modes, both
  topologies — completes all requests with ZERO KV leak and exact
  HBM segment conservation (``manager.hbm_census()``: free + resident
  + faulted == total on every core; a faulted segment is parked, not
  lost or double-freed);
* every ``evacuate`` arm performs >= 1 whole-vNPU evacuation
  round-trip and still completes the workload; every ``restart`` arm
  completes >= 1 deadline-retried request (``retry_successes``);
* at the moderate fault rate, evacuation beats kill-and-restart by
  >= ``EVAC_GAIN`` (1.3x) on chat e2e p95 on every topology;
* fault-rate inflation stays bounded: the moderate-rate evacuate
  arm's e2e p95 is <= ``MAX_INFLATION`` x the fault-free p95.

    PYTHONPATH=src python -m benchmarks.run fig_fault
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from benchmarks.common import BenchRow, timed
from repro.configs import SMOKES
from repro.core.fabric import FabricLink, FabricTopology
from repro.core.faults import FaultEvent, FaultSchedule
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import (NPUCluster, PoissonArrivals,
                                 ServingSession)

CHAT = "qwen2-0.5b"
SEG = 64 * 1024                  # shrunken HBM isolation segment
CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)
LINK = FabricLink(bandwidth=16.0, latency=400_000.0)

PROMPT = 256                     # tokens
GEN = 32                         # decode tokens per request
N_REQ = 24
RATE_RPS = 100_000.0             # burst: deep queue when the fault hits
HBM = 256 * SEG                  # chat vNPU HBM pin (bytes)

DEADLINE_MS = 50.0               # per-attempt admission deadline
MAX_RETRIES = 3
BACKOFF_MS = 0.05                # exponential base

# anchor fault: the chat core goes down mid-burst, back 2 ms later
ANCHOR_AT = 0.0002               # seconds of simulated time
ANCHOR_RECOVERY = 0.002
HORIZON = 0.004                  # chaos window (covers the whole run)

TOPOLOGIES: Tuple[str, ...] = ("mesh", "ring")
N_CORES = 4
RATES: Tuple[Tuple[str, float], ...] = (("moderate", 1.0), ("high", 2.0))
EVAC_GAIN = 1.3                  # evacuate vs restart, e2e p95, moderate
MAX_INFLATION = 12.0             # moderate-evacuate vs fault-free p95


def _topology(kind: str) -> FabricTopology:
    builder = {"mesh": FabricTopology.mesh, "ring": FabricTopology.ring}
    return builder[kind](N_CORES, LINK)


def _schedule(topo: FabricTopology, scale: float, seed: int
              ) -> FaultSchedule:
    """Seeded Poisson chaos + the deterministic anchor core-down."""
    noise = FaultSchedule.chaos(
        horizon=HORIZON, n_cores=N_CORES, links=list(topo.links),
        seed=seed,
        core_fault_rate=1.0 * scale, link_fault_rate=1.0 * scale,
        hbm_fault_rate=1.0 * scale,
        transient_frac=1.0, recovery=ANCHOR_RECOVERY,
        bw_scale=0.25, link_outage_frac=0.25 if scale > 1 else 0.0)
    anchor = FaultEvent(at=ANCHOR_AT, kind="core_down", core=0,
                        recovery=ANCHOR_RECOVERY)
    return FaultSchedule(list(noise) + [anchor])


def serve(kind: str, faults: FaultSchedule = None,
          failover: str = "evacuate") -> Dict[str, float]:
    """One open-loop burst run; returns tail metrics (ms) plus the raw
    fault / ledger / census counters."""
    topo = _topology(kind)
    cluster = NPUCluster(core=CORE, policy="neu10", topology=topo)
    sess = ServingSession(cluster, faults=faults, failover=failover)
    chat = sess.register_generative(
        "chat", SMOKES[CHAT], prompt_len=PROMPT, gen_lens=GEN,
        eu_budget=4, kv_policy="evict", hbm_bytes=HBM,
        deadline_ms=DEADLINE_MS, max_retries=MAX_RETRIES,
        retry_backoff_ms=BACKOFF_MS)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=RATE_RPS,
                                               n=N_REQ, seed=1))
    sess.drain()
    r = sess.report(chat)[0]
    led = chat.vnpu.kv_ledger if chat.vnpu is not None else None
    census = cluster.manager.hbm_census()
    conserved = all(free + resident + faulted == total
                    for free, resident, faulted, total in census)
    return {
        "done": float(r.requests_done),
        "e2e_p95": r.p95_ms,
        "ttft_p95": r.ttft_p95_ms,
        "evacuations": float(r.evacuations),
        "evacuated_kb": r.evacuated_bytes / 1024.0,
        "faults_survived": float(r.faults_survived),
        "hbm_fault_segs": float(r.hbm_fault_segments),
        "retries": float(r.retries),
        "retry_successes": float(r.retry_successes),
        "retries_exhausted": float(r.retries_exhausted),
        "downtime_ms": r.downtime_ms,
        "availability": r.availability,
        "kv_leak_bytes": float(led.in_use + led.shared_in_use
                               if led is not None else 0),
        "census_ok": float(conserved),
    }


def _check(m: Dict[str, float], arm: str) -> None:
    """Per-arm robustness invariants: the workload survives, the
    ledgers drain, and no HBM segment is lost or double-freed."""
    assert m["done"] + m["retries_exhausted"] >= N_REQ, (arm, m)
    assert m["kv_leak_bytes"] == 0, (arm, m)
    assert m["census_ok"] == 1.0, (arm, m)


def run(topologies: Sequence[str] = TOPOLOGIES) -> List[BenchRow]:
    rows: List[BenchRow] = []
    for kind in topologies:
        us, base = timed(lambda k=kind: serve(k))
        _check(base, f"{kind}/fault_free")
        assert base["done"] == N_REQ, base
        assert base["faults_survived"] == 0, base
        rows.append(BenchRow(
            f"fig_fault/{kind}/fault_free", us,
            f"e2e_p95={base['e2e_p95']:.4f}ms done={base['done']:.0f} "
            f"availability={base['availability']:.4f}"))

        moderate: Dict[str, Dict[str, float]] = {}
        for rate_name, scale in RATES:
            arms = (("evacuate", "restart") if rate_name == "moderate"
                    else ("evacuate",))
            for mode in arms:
                us, m = timed(lambda k=kind, s=scale, f=mode: serve(
                    k, _schedule(_topology(k), s, seed=7), failover=f))
                arm = f"{kind}/{rate_name}/{mode}"
                _check(m, arm)
                if mode == "evacuate":
                    # headline (b): >= 1 whole-vNPU failover round-trip,
                    # everything still completes
                    assert m["evacuations"] >= 1, (arm, m)
                    assert m["done"] == N_REQ, (arm, m)
                else:
                    # headline (c): the retry path re-admits and
                    # completes fault-aborted work
                    assert m["retry_successes"] >= 1, (arm, m)
                if rate_name == "moderate":
                    moderate[mode] = m
                rows.append(BenchRow(
                    f"fig_fault/{arm}", us,
                    f"e2e_p95={m['e2e_p95']:.4f}ms done={m['done']:.0f} "
                    f"evacuations={m['evacuations']:.0f} "
                    f"evacuated_kb={m['evacuated_kb']:.0f} "
                    f"retries={m['retries']:.0f} "
                    f"retry_successes={m['retry_successes']:.0f} "
                    f"hbm_fault_segs={m['hbm_fault_segs']:.0f} "
                    f"downtime_ms={m['downtime_ms']:.3f} "
                    f"availability={m['availability']:.4f}"))

        # headline (a): carrying the vNPU beats killing it
        ev, rs = moderate["evacuate"], moderate["restart"]
        gain = rs["e2e_p95"] / max(ev["e2e_p95"], 1e-9)
        inflation = ev["e2e_p95"] / max(base["e2e_p95"], 1e-9)
        rows.append(BenchRow(
            f"fig_fault/{kind}/evacuate_vs_restart", 0.0,
            f"e2e_gain={gain:.2f}x "
            f"evacuate_p95={ev['e2e_p95']:.4f}ms "
            f"restart_p95={rs['e2e_p95']:.4f}ms "
            f"p95_inflation={inflation:.2f}x"))
        assert gain >= EVAC_GAIN, (kind, gain, ev, rs)
        assert inflation <= MAX_INFLATION, (kind, inflation, ev, base)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
