"""Prefill/decode co-location sweep (FlexNPU-style, paper §V-F).

Two generative tenants share one pNPU core:

* ``doc``  — prefill-heavy: long prompts (2k tokens), ~2 generated
  tokens per request (summarization / scoring traffic).
* ``chat`` — decode-heavy: short prompts, a geometric generation-
  length distribution (interactive chat traffic).

Requests flow through the phase-aware open-loop session: each request
is a prefill phase followed by context-bucketed decode steps, and
decode steps from a tenant's in-flight requests coalesce into shared
decode iterations (continuous batching). The sweep reports TTFT and
TBT p95/p99 per tenant under ``neu10`` vs the ``pmt``/``v10``
baselines — the co-location win is `neu10` interleaving the chat
tenant's decode μTOps into the VE-idle windows under `doc`'s prefill
(Fig. 2/6), where the temporal baselines serialize whole operators.

    PYTHONPATH=src python -m benchmarks.run fig_colocation
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from benchmarks.common import BenchRow, timed
from repro.configs import SMOKES
from repro.core.stats import percentile
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, ServingSession,
                                 TenantReport)

POLICIES = ("pmt", "v10", "neu10")
N_CHAT = 24
N_DOC = 10


def serve_mix(policy: str, model: str = "qwen2-0.5b",
              ) -> Tuple[Dict[str, TenantReport], Dict[str, Dict[str, float]]]:
    """One co-location run; returns per-tenant reports + tail stats."""
    cluster = NPUCluster(policy=policy)
    sess = ServingSession(cluster)
    cfg = SMOKES[model]
    chat = sess.register_generative(
        "chat", cfg, prompt_len=128,
        gen_lens=GenLenDistribution(mean=24.0, max_len=96, seed=11),
        eu_budget=4, slo_ttft_ms=5.0, slo_tbt_ms=1.0)
    doc = sess.register_generative(
        "doc", cfg, prompt_len=2048, gen_lens=2, eu_budget=4)
    # overlapping open-loop arrivals so prefills and decodes collide:
    # chat arrives faster than a lone request drains (decode steps
    # coalesce), doc keeps a long prefill in flight most of the time
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=30_000.0, n=N_CHAT,
                                               seed=1))
    sess.submit_arrivals(doc, PoissonArrivals(rate_rps=4_000.0, n=N_DOC,
                                              seed=2))
    sess.drain()
    ms = 1e3 / cluster.core.freq_hz
    reports = {r.name: r for r in sess.report()}
    tails = {}
    for h in (chat, doc):
        st = sess.sim.tenants[h.sim_idx].stats
        tails[h.name] = {
            "ttft_p95": percentile(st.ttft, 0.95) * ms,
            "ttft_p99": percentile(st.ttft, 0.99) * ms,
            "tbt_p95": percentile(st.tbt, 0.95) * ms,
            "tbt_p99": percentile(st.tbt, 0.99) * ms,
            "max_decode_batch": float(st.max_decode_batch),
            "decode_iterations": float(st.decode_iterations),
            "tokens": float(st.tokens),
        }
    return reports, tails


def run(policies: Sequence[str] = POLICIES) -> List[BenchRow]:
    rows: List[BenchRow] = []
    tbt95: Dict[str, float] = {}
    ttft95: Dict[str, float] = {}
    doc95: Dict[str, float] = {}
    for policy in policies:
        us, (reports, tails) = timed(lambda p=policy: serve_mix(p))
        for name in ("chat", "doc"):
            t = tails[name]
            rows.append(BenchRow(
                f"fig_colocation/{name}/{policy}", us,
                f"ttft_p95={t['ttft_p95']:.3f}ms "
                f"ttft_p99={t['ttft_p99']:.3f}ms "
                f"tbt_p95={t['tbt_p95']:.3f}ms "
                f"tbt_p99={t['tbt_p99']:.3f}ms "
                f"reqs={reports[name].requests_done} "
                f"tokens={reports[name].tokens_done}"))
        # the continuous-batching path must actually be exercised:
        # >= 2 in-flight chat requests sharing one decode iteration
        assert tails["chat"]["max_decode_batch"] >= 2, tails["chat"]
        assert all(r.requests_done for r in reports.values())
        tbt95[policy] = tails["chat"]["tbt_p95"]
        ttft95[policy] = tails["chat"]["ttft_p95"]
        doc95[policy] = reports["doc"].p95_ms
    # the co-location wins (each baseline loses where the paper says):
    # * PMT's whole-core request service head-of-line blocks the chat
    #   tenant's first token behind doc's 2k-token prefill;
    # * V10's all-ME operators stall the chat token cadence (TBT);
    # * neu10 also finishes doc's prefill-heavy requests soonest by
    #   harvesting chat's idle MEs between decode iterations.
    if {"pmt", "v10", "neu10"} <= set(tbt95):
        ttft_ratio = ttft95["pmt"] / max(ttft95["neu10"], 1e-9)
        tbt_ratio = tbt95["v10"] / max(tbt95["neu10"], 1e-9)
        rows.append(BenchRow(
            "fig_colocation/chat_ttft_p95_pmt_vs_neu10", 0.0,
            f"{ttft_ratio:.2f}x"))
        rows.append(BenchRow(
            "fig_colocation/chat_tbt_p95_v10_vs_neu10", 0.0,
            f"{tbt_ratio:.2f}x"))
        rows.append(BenchRow(
            "fig_colocation/doc_e2e_p95", 0.0,
            " ".join(f"{p}={doc95[p]:.3f}ms" for p in ("pmt", "v10",
                                                       "neu10"))))
        assert ttft_ratio > 1.5, ttft95
        assert tbt_ratio > 1.5, tbt95
        assert doc95["neu10"] < min(doc95["pmt"], doc95["v10"]), doc95
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
