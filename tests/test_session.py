"""Online control plane: open-loop ServingSession — request-level
admission, mid-run tenant lifecycle, SLO autoscale hook."""
import pytest

from repro.core.mapper import ReconfigureError
from repro.npu.cost_model import Operator, WorkloadTrace
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import (NPUCluster, PoissonArrivals, SLOAutoscaler,
                                 ServingSession, TraceArrivals,
                                 run_closed_loop)


def _trace(name="w", me=200_000.0, ve=50_000.0, n_ops=8):
    return WorkloadTrace(name, [
        Operator(f"{name}_mm{i}", me_cycles=me / n_ops,
                 ve_cycles=ve / n_ops, n_tiles=8)
        for i in range(n_ops)
    ], core=DEFAULT_CORE)


def _session(policy="neu10", **kw):
    return ServingSession(NPUCluster(policy=policy), **kw)


# ----------------------------------------------------------------------
def test_open_loop_poisson_reports_p95_and_throughput():
    sess = _session()
    h = sess.register("a", _trace(), eu_budget=4)
    n = sess.submit_arrivals(h, PoissonArrivals(rate_rps=2000.0, n=40, seed=7))
    assert n == 40
    sess.drain()
    r = sess.report(h)[0]
    assert r.requests_done == 40
    assert r.queued == 0
    assert r.p95_ms > 0 and r.mean_ms > 0
    assert r.p95_ms >= r.mean_ms * 0.5
    assert r.throughput_rps > 0
    # all 40 arrivals are accounted per-request
    assert len(sess.latencies_ms(h)) == 40


def test_open_loop_deterministic():
    def go():
        sess = _session()
        h = sess.register("a", _trace(), eu_budget=4)
        sess.submit_arrivals(h, PoissonArrivals(rate_rps=1000.0, n=25, seed=3))
        sess.drain()
        return sess.latencies_ms(h)

    assert go() == go()


def test_queueing_latency_grows_with_load():
    """Latency is measured from ARRIVAL: an overloaded tenant's tail
    includes queueing delay, a lightly loaded one's does not."""
    def p95_at(rate):
        sess = _session()
        h = sess.register("a", _trace(me=2_000_000.0), eu_budget=4)
        sess.submit_arrivals(h, PoissonArrivals(rate_rps=rate, n=30, seed=0))
        sess.drain()
        return sess.report(h)[0].p95_ms

    assert p95_at(100_000.0) > 2.0 * p95_at(10.0)


def test_trace_arrivals_and_single_submit():
    sess = _session()
    h = sess.register("a", _trace(), eu_budget=4)
    sess.submit_arrivals(h, TraceArrivals([0.002, 0.001, 0.003]))
    sess.submit(h, at_s=0.004)
    sess.drain()
    assert sess.report(h)[0].requests_done == 4


def test_midrun_register_and_deregister():
    """Acceptance gate: a tenant joins and leaves mid-run without the
    simulation restarting."""
    sess = _session()
    a = sess.register("a", _trace("a"), eu_budget=4)
    sess.submit_arrivals(a, PoissonArrivals(rate_rps=1000.0, n=50, seed=1))
    sess.run_until(0.01)
    done_at_pause = sess.report(a)[0].requests_done
    assert 0 < done_at_pause < 50
    t_pause = sess.sim.now  # proof we never restart

    b = sess.register("b", _trace("b", me=50_000.0), eu_budget=2)
    sess.submit_arrivals(b, PoissonArrivals(
        rate_rps=2000.0, n=40, seed=2, start_s=sess.now_s))
    sess.run_until(0.03)
    rb = sess.report(b)[0]
    assert rb.requests_done > 0
    assert sess.sim.now >= t_pause  # same simulation, time kept flowing

    sess.deregister(b)
    assert b not in sess.cluster.tenants
    sess.drain()
    ra = sess.report(a)[0]
    assert ra.requests_done == 50  # a unaffected by b's life cycle
    # b's engines were released back: a freshly registered tenant fits
    c = sess.register("c", _trace("c"), eu_budget=2)
    sess.submit(c)
    sess.drain()
    assert sess.report(c)[0].requests_done == 1


def test_midrun_resize_grows_allocation():
    sess = _session()
    a = sess.register("a", _trace("a"), eu_budget=2)
    sess.submit_arrivals(a, PoissonArrivals(rate_rps=1000.0, n=30, seed=4))
    sess.run_until(0.005)
    before = a.vnpu.config.n_eus
    sess.resize(a, 6)
    assert a.vnpu.config.n_eus > before
    assert a.eu_budget == 6
    sess.drain()
    assert sess.report(a)[0].requests_done == 30


def test_resize_failure_restores_and_keeps_serving():
    sess = _session()
    a = sess.register("a", _trace("a"), eu_budget=4)
    b = sess.register("b", _trace("b"), eu_budget=4)
    sess.submit_arrivals(a, PoissonArrivals(rate_rps=1000.0, n=10, seed=5))
    sess.run_until(0.002)
    with pytest.raises(ReconfigureError):
        sess.resize(a, 8)  # no room next to b
    assert a.vnpu is not None and a.vnpu.config.n_eus >= 2
    sess.drain()
    assert sess.report(a)[0].requests_done == 10


def test_slo_autoscaler_hook_grows_budget():
    sess = _session(autoscaler=SLOAutoscaler(step_eus=2, max_eus=8,
                                             min_samples=3))
    # slow tenant with a tight SLO under sustained load
    a = sess.register("a", _trace("a", me=2_000_000.0), eu_budget=2,
                      slo_p95_ms=0.5)
    sess.submit_arrivals(a, PoissonArrivals(rate_rps=2000.0, n=60, seed=6))
    t = 0.0
    for _ in range(6):
        t += 0.01
        sess.run_until(t)
    assert a.eu_budget > 2  # the hook resized it mid-run
    sess.drain()
    assert sess.report(a)[0].requests_done == 60


def test_closed_loop_helper_matches_server_shim():
    from repro.serve.vserve import MultiTenantServer

    cluster = NPUCluster(policy="neu10")
    cluster.register("a", _trace("a"), eu_budget=4)
    cluster.register("b", _trace("b", me=400_000.0), eu_budget=4)
    res, reports = run_closed_loop(cluster, n_requests=3)

    srv = MultiTenantServer(policy="neu10")
    srv.register("a", _trace("a"), eu_budget=4)
    srv.register("b", _trace("b", me=400_000.0), eu_budget=4)
    res2, reports2 = srv.simulate(n_requests=3)

    assert res.makespan == pytest.approx(res2.makespan, rel=1e-9)
    assert [r.p95_ms for r in reports] == pytest.approx(
        [r.p95_ms for r in reports2], rel=1e-9)


def test_bare_cluster_registration_is_not_silently_misrouted():
    """A tenant registered on the CLUSTER after the session exists has
    no runtime; session calls must refuse it, not hit tenants[-1]."""
    sess = _session()
    a = sess.register("a", _trace("a"), eu_budget=4)
    stray = sess.cluster.register("stray", _trace("s"), eu_budget=2)
    with pytest.raises(ValueError, match="not attached"):
        sess.submit(stray)
    with pytest.raises(ValueError, match="not attached"):
        sess.latencies_ms(stray)
    # the aggregate report covers only attached tenants
    assert [r.name for r in sess.report()] == ["a"]


def test_run_requires_closed_loop_tenants():
    """Simulator.run() terminates on closed-loop completion even with
    an open-loop tenant attached, and refuses an all-open-loop setup."""
    from repro.core.simulator import Simulator, TenantSpec
    from repro.core.policies import get_policy

    cluster = NPUCluster(policy="neu10")
    ha = cluster.register("a", _trace("a"), eu_budget=4)
    hb = cluster.register("b", _trace("b"), eu_budget=2)
    compile_ = get_policy("neu10").compile_program
    sim = Simulator(
        [TenantSpec(compile_(ha.trace, DEFAULT_CORE), ha.vnpu, n_requests=2)],
        policy="neu10")
    sim.add_tenant(
        TenantSpec(compile_(hb.trace, DEFAULT_CORE), hb.vnpu),
        open_loop=True)
    res = sim.run()  # must not spin to max_events on the open tenant
    assert res.tenants[0].requests_done >= 2

    sim2 = Simulator((), policy="neu10")
    with pytest.raises(ValueError, match="closed-loop"):
        sim2.run()


def test_session_multi_core_cluster():
    """Multi-pNPU clusters now construct (PR 6 fabric): one live
    simulator per core, and tenants attach to the core their vNPU
    mapped on with resizes pinned there."""
    sess = ServingSession(NPUCluster(n_pnpus=2))
    assert len(sess.sims) == 2
    assert sess.sim is sess.sims[0]
    a = sess.register("a", _trace("a"), eu_budget=4)
    assert a.core_idx == sess.cluster.manager.core_index_of(a.vnpu)
    assert a.core_hint == a.core_idx
    sess.submit(a, at_s=0.001)
    sess.drain()
    assert sess.report(a)[0].requests_done == 1


def test_inject_guards():
    sess = _session()
    a = sess.register("a", _trace("a"), eu_budget=4)
    sess.submit(a, at_s=0.001)
    sess.drain()
    with pytest.raises(ValueError):
        sess.submit(a, at_s=sess.now_s - 0.001)  # arrival in the past
    sess.deregister(a)
    with pytest.raises(ValueError):
        sess.sim.inject_request(0, sess.sim.now)  # deregistered tenant
