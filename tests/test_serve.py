"""Serving: engine generation + the multi-tenant vNPU control plane."""
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.npu.workloads import get_workload
from repro.serve.engine import ServeEngine
from repro.serve.vserve import MultiTenantServer


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "musicgen-large",
                                  "zamba2-7b"])
def test_engine_generate(arch):
    cfg = SMOKES[arch]
    eng = ServeEngine(cfg, max_seq=64)
    B, S = 2, 16
    if cfg.family == "audio":
        prompt = np.random.randint(0, cfg.vocab_size,
                                   (B, cfg.n_codebooks, S))
    else:
        prompt = np.random.randint(0, cfg.vocab_size, (B, S))
    res = eng.generate(prompt, n_new=4)
    assert res.tokens.shape[-1] == 4
    assert res.tokens.min() >= 0 and res.tokens.max() < cfg.vocab_size
    # greedy decode is deterministic
    res2 = eng.generate(prompt, n_new=4)
    np.testing.assert_array_equal(res.tokens, res2.tokens)


def test_multitenant_server_end_to_end():
    srv = MultiTenantServer(policy="neu10")
    a = srv.register("bert", get_workload("BERT"), eu_budget=4)
    b = srv.register("dlrm", get_workload("DLRM"), eu_budget=4)
    # allocator gave BERT (ME-heavy) more MEs, DLRM more VEs
    assert a.allocation.n_me >= a.allocation.n_ve
    assert b.allocation.n_ve >= b.allocation.n_me
    res, reports = srv.simulate(n_requests=4)
    assert all(r.throughput_rps > 0 for r in reports)
    assert res.me_utilization() <= 1.0


def test_multitenant_policies_ordering():
    def run(policy):
        srv = MultiTenantServer(policy=policy)
        srv.register("rsnt", get_workload("RsNt"), eu_budget=4)
        srv.register("dlrm", get_workload("DLRM"), eu_budget=4)
        res, _ = srv.simulate(n_requests=4)
        return res

    neu = run("neu10")
    nh = run("neu10_nh")
    pmt = run("pmt")
    assert neu.total_throughput() >= nh.total_throughput()
    assert neu.total_throughput() > pmt.total_throughput()


def test_autoscale_to_slo():
    # neu10_nh: without harvesting the EU allocation determines the
    # latency (a solo tenant under neu10 harvests the whole core, so
    # scaling its own allocation would be a no-op)
    srv = MultiTenantServer(policy="neu10_nh")
    t = srv.register("enet", get_workload("ENet"), eu_budget=2,
                     slo_p95_ms=None)
    _, reports = srv.simulate(n_requests=3)
    base_p95 = reports[0].p95_ms
    # demand an SLO just under the 2-EU latency -> autoscaler must grow
    t.slo_p95_ms = base_p95 * 0.7
    reports = srv.autoscale_to_slo(n_requests=3, max_eus=8)
    assert t.eu_budget > 2
    assert reports[0].p95_ms < base_p95


def test_deregister_releases_resources():
    srv = MultiTenantServer(policy="neu10")
    a = srv.register("a", get_workload("MNIST"), eu_budget=4)
    srv.deregister(a)
    b = srv.register("b", get_workload("MNIST"), eu_budget=8)
    assert b.vnpu.config.n_eus <= 8
