"""Live KV-cache accounting in the simulator + serving session:
golden bit-identity of KV-disabled paths, deterministic eviction,
evict/swap-resume vs reject-restart semantics, admission rejection,
and the live-resize-below-occupancy regression (satellite fix)."""
import pytest

from repro.configs import SMOKES
from repro.core.mapper import ReconfigureError
from repro.core.vnpu import VNPUConfig
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.trace import kv_bytes_per_token, request_plan
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, ServingSession)

CFG = SMOKES["qwen2-0.5b"]
SEG = 64 * 1024
SMALL_CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)
WEIGHTS = CFG.param_count() * 2
WSEG = -(-WEIGHTS // SEG) * SEG          # weights rounded to segments


def _pressure_session(kv_policy, kv_segs=2, n=24, policy="neu10"):
    """Decode-heavy chat tenant on a pinned HBM allocation of
    weights + ``kv_segs`` segments (the fig_kv_pressure mix)."""
    cluster = NPUCluster(core=SMALL_CORE, policy=policy)
    sess = ServingSession(cluster)
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=96.0, max_len=256, seed=11),
        eu_budget=4, kv_policy=kv_policy,
        hbm_bytes=WSEG + kv_segs * SEG)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=200_000.0,
                                               n=n, seed=1))
    return sess, chat


# ----------------------------------------------------------------------
# golden regression: KV-disabled paths stay bit-identical to PR 4
# ----------------------------------------------------------------------
# Captured from the PR 4 tree (commit ab53ec8) on the fixed chat+doc
# open-loop scenario below: (requests_done, tokens, sum(latencies),
# sum(ttft), sum(tbt), me_work, ve_work) per tenant + final sim time,
# every float rounded to 1e-6 cycles. With kv_policy unset the live
# ledger must not perturb a single event.
PR4_GOLDEN = {
    ("neu10", "mono"): [
        (10, 172, 448650.934304, 43945.650228, 404705.284076,
         325189.632, 13954.6205),
        (4, 8, 179079.095223, 169251.578723, 9827.5165,
         335429.632, 119134.45),
        528547.261041],
    ("neu10", "chunk"): [
        (10, 172, 442058.384949, 44665.423776, 397392.961173,
         327383.04, 13983.545),
        (4, 8, 194385.140735, 184557.624235, 9827.5165,
         340464.298667, 119097.372667),
        527051.214984],
    ("neu10", "piggy"): [
        (10, 172, 466187.807442, 67657.236842, 398530.5706,
         329576.448, 14070.3185),
        (4, 8, 239267.041721, 227168.105221, 12098.9365,
         392812.214768, 125145.119426),
        528360.102535],
    ("v10", "mono"): [
        (10, 172, 1724289.056411, 215713.143703, 1508575.912709,
         272547.84, 7250.4325),
        (4, 8, 970507.582533, 674541.300675, 295966.281859,
         332852.224, 93907.5255),
        894171.994716],
    ("v10", "chunk"): [
        (10, 172, 1708611.259345, 186132.7604, 1522478.498946,
         274741.248, 7253.357),
        (4, 8, 861505.966915, 814682.242689, 46823.724225,
         340464.298667, 95286.706),
        939222.616563],
    ("v10", "piggy"): [
        (10, 172, 1887630.710117, 386990.981196, 1500639.72892,
         281705.472, 7294.3),
        (4, 8, 1006029.293673, 977476.542548, 28552.751125,
         392812.214768, 100800.61),
        993306.237289],
}
_ARMS = {"mono": {}, "chunk": {"prefill_chunk_tokens": 256},
         "piggy": {"iteration_token_budget": 160}}


def _golden_scenario(policy, **kw):
    cluster = NPUCluster(policy=policy)
    sess = ServingSession(cluster)
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=24.0, max_len=96, seed=11),
        eu_budget=4, **kw)
    doc = sess.register_generative("doc", CFG, prompt_len=1024,
                                   gen_lens=2, eu_budget=4, **kw)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=30_000.0, n=10,
                                               seed=1))
    sess.submit_arrivals(doc, PoissonArrivals(rate_rps=4_000.0, n=4,
                                              seed=2))
    sess.drain()
    out = []
    for h in (chat, doc):
        st = sess.sim.tenants[h.sim_idx].stats
        out.append((st.requests_done, st.tokens,
                    round(sum(st.latencies), 6), round(sum(st.ttft), 6),
                    round(sum(st.tbt), 6), round(st.me_work, 6),
                    round(st.ve_work, 6)))
    out.append(round(sess.sim.now, 6))
    return out


@pytest.mark.parametrize("policy,arm", sorted(PR4_GOLDEN))
def test_kv_disabled_paths_bit_identical_to_pr4(policy, arm):
    assert _golden_scenario(policy, **_ARMS[arm]) == PR4_GOLDEN[(policy,
                                                                 arm)]


# ----------------------------------------------------------------------
# determinism: same seed -> same evictions
# ----------------------------------------------------------------------
@pytest.mark.parametrize("kv_policy", ["evict", "reject"])
def test_open_loop_determinism_with_eviction(kv_policy):
    def run_once():
        sess, chat = _pressure_session(kv_policy)
        sess.drain()
        st = sess.sim.tenants[chat.sim_idx].stats
        return (st.latencies, st.ttft, st.tbt, st.tokens,
                st.kv_evictions, st.kv_swapins, st.kv_restarts,
                st.kv_swapped_bytes, st.kv_peak_segments)

    assert run_once() == run_once()


# ----------------------------------------------------------------------
# evict / reject semantics under pressure
# ----------------------------------------------------------------------
def test_evict_swap_resume_roundtrip_under_pressure():
    sess, chat = _pressure_session("evict")
    sess.drain()
    st = sess.sim.tenants[chat.sim_idx].stats
    led = chat.vnpu.kv_ledger
    assert st.kv_evictions >= 1 and st.kv_swapins >= 1   # full round trip
    assert st.kv_restarts == 0                  # evict mode never aborts
    assert st.kv_swapped_bytes > 0
    assert st.requests_done == 24               # nobody lost
    # ledger safety: the budget was really respected, and drained
    assert st.kv_peak_segments <= led.capacity // SEG
    assert led.in_use == 0 and led.entries == {}
    assert led.reserved == WEIGHTS              # weights stay resident
    # report surfaces the pressure counters
    rep = sess.report(chat)[0]
    assert rep.kv_evictions == st.kv_evictions
    assert rep.kv_swapins == st.kv_swapins
    assert rep.kv_peak_segments == st.kv_peak_segments


def test_reject_mode_restarts_victims():
    sess, chat = _pressure_session("reject")
    sess.drain()
    st = sess.sim.tenants[chat.sim_idx].stats
    assert st.kv_restarts >= 1 and st.kv_evictions >= 1
    assert st.kv_swapins == 0                   # reject never swaps
    assert st.requests_done == 24
    # a restarted request re-runs prefill but TTFT samples only once
    assert len(st.ttft) == 24
    assert chat.vnpu.kv_ledger.in_use == 0


def test_eviction_interacts_with_piggybacked_iterations():
    """KV accounting composes with the budgeted (SARATHI-SF) engine:
    slices charge their ingestion, riders charge growth, and pressure
    still resolves through eviction + resume."""
    cluster = NPUCluster(core=SMALL_CORE, policy="neu10")
    sess = ServingSession(cluster)
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=96.0, max_len=256, seed=11),
        eu_budget=4, kv_policy="evict", hbm_bytes=WSEG + 2 * SEG,
        iteration_token_budget=96)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=200_000.0,
                                               n=24, seed=1))
    sess.drain()
    st = sess.sim.tenants[chat.sim_idx].stats
    assert st.requests_done == 24
    assert st.piggyback_iterations >= 1         # the fused engine ran
    assert st.kv_evictions >= 1 and st.kv_swapins >= 1
    assert chat.vnpu.kv_ledger.in_use == 0


def test_admission_rejects_prompt_that_can_never_fit():
    """A prompt whose KV write exceeds the whole KV budget is dropped
    (counted, no deadlock) instead of wedging the tenant queue."""
    per = kv_bytes_per_token(CFG)
    kv_segs = 1
    # prompt KV > 1 segment (+ rounding slack) can never be admitted
    prompt = int((2 * SEG) // per)
    cluster = NPUCluster(core=SMALL_CORE, policy="neu10")
    sess = ServingSession(cluster)
    h = sess.register_generative(
        "big", CFG, prompt_len=prompt, gen_lens=4, eu_budget=4,
        kv_policy="evict", hbm_bytes=WSEG + kv_segs * SEG)
    sess.submit(h, at_s=0.0)
    sess.submit(h, at_s=0.0)
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.kv_rejected == 2
    assert st.requests_done == 0
    assert sess.sim.tenants[h.sim_idx].in_flight == 0


def test_kv_policy_requires_generative_plan():
    cluster = NPUCluster(policy="neu10")
    with pytest.raises(ValueError, match="kv_policy"):
        cluster.register_model(CFG, batch=1, seq=128, eu_budget=2,
                               kv_policy="evict")


def test_mid_run_deregister_releases_ledger():
    sess, chat = _pressure_session("evict")
    sess.run_until(2e-4)                        # mid-pressure
    led = chat.vnpu.kv_ledger
    sess.deregister(chat)
    assert sess.drain() >= 0.0                  # no orphaned state
    assert led.in_use == 0 and led.entries == {}


# ----------------------------------------------------------------------
# satellite fix: live resize must respect the ledger
# ----------------------------------------------------------------------
def test_reconfigure_below_live_occupancy_rejected_and_restored():
    """The vNPU manager refuses to shrink HBM segments out from under
    live KV: ReconfigureError carries a restored vNPU with the ledger
    (entries, reserve) intact."""
    cluster = NPUCluster(core=SMALL_CORE, policy="neu10")
    v = cluster.manager.create(
        VNPUConfig(2, 2, hbm_bytes=8 * SEG), name="t")
    v.kv_ledger.reserve(2 * SEG)
    assert v.kv_ledger.alloc(0, 3 * SEG)
    with pytest.raises(ReconfigureError) as ei:
        cluster.manager.reconfigure(
            v, VNPUConfig(2, 2, hbm_bytes=4 * SEG))
    restored = ei.value.restored
    assert restored.kv_ledger.in_use == 3 * SEG
    assert restored.kv_ledger.reserved == 2 * SEG
    assert restored.segments.hbm_bytes == 8 * SEG
    # a resize that CAN hold the occupancy migrates the ledger
    grown = cluster.manager.reconfigure(
        restored, VNPUConfig(2, 2, hbm_bytes=6 * SEG))
    assert grown.kv_ledger.in_use == 3 * SEG
    assert grown.kv_ledger.capacity == 6 * SEG


def test_live_resize_keeps_segments_above_occupancy():
    """Session-level regression for the latent bug: a mid-run resize
    under live KV occupancy must never leave the tenant with fewer
    HBM bytes than the ledger holds — the ask is floored at the live
    occupancy and serving continues across the resize."""
    sess, chat = _pressure_session("evict", kv_segs=4)
    sess.run_until(2e-4)                        # KV is live now
    led = chat.vnpu.kv_ledger
    assert led.in_use > 0
    for eu in (6, 2, 4):                        # grow, shrink, restore
        try:
            sess.resize(chat, eu)
        except ReconfigureError:
            pass                                # reject is legal...
        led = chat.vnpu.kv_ledger
        # ...silently shrinking below the live occupancy is not, and
        # the migrated ledger stays conservation-exact
        assert led.capacity >= led.reserved + led.in_use
        assert led.reserved == WEIGHTS
        assert led.in_use == sum(led.entries.values())
    sess.drain()
    st = sess.sim.tenants[chat.sim_idx].stats
    assert st.requests_done == 24               # nothing was corrupted
    assert chat.vnpu.kv_ledger.in_use == 0


# ----------------------------------------------------------------------
# review regressions: cumulative admission, pinned resize, loss report
# ----------------------------------------------------------------------
def _never_fit_prompt(kv_segs=2):
    """Prompt whose TOTAL KV is ~2x the KV budget while every 64-token
    chunk fits individually — the mid-prefill wedge shape."""
    per = kv_bytes_per_token(CFG)
    budget = kv_segs * SEG + (WSEG - WEIGHTS)     # bytes beyond weights
    return 64 * max(int(2 * budget / per) // 64, 2)


@pytest.mark.parametrize("kw", [{"prefill_chunk_tokens": 64},
                                {"iteration_token_budget": 64},
                                {}])
def test_cumulative_prompt_kv_rejected_not_wedged(kw):
    """A request whose chunks/slices fit one at a time but whose whole
    prompt can never fit the KV budget is REJECTED at admission —
    previously it wedged mid-prefill forever, holding partial KV."""
    cluster = NPUCluster(core=SMALL_CORE, policy="neu10")
    sess = ServingSession(cluster)
    h = sess.register_generative(
        "big", CFG, prompt_len=_never_fit_prompt(), gen_lens=4,
        eu_budget=4, kv_policy="evict", hbm_bytes=WSEG + 2 * SEG, **kw)
    sess.submit(h, at_s=0.0)
    sess.submit(h, at_s=0.0)
    sess.drain()
    rt = sess.sim.tenants[h.sim_idx]
    assert rt.stats.kv_rejected == 2
    assert rt.stats.requests_done == 0
    assert rt.in_flight == 0                      # nothing wedged
    assert h.vnpu.kv_ledger.in_use == 0           # no partial-KV leak
    # and the loss is visible at the serving layer
    assert sess.report(h)[0].kv_rejected == 2


def test_resize_keeps_registration_hbm_pin():
    """A resize must keep honoring the hbm_bytes pin the tenant
    registered with — re-inflating to the footprint estimate would
    silently dissolve the KV pressure the operator configured."""
    sess, chat = _pressure_session("evict", kv_segs=2)
    pinned = chat.vnpu.kv_ledger.capacity
    sess.run_until(1e-4)
    sess.resize(chat, 6)
    led = chat.vnpu.kv_ledger
    # capacity may only grow by the occupancy floor, never jump to
    # the (orders-of-magnitude larger) footprint estimate
    floor = -(-(led.reserved + led.in_use) // SEG) * SEG
    assert led.capacity <= max(pinned, floor)
    sess.drain()
    assert sess.sim.tenants[chat.sim_idx].stats.requests_done == 24
    # pressure still exists after the resize round trip
    assert sess.sim.tenants[chat.sim_idx].stats.kv_evictions >= 1
