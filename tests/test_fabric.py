"""Cluster fabric: topology pricing, topology-aware placement, and
the cross-core prefill->decode migration protocol.

Property tests prove the satellite invariant: cross-core migrations
CONSERVE total physical segments across every ledger involved, never
double-free, and a destination-pressure reject leaves both ledgers
untouched. Session-level tests replay deterministic recipes with
real per-core simulators driven in lockstep."""
import math

import pytest

from hypothesis_compat import given, settings, st
from repro.configs.qwen2_0_5b import SMOKE as CHAT
from repro.core.allocator import place_phase_pair
from repro.core.fabric import (FabricLink, FabricTopology, Placement,
                               random_phase_pair)
from repro.core.vnpu import KVLedger, KVLedgerError
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import (NPUCluster, PoissonArrivals, SLOAutoscaler,
                                 ServingSession)

SEG = 64 * 1024
# shrunken core so KV pressure is reachable with tiny prompts
CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)


def _fabric_session(topo, **kw):
    return ServingSession(
        NPUCluster(core=CORE, policy="neu10", topology=topo), **kw)


# ----------------------------------------------------------------------
# topology
# ----------------------------------------------------------------------
def test_topology_shapes_and_hops():
    ring = FabricTopology.ring(6)
    assert ring.kind == "ring" and ring.n_cores == 6
    assert ring.hops(0, 3) == 3          # around either way
    assert ring.hops(0, 5) == 1          # wrap link
    assert ring.neighbors(0) == (1, 5)

    mesh = FabricTopology.mesh(4)        # 2x2 grid
    assert mesh.hops(0, 3) == 2          # diagonal = 2 manhattan hops
    assert mesh.neighbors(0) == (1, 2)

    line = FabricTopology.mesh(3)        # prime n degenerates to a line
    assert line.hops(0, 2) == 2

    fc = FabricTopology.fully_connected(5)
    assert all(fc.hops(a, b) == 1
               for a in range(5) for b in range(5) if a != b)

    single = FabricTopology.single()
    assert single.transfer_cycles(0, 0, 1e12) == 0.0


def test_topology_rejects_bad_links():
    with pytest.raises(ValueError, match="bad link"):
        FabricTopology(2, {(0, 2): FabricLink()})
    with pytest.raises(ValueError, match="bad link"):
        FabricTopology(2, {(0, 0): FabricLink()})
    with pytest.raises(ValueError, match="bandwidth"):
        FabricLink(bandwidth=0.0)


def test_transfer_pricing_is_store_and_forward():
    link = FabricLink(bandwidth=10.0, latency=100.0)
    ring = FabricTopology.ring(4, link)
    # 2 hops, each paying latency + nbytes/bandwidth
    assert ring.transfer_cycles(0, 2, 1000) == pytest.approx(
        2 * (100.0 + 1000 / 10.0))
    # symmetric, and zero for src == dst
    assert ring.transfer_cycles(2, 0, 1000) == ring.transfer_cycles(
        0, 2, 1000)
    assert ring.transfer_cycles(1, 1, 1000) == 0.0
    # unreachable pair: infinite hop count, unpriceable path
    sparse = FabricTopology(4, {(0, 1): link})
    assert sparse.hops(0, 3) == math.inf
    with pytest.raises(ValueError, match="not connected"):
        sparse.path_links(0, 3)


def test_place_phase_pair_prefers_neighbors_then_load():
    ring = FabricTopology.ring(4)
    a, b = place_phase_pair(ring, kv_bytes=SEG)
    assert ring.hops(a, b) == 1
    # cores 0/1 are loaded: the idle neighboring pair wins
    a, b = place_phase_pair(ring, loads=[5.0, 5.0, 0.0, 0.0],
                            kv_bytes=SEG)
    assert (a, b) == (2, 3)
    # disconnected cores are never paired
    sparse = FabricTopology(4, {(0, 1): FabricLink()})
    assert set(place_phase_pair(sparse, kv_bytes=SEG)) == {0, 1}
    with pytest.raises(ValueError, match="no connected"):
        place_phase_pair(FabricTopology(2, {}), kv_bytes=1.0)
    with pytest.raises(ValueError, match="loads"):
        place_phase_pair(ring, loads=[0.0], kv_bytes=1.0)


def test_random_phase_pair_is_seeded_and_distinct():
    topo = FabricTopology.ring(8)
    pairs = {random_phase_pair(topo, seed=s) for s in range(16)}
    assert all(a != b for a, b in pairs)
    assert len(pairs) > 1                       # actually random
    assert (random_phase_pair(topo, seed=3)
            == random_phase_pair(topo, seed=3))  # deterministic


def test_placement_validates_strategy():
    with pytest.raises(ValueError, match="strategy"):
        Placement(strategy="greedy")


# ----------------------------------------------------------------------
# ledger migration protocol (satellite: conservation property)
# ----------------------------------------------------------------------
def test_migrate_entry_reject_leaves_both_untouched():
    src = KVLedger(8 * SEG, SEG)
    dst = KVLedger(2 * SEG, SEG)
    assert src.alloc(1, 4 * SEG)
    # destination pressure: -1, NOTHING moved on either side
    assert src.migrate_entry_to(dst, 1) == -1
    assert src.bytes_of(1) == 4 * SEG and src.in_use == 4 * SEG
    assert dst.in_use == 0 and dst.entries == {}
    # roomy destination: all-or-nothing move under a fresh rid
    dst2 = KVLedger(8 * SEG, SEG)
    assert src.migrate_entry_to(dst2, 1, dst_rid=9) == 4 * SEG
    assert src.in_use == 0 and dst2.bytes_of(9) == 4 * SEG
    # the source entry is gone: a second migrate is a double-free
    with pytest.raises(KVLedgerError, match="migrate"):
        src.migrate_entry_to(dst2, 1)


_MIG_OPS = st.lists(
    st.tuples(
        st.sampled_from(["alloc", "free", "migrate", "acq", "rel"]),
        st.integers(min_value=0, max_value=2),   # src ledger
        st.integers(min_value=0, max_value=2),   # dst ledger (migrate)
        st.integers(min_value=0, max_value=5),   # request id / prefix key
        st.integers(min_value=0, max_value=3 * SEG),  # bytes (alloc)
    ),
    max_size=60,
)

# a prefix key's byte size is a pure function of the key (the hash
# names the exact token range), so every acquire of the same key asks
# for the same bytes — mismatches are a separate KVLedgerError test
def _key_bytes(key: int) -> int:
    return (key + 1) * SEG // 2


@given(ops=_MIG_OPS,
       caps=st.lists(st.integers(min_value=1, max_value=10),
                     min_size=3, max_size=3))
@settings(max_examples=150, deadline=None)
def test_cross_ledger_migration_conserves_segments(ops, caps):
    """Any interleaving of alloc / free / cross-ledger migrate /
    shared-prefix acquire / release against three ledgers: per-ledger
    bookkeeping (private AND refcounted pools) matches a mirror model,
    a reject changes nothing, and the cluster-wide total is conserved
    across every successful migration (no leak, no double-count)."""
    pytest.importorskip("hypothesis")
    leds = [KVLedger(c * SEG, SEG) for c in caps]
    mirrors = [dict() for _ in leds]
    shmirrors = [dict() for _ in leds]       # key -> [bytes, refs]
    for op, i, j, rid, n in ops:
        src, msrc, mshr = leds[i], mirrors[i], shmirrors[i]
        dst, mdst = leds[j], mirrors[j]
        if op == "alloc":
            if src.alloc(rid, n):
                msrc[rid] = msrc.get(rid, 0) + n
        elif op == "free":
            if rid in msrc:
                assert src.free(rid) == msrc.pop(rid)
            else:
                with pytest.raises(KVLedgerError):
                    src.free(rid)
        elif op == "acq":                    # shared-prefix arm
            pb = _key_bytes(rid)
            ok = src.acquire_shared(rid, pb)
            if rid in mshr:
                assert ok                    # resident: refcount bump
                mshr[rid][1] += 1
            elif ok:
                mshr[rid] = [pb, 1]          # all-or-nothing first fill
            else:                            # no room: nothing changed
                assert pb > src.available
        elif op == "rel":
            if rid in mshr:
                mshr[rid][1] -= 1
                freed = src.release_shared(rid)
                if mshr[rid][1] == 0:
                    assert freed == mshr.pop(rid)[0]
                else:
                    assert freed == 0        # not the last holder
            else:
                with pytest.raises(KVLedgerError):
                    src.release_shared(rid)  # refcount underflow
        else:  # migrate
            if rid not in msrc:
                with pytest.raises(KVLedgerError):
                    src.migrate_entry_to(dst, rid)
                continue
            before = sum(led.in_use + led.shared_in_use for led in leds)
            held = msrc[rid]
            moved = src.migrate_entry_to(dst, rid)
            if i == j:                       # same ledger: no-op
                assert moved == held
            elif moved == -1:                # destination pressure
                assert held > (dst.capacity - dst.reserved - dst.in_use
                               - dst.shared_in_use)
                assert src.bytes_of(rid) == held
            else:
                assert moved == held
                mdst[rid] = mdst.get(rid, 0) + held
                del msrc[rid]
            # conservation: a migration moves bytes, never mints them
            assert (sum(led.in_use + led.shared_in_use for led in leds)
                    == before)
        for led, mir, shm in zip(leds, mirrors, shmirrors):
            assert (led.reserved + led.in_use
                    + led.shared_in_use <= led.capacity)
            assert led.in_use == sum(mir.values())
            assert led.entries == mir
            assert led.shared == shm
            assert led.shared_in_use == sum(b for b, _ in shm.values())
            # refcounts never go negative (present => >= 1)
            assert all(refs >= 1 for _, refs in shm.values())


# ----------------------------------------------------------------------
# session-level migration (per-core simulators in lockstep)
# ----------------------------------------------------------------------
def test_fabric_migration_round_trip():
    """Every request prefills on core A, migrates its KV over the
    fabric, decodes on core B, and both ledgers drain to zero."""
    sess = _fabric_session(FabricTopology.mesh(4))
    ft = sess.register_generative(
        "chat", CHAT, prompt_len=128, gen_lens=8, eu_budget=4,
        placement=Placement(), kv_policy="evict", hbm_bytes=256 * SEG)
    assert ft.prefill_core != ft.decode_core
    assert ft.hops == 1                 # topo-aware: neighboring cores
    assert ft.prefill.core_idx == ft.prefill_core
    assert ft.decode.core_idx == ft.decode_core
    sess.submit_arrivals(ft, PoissonArrivals(rate_rps=200.0, n=20, seed=1))
    sess.drain()
    r = sess.report(ft)[0]
    assert r.requests_done == 20
    assert r.kv_migrations == 20        # every hand-off crossed cores
    assert r.cross_core_hops == 20
    assert r.kv_migrated_bytes > 0
    assert r.kv_migration_rejects == 0
    assert r.queued == 0 and ft.in_transit == 0
    # zero KV leak on either side
    assert ft.prefill.vnpu.kv_ledger.in_use == 0
    assert ft.decode.vnpu.kv_ledger.in_use == 0
    # the default listing shows ONE merged row named after the pair
    assert [x.name for x in sess.report()] == ["chat"]
    assert len(sess.latencies_ms(ft)) == 20


def test_fabric_reject_falls_back_to_local_decode():
    """Destination pressure: a squeezed decode-side ledger rejects
    some hand-offs; those requests decode locally on the prefill core
    and STILL complete, with zero leak on both sides."""
    # size the decode pool to hold weights + ~1.2 prompts of KV
    from repro.npu.trace import request_plan
    probe = request_plan(CHAT, 1, 256, 1, core=CORE)
    dec_hbm = -(-int(probe.weight_bytes
                     + 1.2 * probe.kv_prompt_bytes) // SEG) * SEG
    sess = _fabric_session(FabricTopology.ring(4))
    ft = sess.register_generative(
        "chat", CHAT, prompt_len=256, gen_lens=32, eu_budget=4,
        placement=Placement(decode_hbm_bytes=dec_hbm),
        kv_policy="evict", hbm_bytes=512 * SEG)
    sess.submit_arrivals(ft, PoissonArrivals(rate_rps=3000.0, n=16, seed=3))
    sess.drain()
    r = sess.report(ft)[0]
    assert r.requests_done == 16        # rejects completed locally
    assert r.kv_migration_rejects >= 1
    assert r.kv_migrations >= 1
    assert r.kv_migrations + r.kv_migration_rejects == 16
    assert ft.prefill.vnpu.kv_ledger.in_use == 0
    assert ft.decode.vnpu.kv_ledger.in_use == 0
    # capacity was never breached while squeezing (per-core invariant)
    led = ft.decode.vnpu.kv_ledger
    assert led.peak_bytes <= led.capacity


def test_fabric_transfer_delay_prices_into_latency():
    """A slower link makes the same workload's e2e tail strictly
    worse — the hand-off is PRICED, not teleported."""
    def p95_with(link):
        sess = _fabric_session(FabricTopology.ring(2, link))
        ft = sess.register_generative(
            "chat", CHAT, prompt_len=64, gen_lens=4, eu_budget=4,
            placement=Placement(prefill_core=0, decode_core=1),
            kv_policy="evict", hbm_bytes=256 * SEG)
        sess.submit_arrivals(ft, PoissonArrivals(rate_rps=500.0, n=8,
                                                 seed=2))
        sess.drain()
        r = sess.report(ft)[0]
        assert r.requests_done == 8 and r.kv_migrations == 8
        return r.p95_ms

    fast = p95_with(FabricLink(bandwidth=1e9, latency=0.0))
    slow = p95_with(FabricLink(bandwidth=4.0, latency=2_000_000.0))
    assert slow > fast * 1.5


def test_fabric_policy_hook_fires_on_landing():
    sess = _fabric_session(FabricTopology.ring(2))
    ft = sess.register_generative(
        "chat", CHAT, prompt_len=64, gen_lens=4, eu_budget=4,
        placement=Placement(prefill_core=0, decode_core=1),
        kv_policy="evict", hbm_bytes=256 * SEG)
    landed = []
    pol = sess.sims[ft.decode.core_idx].policy_obj
    pol.on_request_migrated = (
        lambda sim, rt, req: landed.append(req.rid))
    sess.submit(ft, at_s=0.0)
    sess.drain()
    assert len(landed) == 1
    assert sess.report(ft)[0].requests_done == 1


def test_fabric_deregister_removes_both_pools():
    sess = _fabric_session(FabricTopology.mesh(4))
    ft = sess.register_generative(
        "chat", CHAT, prompt_len=64, gen_lens=4, eu_budget=4,
        placement=Placement(), kv_policy="evict", hbm_bytes=256 * SEG)
    assert ft.prefill in sess.cluster.tenants
    sess.deregister(ft)
    assert ft.prefill not in sess.cluster.tenants
    assert ft.decode not in sess.cluster.tenants
    assert ft not in sess.fabric_tenants
    # the engines freed: a full-size tenant fits again on either core
    sess.register_generative("next", CHAT, prompt_len=64, eu_budget=4)


def test_fabric_autoscaler_grows_prefill_side_only():
    """Satellite: the per-core SLOAutoscaler judges TTFT on the
    prefill pool and grows THAT vNPU on THAT core — the decode pool
    (huge TBT SLO) holds its size."""
    auto = SLOAutoscaler(step_eus=2, max_eus=6, window=8, min_samples=2)
    sess = _fabric_session(FabricTopology.mesh(4), autoscaler=auto)
    ft = sess.register_generative(
        "chat", CHAT, prompt_len=256, gen_lens=4, eu_budget=4,
        placement=Placement(),
        slo_ttft_ms=1e-6,       # unreachable: every window violates
        slo_tbt_ms=1e9)         # never violates
    assert ft.prefill.slo_ttft_ms == 1e-6
    assert ft.decode.slo_ttft_ms is None    # phase SLOs split per pool
    assert ft.decode.slo_tbt_ms == 1e9
    pre0, dec0 = ft.prefill.eu_budget, ft.decode.eu_budget
    sess.submit_arrivals(ft, PoissonArrivals(rate_rps=500.0, n=12, seed=0))
    t = 0.0
    for _ in range(6):
        t += 0.01
        sess.run_until(t)
    sess.drain()
    assert ft.prefill.eu_budget > pre0      # TTFT violation grew it
    assert ft.decode.eu_budget == dec0      # decode pool untouched
    # the growth stayed on the prefill core (core_hint pin)
    assert (sess.cluster.manager.core_index_of(ft.prefill.vnpu)
            == ft.prefill_core)
    assert sess.report(ft)[0].requests_done == 12
