"""Live KV-cache HBM ledger: unit contract + property-based
invariants (never exceed capacity, exact frees, conservation across
arbitrary op sequences and mid-run tenant churn / resizes)."""
import pytest
from hypothesis_compat import given, settings, st

from repro.core.mapper import ReconfigureError, VNPUManager
from repro.core.vnpu import KVLedger, KVLedgerError, VNPUConfig
from repro.npu.hw_config import DEFAULT_CORE

SEG = 64 * 1024


# ----------------------------------------------------------------------
# unit contract
# ----------------------------------------------------------------------
def test_alloc_grow_free_roundtrip():
    led = KVLedger(10 * SEG, SEG)
    assert led.alloc(1, 3 * SEG)
    assert led.alloc(1, SEG)            # grow merges into the entry
    assert led.bytes_of(1) == 4 * SEG
    assert led.in_use == 4 * SEG
    assert led.used_segments == 4
    assert led.free(1) == 4 * SEG
    assert led.in_use == 0 and led.entries == {}


def test_alloc_is_all_or_nothing():
    led = KVLedger(2 * SEG, SEG)
    assert led.alloc(1, SEG)
    assert not led.alloc(2, 2 * SEG)    # would exceed: refused...
    assert led.bytes_of(2) == 0         # ...and nothing changed
    assert led.in_use == SEG
    assert led.alloc(2, SEG)            # exact fit is fine
    assert not led.fits(1)


def test_double_free_raises_exactly():
    led = KVLedger(4 * SEG, SEG)
    led.alloc(7, SEG)
    led.free(7)
    with pytest.raises(KVLedgerError, match="already-freed"):
        led.free(7)
    assert led.release(7) == 0          # lenient teardown variant


def test_reserve_respects_live_allocations():
    led = KVLedger(4 * SEG, SEG)
    led.reserve(2 * SEG)
    assert led.available == 2 * SEG
    led.alloc(1, 2 * SEG)
    with pytest.raises(KVLedgerError, match="reserve"):
        led.reserve(3 * SEG)
    led.clear()                          # per-request state only
    assert led.reserved == 2 * SEG and led.in_use == 0


def test_peaks_are_monotone_and_segment_rounded():
    led = KVLedger(10 * SEG, SEG)
    led.reserve(SEG // 2)
    led.alloc(1, SEG)                    # occupancy 1.5 seg -> 2 segments
    assert led.peak_segments == 2
    led.free(1)
    assert led.peak_segments == 2        # peaks never decay
    assert led.peak_bytes == SEG // 2 + SEG


def test_migrate_carries_state_and_rejects_shrink_below_live():
    a = KVLedger(8 * SEG, SEG, reserved_bytes=2 * SEG)
    a.alloc(1, 3 * SEG)
    b = KVLedger(6 * SEG, SEG)
    b.migrate_from(a)
    assert b.reserved == 2 * SEG and b.bytes_of(1) == 3 * SEG
    small = KVLedger(4 * SEG, SEG)
    with pytest.raises(KVLedgerError, match="exceeds the resized"):
        small.migrate_from(a)
    assert small.in_use == 0             # failed migrate changed nothing


def test_migrate_entry_unknown_rid_raises_both_untouched():
    src, dst = KVLedger(8 * SEG, SEG), KVLedger(8 * SEG, SEG)
    src.alloc(1, 2 * SEG)
    with pytest.raises(KVLedgerError, match="unknown"):
        src.migrate_entry_to(dst, 99)
    assert src.in_use == 2 * SEG and dst.in_use == 0


def test_migrate_entry_dst_pressure_rejects_both_untouched():
    src, dst = KVLedger(8 * SEG, SEG), KVLedger(2 * SEG, SEG)
    src.alloc(1, 3 * SEG)
    dst.alloc(7, SEG)
    assert src.migrate_entry_to(dst, 1) == -1    # 3 segs into 1 free
    assert src.in_use == 3 * SEG and src.bytes_of(1) == 3 * SEG
    assert dst.in_use == SEG                     # partial-failure: no
    assert dst.bytes_of(1) == 0                  # half-charged entry


def test_migrate_entry_dst_rid_collision_raises_both_untouched():
    src, dst = KVLedger(8 * SEG, SEG), KVLedger(8 * SEG, SEG)
    src.alloc(1, 2 * SEG)
    dst.alloc(1, SEG)
    with pytest.raises(KVLedgerError, match="already live"):
        src.migrate_entry_to(dst, 1)             # silent merge refused
    assert src.bytes_of(1) == 2 * SEG and dst.bytes_of(1) == SEG
    # an explicit non-colliding destination rid works
    assert src.migrate_entry_to(dst, 1, dst_rid=2) == 2 * SEG
    assert src.in_use == 0 and dst.bytes_of(2) == 2 * SEG


def test_migrate_entry_same_ledger_is_a_noop():
    led = KVLedger(4 * SEG, SEG)
    led.alloc(1, SEG)
    assert led.migrate_entry_to(led, 1) == SEG
    assert led.bytes_of(1) == SEG and led.in_use == SEG


def test_migrate_from_failure_leaves_source_fully_usable():
    a = KVLedger(8 * SEG, SEG, reserved_bytes=SEG)
    a.alloc(1, 2 * SEG)
    a.acquire_shared(5, SEG)
    small = KVLedger(3 * SEG, SEG)
    with pytest.raises(KVLedgerError, match="exceeds the resized"):
        small.migrate_from(a)                    # 4 live segs into 3
    # the failed rollover mutated NEITHER side: source still serves
    assert small.in_use == 0 and small.shared_in_use == 0
    assert a.bytes_of(1) == 2 * SEG and a.shared_refs(5) == 1
    assert a.alloc(2, SEG)                       # and still allocates
    ok = KVLedger(8 * SEG, SEG)
    ok.migrate_from(a)
    assert ok.occupancy == a.occupancy           # retry lands exactly


# ----------------------------------------------------------------------
# property: arbitrary op sequences vs an independent mirror model
# ----------------------------------------------------------------------
_OPS = st.lists(
    st.tuples(st.sampled_from(["alloc", "grow", "free", "clear", "resize"]),
              st.integers(0, 5),            # request id
              st.integers(0, 3 * SEG)),     # bytes / new capacity scale
    max_size=60)


@given(ops=_OPS, cap_segs=st.integers(1, 12), reserved=st.integers(0, SEG))
@settings(max_examples=150, deadline=None)
def test_ledger_invariants_under_arbitrary_sequences(ops, cap_segs,
                                                     reserved):
    """Whatever the arrival/finish/evict/resize interleaving, the
    ledger never exceeds capacity, frees are exact (no leak, no
    double-free), and occupancy equals the sum of live entries."""
    pytest.importorskip("hypothesis")
    led = KVLedger(cap_segs * SEG, SEG)
    if reserved + led.in_use <= led.capacity:
        led.reserve(reserved)
    mirror = {}
    for op, rid, n in ops:
        if op in ("alloc", "grow"):
            before = led.bytes_of(rid)
            ok = led.alloc(rid, n)
            expect_ok = n <= led.capacity - led.reserved - sum(
                mirror.values())
            if n > 0:
                assert ok == expect_ok
            if ok:
                mirror[rid] = mirror.get(rid, 0) + n
                assert led.bytes_of(rid) == before + n
            else:
                assert led.bytes_of(rid) == before   # all-or-nothing
        elif op == "free":
            if rid in mirror:
                assert led.free(rid) == mirror.pop(rid)
            else:
                with pytest.raises(KVLedgerError):
                    led.free(rid)
        elif op == "clear":
            led.clear()
            mirror.clear()
        else:  # resize: migrate into a new-capacity ledger
            new = KVLedger(max(n, SEG), SEG)
            need = led.reserved + led.in_use
            if need <= new.capacity:
                new.migrate_from(led)
                led = new
            else:
                with pytest.raises(KVLedgerError):
                    new.migrate_from(led)
        # the three invariants, after every single op (peaks are
        # historical telemetry: monotone, dominating live occupancy —
        # a shrink-resize may leave them above the NEW capacity)
        assert led.reserved + led.in_use <= led.capacity
        assert led.in_use == sum(mirror.values()) == sum(
            led.entries.values())
        assert led.peak_bytes >= led.reserved + led.in_use
    drained = led.clear()
    assert drained == sum(mirror.values())
    assert led.in_use == 0


# ----------------------------------------------------------------------
# property: manager-level churn conserves physical segments
# ----------------------------------------------------------------------
@given(script=st.lists(st.tuples(st.integers(0, 3),     # slot
                                 st.integers(1, 4),     # hbm segments
                                 st.integers(0, 2)),    # action
                       max_size=24))
@settings(max_examples=60, deadline=None)
def test_manager_churn_conserves_segments(script):
    """Create / KV-fill / destroy / reconfigure vNPUs in arbitrary
    order: every core's free+owned HBM segments stay conserved, each
    ledger's capacity tracks its vNPU's segment allocation, and a
    full teardown returns every segment."""
    pytest.importorskip("hypothesis")
    mgr = VNPUManager(core=DEFAULT_CORE)
    total = DEFAULT_CORE.hbm_bytes // DEFAULT_CORE.hbm_segment
    slots = {}
    for slot, n_hbm, action in script:
        v = slots.get(slot)
        if action == 0 and v is None:            # create + some live KV
            try:
                v = mgr.create(VNPUConfig(
                    1, 1, hbm_bytes=n_hbm * DEFAULT_CORE.hbm_segment))
            except RuntimeError:
                continue
            v.kv_ledger.alloc(0, DEFAULT_CORE.hbm_segment // 2)
            slots[slot] = v
        elif action == 1 and v is not None:      # destroy
            mgr.destroy(v)
            del slots[slot]
        elif action == 2 and v is not None:      # reconfigure (resize)
            try:
                slots[slot] = mgr.reconfigure(v, VNPUConfig(
                    1, 1, hbm_bytes=n_hbm * DEFAULT_CORE.hbm_segment))
            except ReconfigureError as exc:
                slots[slot] = exc.restored       # handle stays valid
        owned = sum(len(v.segments.hbm_segments) for v in slots.values())
        free = sum(len(cs.free_hbm_segs) for cs in mgr.cores)
        assert owned + free == total             # conservation
        for v in slots.values():
            assert v.kv_ledger.capacity == v.segments.hbm_bytes
            assert v.kv_ledger.in_use == DEFAULT_CORE.hbm_segment // 2
    for v in list(slots.values()):
        mgr.destroy(v)
    assert sum(len(cs.free_hbm_segs) for cs in mgr.cores) == total
