"""Incremental dirty-set scheduling core: result identity against the
reference and fast-path engines (per policy, on the cohort-heavy
config, under KV pressure, and under randomized mid-run churn), plus
event-heap hygiene (lazy deletion stays bounded; compaction is
result-invariant) and the cluster event heap's idle-core migration
wake-up."""
import math

import pytest

from hypothesis_compat import given, settings, st
from repro.configs.qwen2_0_5b import SMOKE as CHAT
from repro.core.compiler import compile_neuisa, compile_vliw
from repro.core.fabric import FabricTopology, Placement
from repro.core.mapper import ReconfigureError, VNPUManager
from repro.core.simulator import Simulator, TenantSpec
from repro.core.vnpu import VNPUConfig
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig
from repro.npu.workloads import get_workload
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, ServingSession)

POLICIES = ("pmt", "v10", "neu10_nh", "neu10")

SEG = 64 * 1024
KV_CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)


def _pair_specs(policy, core, me_ve=(2, 2), n_requests=4,
                names=("BERT", "ENet")):
    mgr = VNPUManager(core=core)
    spatial = policy.startswith("neu10")
    specs = []
    for name in names:
        v = mgr.create(VNPUConfig(*me_ve, hbm_bytes=1 << 30),
                       mapping="spatial" if spatial else "temporal")
        tr = get_workload(name, core)
        prog = (compile_neuisa(tr, core) if spatial
                else compile_vliw(tr, core))
        specs.append(TenantSpec(prog, v, n_requests))
    return specs


def _three_way(specs, policy, core):
    """ref (fast_path off) / fast (PR-4) / inc (dirty-set core)."""
    ref = Simulator(specs, policy=policy, core=core,
                    fast_path=False).run()
    fast = Simulator(specs, policy=policy, core=core,
                     incremental=False).run()
    inc = Simulator(specs, policy=policy, core=core,
                    incremental=True).run()
    return ref, fast, inc


# ----------------------------------------------------------------------
# bit-identity pins (the goldens every earlier PR validated stay exact)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
def test_incremental_identical_per_policy(policy):
    """Each registry policy — ported (neu10) or falling back to its
    full schedule pass — produces the SAME SimResult through the
    incremental dispatch core."""
    core = DEFAULT_CORE
    specs = _pair_specs(policy, core)
    ref, fast, inc = _three_way(specs, policy, core)
    assert inc == fast == ref


def test_incremental_identical_on_cohort_config():
    """The 8ME/8VE half-split sweep is the cohort-heavy load (runs of
    identical non-contender chunks dispatched under one completion
    event) — exactness there pins the batched completion path."""
    core = NPUCoreConfig(n_me=8, n_ve=8)
    specs = _pair_specs("neu10", core, me_ve=(4, 4), n_requests=6)
    ref, fast, inc = _three_way(specs, "neu10", core)
    assert inc == fast == ref


_WSEG = -(-(CHAT.param_count() * 2) // SEG) * SEG   # weights, rounded


def _kv_pressure_result(incremental):
    """Decode-heavy open-loop burst on a weights + 2-segment HBM pin:
    concurrent decodes overflow the ledger, forcing evict/swap-resume
    round trips (the fig_kv_pressure mix)."""
    sess = ServingSession(NPUCluster(core=KV_CORE, policy="neu10"),
                          incremental=incremental)
    chat = sess.register_generative(
        "chat", CHAT, prompt_len=128,
        gen_lens=GenLenDistribution(mean=96.0, max_len=256, seed=11),
        eu_budget=4, kv_policy="evict", hbm_bytes=_WSEG + 2 * SEG)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=200_000.0,
                                               n=24, seed=1))
    sess.drain()
    return sess.sims[0].result()


def test_incremental_identical_under_kv_pressure():
    """Live KV accounting (evictions, swap-in resumes) drives the
    simulator down its pressure paths; the incremental core must
    reproduce the ledger trajectory exactly."""
    inc = _kv_pressure_result(True)
    ref = _kv_pressure_result(False)
    assert inc == ref
    assert any(t.kv_evictions > 0 and t.kv_swapins > 0
               for t in inc.tenants)


# ----------------------------------------------------------------------
# randomized mid-run churn (arrivals / adds / removes / resizes)
# ----------------------------------------------------------------------
_OP = st.one_of(
    st.tuples(st.just("run"), st.integers(1, 40)),
    st.tuples(st.just("arrive"), st.integers(0, 5), st.integers(1, 3)),
    st.tuples(st.just("add"), st.integers(0, 1)),
    st.tuples(st.just("remove"), st.integers(0, 5)),
    st.tuples(st.just("resize"), st.integers(0, 5), st.integers(2, 6)),
)

_CHURN_CORE = NPUCoreConfig(n_me=4, n_ve=4)
_CHURN_TRACES = ("DLRM", "ENet")


def _churn_result(ops, incremental):
    """Replay one churn script on a fresh open-loop simulator. Every
    side effect is keyed to an ABSOLUTE time cursor (never sim.now),
    so the script is replayable independent of engine internals."""
    cluster = NPUCluster(core=_CHURN_CORE, policy="neu10")
    sim = Simulator((), policy="neu10", core=_CHURN_CORE,
                    incremental=incremental)
    active = []          # (handle, sim_idx)
    t = 0.0
    serial = 0
    for op in ops:
        kind = op[0]
        if kind == "run":
            t += op[1] * 4_000.0
            sim.run_until(t)
        elif kind == "add" and len(active) < 3:
            name = f"t{serial}"
            serial += 1
            try:
                h = cluster.register_vnpu(
                    name, get_workload(_CHURN_TRACES[op[1]], _CHURN_CORE),
                    VNPUConfig(1, 1, hbm_bytes=64 << 20,
                               sram_bytes=1 << 20))
            except RuntimeError:
                continue     # no free engines right now
            idx = sim.add_tenant(
                TenantSpec(cluster.compile(h.trace), h.vnpu),
                open_loop=True)
            active.append((h, idx))
        elif kind == "arrive" and active:
            _, idx = active[op[1] % len(active)]
            for i in range(op[2]):
                sim.inject_request(idx, t + i * 500.0)
        elif kind == "remove" and active:
            h, idx = active.pop(op[1] % len(active))
            sim.remove_tenant(idx)
            cluster.deregister(h)
        elif kind == "resize" and active:
            h, idx = active[op[1] % len(active)]
            try:
                cluster.resize(h, op[2])
            except (ReconfigureError, RuntimeError):
                continue
            sim.update_tenant_vnpu(idx, h.vnpu)
    sim.run_until(math.inf)
    return sim.result()


@settings(max_examples=12, deadline=None)
@given(ops=st.lists(_OP, min_size=4, max_size=14))
def test_property_churn_incremental_identity(ops):
    """Under arbitrary interleavings of arrivals, tenant adds/removes
    and live resizes, the incremental core's SimResult is bit-
    identical to the full-pass engine."""
    assert _churn_result(ops, True) == _churn_result(ops, False)


# ----------------------------------------------------------------------
# event-heap hygiene
# ----------------------------------------------------------------------
def _heap_churn(compact_min=None):
    """Sustained cancellation churn: tenant A serves a rolling open
    loop while short-lived BERT tenants (long per-chunk durations, so
    their pending completion events sit FAR in the future) keep
    registering and deregistering — each removal lazily cancels its
    in-flight events. Returns the peak event-heap size observed."""
    cluster = NPUCluster(core=_CHURN_CORE, policy="neu10")
    sim = Simulator((), policy="neu10", core=_CHURN_CORE)
    if compact_min is not None:
        sim.HEAP_COMPACT_MIN = compact_min
    a = cluster.register_vnpu(
        "a", get_workload("DLRM", _CHURN_CORE),
        VNPUConfig(2, 2, hbm_bytes=64 << 20, sram_bytes=1 << 20))
    ia = sim.add_tenant(TenantSpec(cluster.compile(a.trace), a.vnpu),
                        open_loop=True)
    t = 0.0
    peak = 0
    for i in range(120):
        sim.inject_request(ia, t)
        b = cluster.register_vnpu(
            f"b{i}", get_workload("BERT", _CHURN_CORE),
            VNPUConfig(2, 2, hbm_bytes=64 << 20, sram_bytes=1 << 20))
        ib = sim.add_tenant(TenantSpec(cluster.compile(b.trace), b.vnpu),
                            open_loop=True)
        for j in range(2):
            sim.inject_request(ib, t + j * 100.0)
        t += 500.0
        sim.run_until(t)
        sim.remove_tenant(ib)
        cluster.deregister(b)
        peak = max(peak, len(sim._heap))
    sim.run_until(math.inf)
    return peak


def test_heap_bounded_under_cancellation_churn():
    """Lazy deletion + threshold compaction keep the event heap
    bounded under sustained cancellation churn; with compaction
    disabled the SAME script accumulates strictly more stale entries
    (the bloat the compaction ledger exists to prune)."""
    bound = 4 * Simulator.HEAP_COMPACT_MIN
    peak = _heap_churn()
    assert peak <= bound, f"heap peaked at {peak} > {bound}"
    peak_off = _heap_churn(compact_min=10**9)
    assert peak_off > peak, (peak, peak_off)


def test_compaction_threshold_is_result_invariant():
    """Compacting aggressively (threshold 1) vs never compacting
    yields the same SimResult — compaction only sweeps entries whose
    engine token already moved on, never a live event."""
    def run(threshold):
        cluster = NPUCluster(core=_CHURN_CORE, policy="neu10")
        sim = Simulator((), policy="neu10", core=_CHURN_CORE)
        sim.HEAP_COMPACT_MIN = threshold
        hs = []
        for i, w in enumerate(("DLRM", "ENet")):
            h = cluster.register_vnpu(
                w, get_workload(w, _CHURN_CORE),
                VNPUConfig(2, 2, hbm_bytes=64 << 20, sram_bytes=1 << 20))
            idx = sim.add_tenant(
                TenantSpec(cluster.compile(h.trace), h.vnpu),
                open_loop=True)
            hs.append((h, idx))
            for j in range(6):
                sim.inject_request(idx, j * 3_000.0)
        sim.run_until(20_000.0)
        h, idx = hs.pop(0)
        sim.remove_tenant(idx)        # cancellations feed the ledger
        cluster.deregister(h)
        sim.run_until(math.inf)
        return sim.result()

    assert run(1) == run(10**9)


# ----------------------------------------------------------------------
# cluster event heap (ServingSession._advance)
# ----------------------------------------------------------------------
def _fabric_run(incremental):
    sess = ServingSession(
        NPUCluster(core=KV_CORE, policy="neu10",
                   topology=FabricTopology.ring(4)),
        incremental=incremental)
    ft = sess.register_generative(
        "chat", CHAT, prompt_len=128, gen_lens=8, eu_budget=4,
        placement=Placement(prefill_core=0, decode_core=2),
        kv_policy="evict", hbm_bytes=256 * SEG)
    sess.submit_arrivals(ft, PoissonArrivals(rate_rps=150.0, n=16, seed=7))
    sess.drain()
    return sess, ft


def test_cluster_heap_wakes_idle_migration_target():
    """The decode core starts with an INFINITE event horizon (no
    local work): only the migration hook's re-key can wake it in the
    cluster event heap. Every hand-off must land and decode there —
    and the whole run must be identical with the incremental core on
    or off."""
    sess, ft = _fabric_run(True)
    r = sess.report(ft)[0]
    assert r.requests_done == 16
    assert r.kv_migrations == 16
    assert ft.decode.vnpu.kv_ledger.in_use == 0
    sess_ref, ft_ref = _fabric_run(False)
    for s_inc, s_ref in zip(sess.sims, sess_ref.sims):
        assert s_inc.result() == s_ref.result()
