"""Sharding rules: divisibility invariants across all full configs,
sanitize fallback, and a local-mesh lowering smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SMOKES, TRAIN_4K
from repro.distributed.sharding import (
    batch_specs, dp_axes, param_specs, sanitize_spec)
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import init_train_state, make_train_step
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


class _FakeMesh:
    """Shape-only stand-in so we can test 16x16 rules without 256
    devices."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH16 = _FakeMesh({"data": 16, "model": 16})
MESHPOD = _FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH16, MESHPOD], ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    """Every sharded dim must be divisible by its mesh axes — the
    invariant that makes all 68 dry-run cells lower."""
    cfg = ARCHS[arch]
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k, jnp.bfloat16), KEY)
    specs = param_specs(cfg, shapes, mesh)

    def check(path, spec, leaf):
        for i, entry in enumerate(tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            k = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[i] % k == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, specs, shapes)


def test_tp_actually_shards_big_matrices():
    """The rules must not silently replicate everything: for every
    arch, a majority of FFN/projection bytes are model-sharded."""
    for arch, cfg in ARCHS.items():
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda k: model.init(k, jnp.bfloat16), KEY)
        specs = param_specs(cfg, shapes, MESH16)
        tot = shd = 0

        def acc(spec, leaf):
            nonlocal tot, shd
            n = int(np.prod(leaf.shape))
            tot += n
            if any(e is not None for e in tuple(spec)):
                shd += n

        jax.tree_util.tree_map(
            acc, specs, shapes, is_leaf=lambda x: isinstance(x, P))
        assert shd / tot > 0.5, f"{arch}: only {shd/tot:.0%} params sharded"


class _FakeMeshWrap:
    shape = {"data": 16, "model": 16}
    axis_names = ("data", "model")


def test_sanitize_spec():
    sp = sanitize_spec((1, 1), P("data", None), _FakeMeshWrap())
    assert sp == P(None, None)
    sp = sanitize_spec((32, 32), P("data", "model"), _FakeMeshWrap())
    assert sp == P("data", "model")
    sp = sanitize_spec((32, 17), P("data", "model"), _FakeMeshWrap())
    assert sp == P("data", None)


def test_batch_specs_kinds():
    cfg = ARCHS["qwen2-0.5b"]
    bs = batch_specs(cfg, "train", _FakeMeshWrap())
    assert bs["tokens"] == P("data", None)
    bs = batch_specs(ARCHS["musicgen-large"], "decode", _FakeMeshWrap())
    assert bs["tokens"] == P("data", None, None)
    assert bs["cache_index"] == P()


def test_local_mesh_train_step_lowers_and_runs():
    """End-to-end: sharded train step executes on the local device
    mesh (1 CPU) — the same code path the production mesh uses."""
    cfg = SMOKES["qwen3-14b"]
    mesh = make_local_mesh()
    model = build_model(cfg)
    state = init_train_state(model, KEY)
    shapes = jax.eval_shape(lambda: state)
    specs = param_specs(cfg, shapes["params"], mesh)
    step = jax.jit(make_train_step(model, warmup=1))
    batch = {
        "tokens": jnp.zeros((2, 32), jnp.int32),
        "targets": jnp.zeros((2, 32), jnp.int32),
    }
    with mesh:
        state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
