"""Chunked prefill (SARATHI-style): chunk trace construction, the
per-chunk program cache, chunk phase chains with decode interleave in
the simulator, fused prefill+decode issue groups, and the guarantee
that an UNSET ``prefill_chunk_tokens`` stays bit-identical to the
monolithic-prefill engine."""
import pytest

from repro.configs import SMOKES
from repro.core.compiler import ProgramCache, compile_request_plan
from repro.core.neuisa import FusedIssueGroup, form_fused_group
from repro.core.simulator import Simulator
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.trace import lm_trace, request_plan
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, ServingSession,
                                 run_closed_loop)

CFG = SMOKES["qwen2-0.5b"]


def _session(policy="neu10"):
    return ServingSession(NPUCluster(policy=policy))


# ----------------------------------------------------------------------
# trace / plan construction
# ----------------------------------------------------------------------
def test_chunked_plan_construction():
    plan = request_plan(CFG, batch=1, prompt_len=2048, gen_len=8,
                        prefill_chunk_tokens=256)
    assert plan.chunked and plan.prefill_chunk_tokens == 256
    assert plan.n_prefill_chunks == 8
    assert len(plan.prefill_phases()) == 8
    # only the final chunk carries the lm_head (emits token 1)
    for tr in plan.prefill_chunks[:-1]:
        assert all(op.name != "lm_head" for op in tr.ops)
    assert plan.prefill_chunks[-1].ops[-1].name == "lm_head"
    # chunks after the first re-read prior KV from HBM
    assert all(op.name != "kv_chunk_read" for op in plan.prefill_chunks[0].ops)
    for tr in plan.prefill_chunks[1:]:
        assert any(op.name == "kv_chunk_read" for op in tr.ops)
    # chunk names encode (batch, prior context, tokens) for the cache
    assert ":prefill:b1s256" in plan.prefill_chunks[0].name
    assert ":prefill:b1k256+256" in plan.prefill_chunks[1].name


def test_uneven_prompt_takes_remainder_chunk():
    plan = request_plan(CFG, batch=1, prompt_len=600, gen_len=4,
                        prefill_chunk_tokens=256)
    assert plan.n_prefill_chunks == 3            # 256 + 256 + 88
    assert ":b1k512+88" in plan.prefill_chunks[-1].name


def test_short_prompt_stays_monolithic():
    plan = request_plan(CFG, batch=1, prompt_len=128, gen_len=8,
                        prefill_chunk_tokens=256)
    assert not plan.chunked
    assert plan.prefill_chunk_tokens == 0
    assert plan.prefill_phases() == [plan.prefill]


def test_unset_knob_is_bit_identical_plan():
    """prefill_chunk_tokens=0 must produce exactly the pre-chunking
    plan — same prefill ops, same decode buckets, no chunk state."""
    a = request_plan(CFG, batch=1, prompt_len=512, gen_len=16)
    b = request_plan(CFG, batch=1, prompt_len=512, gen_len=16,
                     prefill_chunk_tokens=0)
    assert not a.chunked and not b.chunked
    assert a.prefill.name == b.prefill.name
    assert [(o.name, o.me_cycles, o.ve_cycles, o.hbm_bytes, o.n_tiles)
            for o in a.prefill.ops] == \
           [(o.name, o.me_cycles, o.ve_cycles, o.hbm_bytes, o.n_tiles)
            for o in b.prefill.ops]
    assert [c for c, _ in a.decode] == [c for c, _ in b.decode]


def test_chunk_work_conserves_compute_and_pays_kv_overhead():
    """Chunking re-tiles the causal attention: total ME/VE work stays
    within a few percent of monolithic, while HBM grows (per-chunk KV
    re-read + weight re-streaming) — the throughput tax the benchmark
    bounds."""
    mono = request_plan(CFG, batch=1, prompt_len=2048, gen_len=2)
    chk = request_plan(CFG, batch=1, prompt_len=2048, gen_len=2,
                       prefill_chunk_tokens=256)
    me_m, ve_m, hbm_m = mono.prefill.totals()
    me_c = sum(t.totals()[0] for t in chk.prefill_chunks)
    ve_c = sum(t.totals()[1] for t in chk.prefill_chunks)
    hbm_c = sum(t.totals()[2] for t in chk.prefill_chunks)
    assert me_m <= me_c <= 1.05 * me_m
    assert ve_m <= ve_c <= 1.05 * ve_m
    assert hbm_c > hbm_m


def test_profile_trace_blends_chunks():
    """The Eq. 1-4 allocator profile of a chunked plan reflects the
    chunk traces (what actually executes), staying close to the
    monolithic compute mix."""
    mono = request_plan(CFG, batch=1, prompt_len=2048, gen_len=8)
    chk = request_plan(CFG, batch=1, prompt_len=2048, gen_len=8,
                       prefill_chunk_tokens=256)
    m0, v0 = mono.profile_trace().profile_mv()
    m1, v1 = chk.profile_trace().profile_mv()
    assert m1 == pytest.approx(m0, rel=0.1)
    assert v1 == pytest.approx(v0, rel=0.1)


# ----------------------------------------------------------------------
# compiler: chunk programs through the shared cache
# ----------------------------------------------------------------------
def test_compile_chunked_plan_once_per_chunk_position():
    cache = ProgramCache()
    plan = request_plan(CFG, batch=1, prompt_len=1024, gen_len=8,
                        prefill_chunk_tokens=256)
    c1 = compile_request_plan(plan, DEFAULT_CORE, isa="neuisa", cache=cache)
    assert c1.chunked and c1.n_prefill_chunks == 4
    assert [ph.kind for ph in c1.prefill_phases()] == ["prefill"] * 4
    assert [ph.context for ph in c1.prefill_chunks] == [256, 512, 768, 1024]
    assert c1.prefill is c1.prefill_chunks[0]
    misses = cache.misses
    assert misses == 4 + len(plan.decode)
    # a second tenant with the same shape + chunk size compiles NOTHING
    c2 = compile_request_plan(plan, DEFAULT_CORE, isa="neuisa", cache=cache)
    assert cache.misses == misses
    for a, b in zip(c1.prefill_chunks, c2.prefill_chunks):
        assert a.program is b.program
    # a different chunk size is a different program set
    plan2 = request_plan(CFG, batch=1, prompt_len=1024, gen_len=8,
                         prefill_chunk_tokens=512)
    compile_request_plan(plan2, DEFAULT_CORE, isa="neuisa", cache=cache)
    assert cache.misses > misses


def test_unchunked_compiled_plan_unchanged():
    plan = request_plan(CFG, batch=1, prompt_len=512, gen_len=8)
    c = compile_request_plan(plan, DEFAULT_CORE, isa="neuisa")
    assert not c.chunked
    assert c.n_prefill_chunks == 1
    assert c.prefill_phases() == [c.prefill]


# ----------------------------------------------------------------------
# simulator: chunk phase chains + same-tenant decode interleave
# ----------------------------------------------------------------------
def _chunked_tenant(sess, gen=8, chunk=256, prompt=1024, name="g"):
    return sess.register_generative(name, CFG, prompt_len=prompt,
                                    gen_lens=gen, eu_budget=4,
                                    prefill_chunk_tokens=chunk)


def test_chunked_token_accounting_matches_monolithic():
    """Chunking changes WHEN work runs, not what a request produces:
    same requests, same tokens, same TTFT/TBT sample counts."""
    outs = []
    for chunk in (0, 256):
        sess = _session()
        h = _chunked_tenant(sess, gen=8, chunk=chunk)
        sess.submit(h, at_s=0.0)
        sess.drain()
        st = sess.sim.tenants[h.sim_idx].stats
        outs.append((st.requests_done, st.tokens, len(st.ttft), len(st.tbt),
                     st.decode_iterations))
        # the chunk counter names CHUNK phases: monolithic counts none
        assert st.prefill_chunks == (0 if chunk == 0 else 4)
    assert outs[0] == outs[1]


def test_decode_iteration_between_prefill_chunks():
    """THE tentpole property: with one request decoding and another
    mid-prefill, a decode iteration executes between two prefill
    chunks of the same tenant. The iteration log must show the
    pattern prefill-chunk -> decode -> prefill-chunk."""
    sess = _session()
    h = _chunked_tenant(sess, gen=16, chunk=256, prompt=1024)
    sim = sess.sim
    rt = sim.tenants[h.sim_idx]
    log = []
    orig = rt._start_iteration

    def spy(t):
        orig(t)
        if rt.in_request:
            log.append(rt.active_kind)

    rt._start_iteration = spy
    sess.submit(h, at_s=0.0)          # request A: prefill + 15 decodes
    sess.submit(h, at_s=0.00002)      # request B arrives mid-A-decode
    sess.drain()
    st = rt.stats
    assert st.requests_done == 2 and st.tokens == 32
    assert st.chunk_interleaved_decodes >= 1
    # find a decode sandwiched between two prefill chunk iterations
    assert any(log[i] == "prefill" and log[i + 1] == "decode"
               and log[i + 2] == "prefill"
               for i in range(len(log) - 2)), log


def test_monolithic_run_never_interleaves():
    sess = _session()
    h = _chunked_tenant(sess, gen=16, chunk=0)
    sess.submit(h, at_s=0.0)
    sess.submit(h, at_s=0.00002)
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.chunk_interleaved_decodes == 0
    assert st.requests_done == 2


def test_chunked_closed_loop_and_open_loop_agree_on_tokens():
    cluster = NPUCluster(policy="neu10")
    cluster.register_generative("g", CFG, prompt_len=1024, gen_lens=8,
                                eu_budget=4, prefill_chunk_tokens=256)
    res, reports = run_closed_loop(cluster, n_requests=3)
    st = res.tenants[0]
    assert st.requests_done >= 3
    assert st.tokens == st.requests_done * 8
    assert st.prefill_chunks == st.requests_done * 4
    assert reports[0].ttft_p95_ms > 0


def test_chunked_determinism():
    def run_once():
        sess = _session()
        h = _chunked_tenant(sess, gen=12, chunk=256)
        sess.submit_arrivals(h, PoissonArrivals(rate_rps=4000.0, n=8, seed=3))
        sess.drain()
        st = sess.sim.tenants[h.sim_idx].stats
        return (st.latencies, st.ttft, st.tbt, st.tokens,
                st.chunk_interleaved_decodes, st.prefill_chunks)

    assert run_once() == run_once()


def test_chunked_tenant_removable_mid_prefill():
    """Deregistering a tenant with a request parked between chunks
    must drop it cleanly (no orphaned iteration state)."""
    sess = _session()
    h = _chunked_tenant(sess, gen=8, chunk=256)
    sess.submit(h, at_s=0.0)
    sess.submit(h, at_s=0.0)
    sess.run_until(1e-5)              # somewhere mid-chain
    sess.deregister(h)
    assert sess.drain() >= 0.0        # no deadlock, no leftover work


# ----------------------------------------------------------------------
# fused prefill+decode issue groups (Fig. 6)
# ----------------------------------------------------------------------
def test_form_fused_group_rules():
    g = form_fused_group(0, "qkv_proj", [
        (0, "attn_decode", "decode"),   # same tenant: never fuses
        (1, "attn_decode", "decode"),   # fuses
        (2, "softmax", "prefill"),      # wrong phase: never fuses
        (3, "attn_decode", "decode"),   # over max_ve
    ], max_ve=1)
    assert isinstance(g, FusedIssueGroup)
    assert g.fused and g.ve_members == [(1, "attn_decode")]
    empty = form_fused_group(0, "qkv_proj", [(0, "x", "decode")])
    assert not empty.fused


def test_neu10_forms_fused_groups_under_colocation():
    """A decode-heavy tenant next to a prefill-heavy tenant under
    neu10 co-issues decode VE μTOps into the neighbor's prefill ME
    window; the baselines (no fuse attr path) never set the flag."""
    sess = _session("neu10")
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=24.0, max_len=96, seed=11),
        eu_budget=4)
    doc = sess.register_generative("doc", CFG, prompt_len=2048,
                                   gen_lens=2, eu_budget=4,
                                   prefill_chunk_tokens=256)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=30_000.0, n=12,
                                               seed=1))
    sess.submit_arrivals(doc, PoissonArrivals(rate_rps=4_000.0, n=4, seed=2))
    sess.drain()
    assert sess.sim.tenants[chat.sim_idx].stats.fused_groups > 0
    # the policy keeps the recent groups inspectable: doc's prefill
    # MEs anchor, chat's decode VE μTOps ride
    recent = sess.sim.policy_obj.recent_fused
    assert recent and all(g.fused for g in recent)
    assert any(g.me_tenant == doc.sim_idx
               and (chat.sim_idx, "attn_decode") in g.ve_members
               for g in recent), list(recent)[:4]


def test_v10_pmt_never_fuse():
    for policy in ("pmt", "v10"):
        sess = _session(policy)
        chat = sess.register_generative(
            "chat", CFG, prompt_len=128, gen_lens=8, eu_budget=4)
        doc = sess.register_generative("doc", CFG, prompt_len=1024,
                                       gen_lens=2, eu_budget=4,
                                       prefill_chunk_tokens=256)
        sess.submit_arrivals(chat, PoissonArrivals(rate_rps=20_000.0, n=6,
                                                   seed=1))
        sess.submit_arrivals(doc, PoissonArrivals(rate_rps=4_000.0, n=3,
                                                  seed=2))
        sess.drain()
        for h in (chat, doc):
            assert sess.sim.tenants[h.sim_idx].stats.fused_groups == 0


# ----------------------------------------------------------------------
# guards
# ----------------------------------------------------------------------
def test_chunked_misuse_guards():
    with pytest.raises(AssertionError):
        lm_trace(CFG, 1, 512, "decode", kv_prior=256)   # decode has no chunks
    sim = Simulator((), policy="neu10")
    sim.run_until(100.0)                                # empty run still fine
