"""Credit-based SLO admission control (fleet scale) + the admission/
loan lifecycle bugfix sweep: CreditAccount conservation (hypothesis),
price/decide/downsize/defer unit coverage, knapsack tie-breaks, gate
integration (defer -> re-admit -> arrival replay, scale-up gating,
report fields, free-fleet bit-identity with the gate off), composition
with KV pressure and faults, and the three lifecycle regressions —
deregister loan unwinding on both sides, stale autoscale cursors on
sim-slot reuse, and the zero-backoff retry storm."""
import math

import pytest

from repro.configs import SMOKES
from repro.core.admission import (AdmissionAsk, AdmissionController,
                                  CreditAccount, FleetState)
from repro.core.allocator import credit_weighted_fill
from repro.core.fabric import FabricLink, FabricTopology
from repro.core.faults import FaultEvent, FaultSchedule
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import (AdmissionTicket, GenLenDistribution,
                                 NPUCluster, PoissonArrivals,
                                 ServingSession, SLOAutoscaler)
from tests.hypothesis_compat import given, settings, st

CFG = SMOKES["qwen2-0.5b"]
SEG = 64 * 1024
CORE = DEFAULT_CORE.with_(hbm_bytes=64 * SEG, hbm_segment=SEG)
BIG_CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)
LINK = FabricLink(bandwidth=16.0, latency=400_000.0)
WEIGHTS = CFG.param_count() * 2
WSEG = -(-WEIGHTS // SEG) * SEG


def _census_ok(sess):
    return all(f + r + x == t
               for f, r, x, t in sess.cluster.manager.hbm_census())


def _one_core(admission=None, core=CORE, **kw):
    topo = FabricTopology.mesh(1, LINK)
    cluster = NPUCluster(core=core, policy="neu10", topology=topo)
    return ServingSession(cluster, admission=admission, **kw)


# ----------------------------------------------------------------------
# FleetState / pricing / decide unit coverage
# ----------------------------------------------------------------------
def test_fleet_state_pressure_is_dominant_resource():
    f = FleetState(free_eus=2, total_eus=8,
                   free_hbm_segments=48, total_hbm_segments=64)
    assert f.pressure == pytest.approx(0.75)          # EUs dominate
    assert f.dominant_share(2, 32) == pytest.approx(0.5)
    assert f.fits(2, 48) and not f.fits(3, 0) and not f.fits(0, 49)


def test_price_free_below_free_level_then_linear():
    ctl = AdmissionController(price_scale=4.0, free_level=0.5)
    idle = FleetState(8, 8, 64, 64)
    assert ctl.price(4, 0, idle) == 0.0
    half = FleetState(4, 8, 64, 64)                    # exactly at knee
    assert ctl.price(4, 0, half) == 0.0
    full = FleetState(0, 8, 64, 64)                    # saturated
    assert ctl.price(4, 0, full) == pytest.approx(4.0 * 0.5)
    mid = FleetState(2, 8, 64, 64)                     # pressure 0.75
    assert ctl.price(4, 0, mid) == pytest.approx(4.0 * 0.5 * 0.5)


def test_decide_admit_downsize_defer_paths():
    ctl = AdmissionController(initial_credit=1.0, free_level=0.0,
                              price_scale=4.0)
    idle = FleetState(16, 16, 64, 64)
    ask = AdmissionAsk(name="a", eus=4, hbm_segments=8, min_eus=2)
    d = ctl.decide(ask, 0.0, idle)
    assert d.status == "admit" and d.eus == 4 and d.price == 0.0
    # pressure makes the full ask unaffordable but a smaller one fits
    tight = FleetState(4, 16, 8, 64)                   # pressure 0.875
    acct = ctl.accounts["a"]
    acct.spend(acct.credit - ctl.price(3, 8, tight) - 1e-9)
    d = ctl.decide(ask, 0.0, tight)
    assert d.status == "downsize" and 2 <= d.eus < 4
    # broke: even the floored ask is unaffordable -> defer on credit
    acct.spend(acct.credit + 1.0)
    d = ctl.decide(ask, 0.0, tight)
    assert d.status == "defer" and d.reason == "credit"
    # misfit: the floored ask does not fit -> defer on capacity
    rich = ctl.touch(AdmissionAsk(name="b", eus=4, hbm_segments=8), 0.0)
    rich.spend(rich.credit - 100.0)
    d = ctl.decide(AdmissionAsk(name="b", eus=4, hbm_segments=8),
                   0.0, FleetState(1, 16, 8, 64))
    assert d.status == "defer" and d.reason == "capacity"
    assert ctl.accounts["a"].deferrals == 1
    assert all(a.conserved() for a in ctl.accounts.values())


def test_hbm_ask_is_never_downsized():
    ctl = AdmissionController(free_level=0.0)
    a = ctl.touch(AdmissionAsk(name="a", eus=4, hbm_segments=32), 0.0)
    a.spend(a.credit - 1e9)
    # plenty of EUs, not enough segments: no EU walk can help
    d = ctl.decide(AdmissionAsk(name="a", eus=4, hbm_segments=32),
                   0.0, FleetState(16, 16, 16, 64))
    assert d.status == "defer" and d.reason == "capacity"


def test_accrual_rate_follows_declared_strictness():
    ctl = AdmissionController(base_rate=0.1, slo_rate=1.0)
    lax = ctl.accrual_rate(AdmissionAsk(name="l", eus=2))
    strict = ctl.accrual_rate(AdmissionAsk(name="s", eus=2,
                                           slo_ttft_ms=2.0,
                                           slo_tbt_ms=1.0))
    assert lax == pytest.approx(0.1)
    assert strict == pytest.approx(0.1 + 1.0 * (0.5 + 1.0))
    # touch is idempotent: a failover re-attach keeps the balance
    a = ctl.touch(AdmissionAsk(name="s", eus=2, slo_ttft_ms=2.0), 0.0)
    a.spend(a.credit - 42.0)
    assert ctl.touch(AdmissionAsk(name="s", eus=2), 5.0).credit == 42.0


def test_decay_bounds_hoarded_credit():
    ctl = AdmissionController(initial_credit=0.0, base_rate=1.0,
                              slo_rate=0.0, decay_halflife_s=1.0)
    acct = ctl.touch(AdmissionAsk(name="a", eus=2), 0.0)
    dt = 0.05
    for i in range(1, 2001):
        acct.advance(i * dt)
    # discrete equilibrium of decay-then-accrue:
    # c* = rate*dt / (1 - exp(-dt/tau)) — approaches rate*tau as dt->0,
    # never more than one step's accrual above it
    eq = acct.rate * dt / (1.0 - math.exp(-dt / acct.tau_s))
    assert acct.credit == pytest.approx(eq, rel=1e-6)
    assert acct.rate * acct.tau_s < eq < acct.rate * (acct.tau_s + dt)
    assert acct.conserved()


# ----------------------------------------------------------------------
# credit-weighted knapsack: ordering + tie-breaks
# ----------------------------------------------------------------------
def test_knapsack_grants_by_credit_density():
    # b asks the same resources with twice the credit -> drains first;
    # the pool only fits one
    got = credit_weighted_fill([("a", 1.0, 4, 0), ("b", 2.0, 4, 0)],
                               free_eus=4, free_segments=0,
                               total_eus=16, total_segments=0)
    assert got == ["b"]


def test_knapsack_skips_misfit_without_blocking_smaller():
    # the high-credit big ask does not fit; the small one behind it
    # still drains (greedy knapsack, not strict FIFO-by-rank)
    got = credit_weighted_fill([("big", 9.0, 12, 0), ("small", 1.0, 2, 0)],
                               free_eus=4, free_segments=0,
                               total_eus=16, total_segments=0)
    assert got == ["small"]


def test_knapsack_tie_breaks_credit_then_name():
    # equal density (same ask, same credit-per-share): higher absolute
    # credit first; full tie falls back to ascending name
    got = credit_weighted_fill(
        [("z", 2.0, 2, 0), ("m", 4.0, 4, 0), ("a", 2.0, 2, 0)],
        free_eus=16, free_segments=0, total_eus=16, total_segments=0)
    assert got == ["a", "m", "z"] or got == ["m", "a", "z"]
    # density: m = 4/(4/16) = 16, a = z = 2/(2/16) = 16 -> all equal;
    # credit breaks first (m), then name (a before z)
    assert got == ["m", "a", "z"]


# ----------------------------------------------------------------------
# hypothesis: accounts conserve accrual - decay - debits under churn
# ----------------------------------------------------------------------
@settings(deadline=None, max_examples=60)
@given(st.lists(st.tuples(st.sampled_from(["advance", "violate",
                                           "decide", "scaleup"]),
                          st.floats(min_value=0.001, max_value=2.0),
                          st.integers(min_value=0, max_value=3)),
                min_size=1, max_size=40))
def test_credit_accounts_conserve_under_churn(ops):
    ctl = AdmissionController(initial_credit=0.5, decay_halflife_s=0.7,
                              free_level=0.25)
    fleet = FleetState(6, 16, 40, 64)
    now = 0.0
    ask = AdmissionAsk(name="t", eus=4, hbm_segments=8,
                       slo_ttft_ms=2.0, min_eus=2)
    ctl.touch(ask, now)
    for op, dt, k in ops:
        now += dt
        if op == "advance":
            ctl.balance("t", now)
        elif op == "violate":
            ctl.observe("t", now, k)
        elif op == "decide":
            ctl.decide(ask, now, fleet)
        else:
            ctl.approve_scaleup("t", k, now, fleet)
        acct = ctl.accounts["t"]
        assert acct.conserved(1e-6)
        assert acct.last_s <= now + 1e-12
    acct = ctl.accounts["t"]
    assert acct.credit == pytest.approx(
        acct.initial + acct.accrued - acct.decayed - acct.debited,
        abs=1e-6)


# ----------------------------------------------------------------------
# session integration: gate off by default, free fleet = bit-identical
# ----------------------------------------------------------------------
def _gated_run(admission):
    sess = _one_core(admission=admission, core=BIG_CORE)
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128, gen_lens=8, eu_budget=4,
        kv_policy="evict", hbm_bytes=WSEG + 8 * SEG,
        slo_ttft_ms=5.0, slo_tbt_ms=2.0)
    assert not isinstance(chat, AdmissionTicket)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=200.0, n=20,
                                               seed=1))
    sess.drain()
    return sess.latencies_ms(chat), sess


def test_free_fleet_gate_is_bit_identical_to_off():
    """Below ``free_level`` utilization every ask is free and admitted
    at full size — the gate must be invisible in the dynamics."""
    off, _ = _gated_run(None)
    on, sess = _gated_run(AdmissionController())
    assert on == off
    assert all(a.conserved() for a in
               sess.admission.accounts.values())


def test_report_exposes_credit_and_deferrals():
    _, sess = _gated_run(AdmissionController())
    rep, = sess.report()
    assert rep.credit > 0.0
    assert rep.admission_deferrals == 0
    # gate off: the fields stay zero
    _, off_sess = _gated_run(None)
    assert off_sess.report()[0].credit == 0.0


def test_deferred_ticket_admits_when_capacity_frees():
    ctl = AdmissionController(initial_credit=0.2, free_level=0.3,
                              base_rate=0.5)
    sess = _one_core(admission=ctl)
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128, gen_lens=8, eu_budget=6,
        hbm_bytes=32 * SEG, slo_ttft_ms=2.0, slo_tbt_ms=1.0)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=20_000.0,
                                               n=30, seed=1))
    burst = sess.register_generative(
        "burst", CFG, prompt_len=128, gen_lens=8, eu_budget=6,
        min_eus=2, hbm_bytes=16 * SEG)
    assert isinstance(burst, AdmissionTicket)
    # arrivals queue against the ticket, nothing injected yet
    n = sess.submit_arrivals(burst, PoissonArrivals(rate_rps=10_000.0,
                                                    n=10, seed=2))
    assert n == 0 and len(burst.pending_arrivals) == 1
    for w in range(1, 11):
        sess.run_until(w * 0.01)
    assert not burst.admitted and burst.deferrals >= 1
    # the incumbent leaves -> the queued ticket clears next window and
    # its arrivals replay (past ones land at the admission instant)
    sess.deregister(chat)
    sess.run_until(0.12)
    assert burst.admitted and burst.handle.eu_budget == 6
    sess.drain()
    rep = {r.name: r for r in sess.report()}
    assert rep["burst"].requests_done == 10
    assert rep["burst"].admission_deferrals == burst.deferrals
    assert _census_ok(sess)
    assert all(a.conserved() for a in ctl.accounts.values())


def test_scaleup_passes_the_credit_gate():
    """A broke tenant's autoscale grow is denied (and counted); the
    same run without pressure-priced scale-ups resizes freely."""
    ctl = AdmissionController(initial_credit=0.0, base_rate=0.0,
                              slo_rate=0.0, free_level=0.0)
    sess = _one_core(admission=ctl, core=BIG_CORE,
                     autoscaler=SLOAutoscaler(step_eus=2, max_eus=8,
                                              min_samples=3))
    slow = sess.register_generative(
        "slow", CFG, prompt_len=512, gen_lens=64, eu_budget=2,
        slo_p95_ms=0.01)
    sess.submit_arrivals(slow, PoissonArrivals(rate_rps=5000.0, n=40,
                                               seed=3))
    t = 0.0
    for _ in range(8):
        t += 0.01
        sess.run_until(t)
    assert slow.eu_budget == 2                    # never allowed to grow
    assert ctl.accounts["slow"].scaleups_denied >= 1
    sess.drain()
    assert all(a.conserved() for a in ctl.accounts.values())


# ----------------------------------------------------------------------
# composition: credit gate x KV pressure x faults
# ----------------------------------------------------------------------
def test_admission_composes_with_faults_and_kv_pressure():
    """Multi-core fabric under a transient core fault with KV-evicting
    tenants and the gate on: a deferred tenant admitted mid-chaos
    replays cleanly, every arrival is accounted, census conserves and
    so do the credit accounts."""
    topo = FabricTopology.mesh(2, LINK)
    sch = FaultSchedule([FaultEvent(at=0.0002, kind="core_down", core=1,
                                    recovery=0.002)])
    cluster = NPUCluster(core=CORE, policy="neu10", topology=topo)
    ctl = AdmissionController(initial_credit=0.3, free_level=0.2,
                              base_rate=1.0)
    sess = ServingSession(cluster, admission=ctl, faults=sch,
                          failover="evacuate")
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=32.0, max_len=64, seed=7),
        eu_budget=6, kv_policy="evict", hbm_bytes=WSEG + 4 * SEG,
        slo_ttft_ms=2.0, slo_tbt_ms=1.0)
    assert not isinstance(chat, AdmissionTicket)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=20_000.0,
                                               n=24, seed=1))
    late = sess.register_generative(
        "late", CFG, prompt_len=128, gen_lens=8, eu_budget=10,
        min_eus=2, kv_policy="evict", hbm_bytes=WSEG + 2 * SEG)
    if isinstance(late, AdmissionTicket):
        sess.submit_arrivals(late, PoissonArrivals(rate_rps=5000.0,
                                                   n=8, seed=2))
        w = 0
        while not late.admitted and w < 600:
            w += 1
            sess.run_until(w * 0.01)
        assert late.admitted
        late_done = 8
    else:
        sess.submit_arrivals(late, PoissonArrivals(rate_rps=5000.0,
                                                   n=8, seed=2))
        late_done = 8
    sess.drain()
    rep = {r.name: r for r in sess.report()}
    assert rep["chat"].requests_done == 24
    assert rep["late"].requests_done == late_done
    assert _census_ok(sess)
    assert not sess.cluster.manager._loans
    assert all(a.conserved() for a in ctl.accounts.values())


# ----------------------------------------------------------------------
# bugfix regression: deregister unwinds HBM loans (both sides)
# ----------------------------------------------------------------------
def _loan_pair():
    """A squeezed borrower holding the owner's idle segments (the
    test_kv_prefix borrow idiom, paused mid-loan)."""
    cluster = NPUCluster(core=BIG_CORE, policy="neu10")
    sess = ServingSession(cluster)
    needy = sess.register_generative(
        "needy", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=96.0, max_len=256, seed=11),
        eu_budget=2, kv_policy="evict", hbm_bytes=WSEG + 2 * SEG,
        kv_borrow=True)
    owner = sess.register_generative(
        "owner", CFG, prompt_len=128, gen_lens=64, eu_budget=2,
        kv_policy="evict", hbm_bytes=WSEG + 8 * SEG)
    sess.submit_arrivals(needy, PoissonArrivals(rate_rps=200_000.0,
                                                n=24, seed=1))
    sess.run_until(0.01)
    man = cluster.manager
    lent, _ = man.loans_of(owner.vnpu)
    assert lent > 0 and needy.vnpu.kv_ledger.borrowed == lent
    return sess, needy, owner, man


def test_deregister_borrower_returns_loans():
    sess, needy, owner, man = _loan_pair()
    sess.deregister(needy)
    assert owner.vnpu.kv_ledger.lent == 0
    assert man.loans_of(owner.vnpu) == (0, 0)
    assert not man._loans                       # loan table fully settled
    assert _census_ok(sess)
    # the owner keeps serving at full capacity afterwards
    for i in range(4):
        sess.submit(owner, at_s=sess.now_s + 1e-6 * (i + 1))
    sess.drain()
    assert sess.report(owner)[0].requests_done == 4


def test_deregister_lender_reclaims_loans():
    sess, needy, owner, man = _loan_pair()
    sess.deregister(owner)
    assert needy.vnpu.kv_ledger.borrowed == 0
    assert needy.vnpu.kv_ledger.in_use == 0
    assert not man._loans
    assert _census_ok(sess)
    # the borrower survives the reclaim and keeps serving
    for i in range(4):
        sess.submit(needy, at_s=sess.now_s + 1e-6 * (i + 1))
    sess.drain()
    assert sess.report(needy)[0].requests_done == 24 + 4


# ----------------------------------------------------------------------
# bugfix regression: stale autoscale cursors on sim-slot reuse
# ----------------------------------------------------------------------
def test_deregister_drops_autoscale_cursors():
    sess = _one_core(core=BIG_CORE,
                     autoscaler=SLOAutoscaler(step_eus=2, max_eus=8,
                                              min_samples=3))
    a = sess.register_generative("a", CFG, prompt_len=128, gen_lens=8,
                                 eu_budget=2, slo_p95_ms=1000.0)
    sess.submit_arrivals(a, PoissonArrivals(rate_rps=5000.0, n=20,
                                            seed=1))
    sess.run_until(0.01)
    slot = (a.core_idx, a.sim_idx)
    sess._autoscale_cursor[slot] = len(sess._rt(a).stats.latencies)
    # a lazily-created per-series key (the fabric autoscaler's shape)
    # must die with the tenant too
    sess._autoscale_cursor[slot + ("ttft",)] = 7
    sess.deregister(a)
    assert not any(k[:2] == slot for k in sess._autoscale_cursor)
    # a successor windows from ZERO: its very first autoscale decision
    # sees only its own samples, and the breach is acted on promptly
    b = sess.register_generative("b", CFG, prompt_len=512, gen_lens=64,
                                 eu_budget=2, slo_p95_ms=0.01)
    assert sess._autoscale_cursor[(b.core_idx, b.sim_idx)] == 0
    sess.submit_arrivals(b, PoissonArrivals(rate_rps=5000.0, n=20,
                                            seed=2, start_s=sess.now_s))
    t = sess.now_s
    for _ in range(6):
        t += 0.01
        sess.run_until(t)
    assert b.eu_budget > 2                   # the hook resized it
    sess.drain()


def test_cursor_table_stays_bounded_under_churn():
    """deregister -> register -> autoscale cycles: the cursor table
    tracks LIVE tenants only (the pre-fix leak grew one stale entry
    per departed tenant, forever)."""
    sess = _one_core(core=BIG_CORE,
                     autoscaler=SLOAutoscaler(step_eus=2, max_eus=4,
                                              min_samples=3))
    for i in range(6):
        h = sess.register_generative(
            f"t{i}", CFG, prompt_len=128, gen_lens=8, eu_budget=2,
            slo_p95_ms=1000.0)
        sess.submit_arrivals(h, PoissonArrivals(rate_rps=5000.0, n=5,
                                                seed=i,
                                                start_s=sess.now_s))
        sess.run_until(sess.now_s + 0.01)
        sess.deregister(h)
        assert len(sess._autoscale_cursor) == 0
    assert _census_ok(sess)


# ----------------------------------------------------------------------
# bugfix regression: zero-backoff retry storm
# ----------------------------------------------------------------------
def test_zero_backoff_retries_land_on_distinct_timestamps():
    """With ``retry_backoff_ms=0`` a re-admitted request must land
    STRICTLY AFTER the cycle it expired in (next event tick, else one
    sweep period) — never at the same instant, where the deadline
    sweep would re-expire it before anything can move."""
    sess = _one_core(core=BIG_CORE)
    chat = sess.register_generative(
        "chat", CFG, prompt_len=512, gen_lens=64, eu_budget=2,
        deadline_ms=0.05, max_retries=3, retry_backoff_ms=0.0)
    sim = sess._sim_of(chat)
    seen = []
    orig = sim.inject_retry

    def spy(idx, at, **kw):
        seen.append((sim.now, at))
        return orig(idx, at, **kw)

    sim.inject_retry = spy
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=200_000.0,
                                               n=24, seed=1))
    sess.drain()
    assert seen                                  # deadline hits happened
    assert all(at > now for now, at in seen)     # strictly in the future
    r = sess.report(chat)[0]
    # every arrival either completed or exhausted its retries — no
    # request burned its whole budget inside one frozen timestamp
    # without the run terminating
    assert r.requests_done + r.retries_exhausted == 24
    assert r.retries >= 1
    assert _census_ok(sess)


def test_zero_backoff_retry_can_still_succeed():
    """Once the backlog clears, a zero-backoff retry admitted at the
    next tick completes (progress, not just exhaustion)."""
    sess = _one_core(core=BIG_CORE)
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128, gen_lens=8, eu_budget=4,
        deadline_ms=0.3, max_retries=8, retry_backoff_ms=0.0)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=100_000.0,
                                               n=24, seed=5))
    sess.drain()
    r = sess.report(chat)[0]
    assert r.requests_done + r.retries_exhausted == 24
    if r.retries:                                # pressure did expire some
        assert r.retry_successes >= 1 or r.retries_exhausted >= 1
    assert r.requests_done > 0
