"""Pallas kernel validation: shape/dtype sweeps + properties against
the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import gqa_attention_ref, grouped_matmul_ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 128, 4, 64),    # minimal blocks
    (2, 256, 8, 64),    # multi-block
    (1, 384, 8, 128),   # 3 blocks, big head
    (2, 200, 4, 64),    # padding path
])
def test_flash_attention_sweep(shape, dtype):
    B, S, H, hd = shape
    for hkv in {H, max(H // 4, 1)}:
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
        k = jax.random.normal(ks[1], (B, S, hkv, hd), dtype)
        v = jax.random.normal(ks[2], (B, S, hkv, hd), dtype)
        out = ops.flash_attention(q, k, v, causal=True)
        ref = gqa_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype))


def test_flash_attention_causality():
    """Output at position i must not depend on tokens > i."""
    B, S, H, hd = 1, 256, 4, 64
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out1 = ops.flash_attention(q, k, v, causal=True)
    k2 = k.at[:, S // 2:].set(jax.random.normal(ks[3], (B, S // 2, H, hd)))
    v2 = v.at[:, S // 2:].set(0.0)
    out2 = ops.flash_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(np.asarray(out1[:, : S // 2]),
                               np.asarray(out2[:, : S // 2]),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_matches_model_sdpa():
    """The kernel agrees with the model-zoo reference attention."""
    from repro.models.layers import _sdpa

    B, S, H, hd = 2, 128, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, 2, hd))
    v = jax.random.normal(ks[2], (B, S, 2, hd))
    mask = jnp.tril(jnp.ones((S, S), bool))[None, None, None]
    ref = _sdpa(q, k, v, mask)
    out = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (4, 128, 256, 128),
    (2, 64, 512, 96),    # padding on f
    (6, 100, 300, 130),  # padding everywhere
])
def test_grouped_matmul_sweep(shape, dtype):
    E, C, d, f = shape
    x = jax.random.normal(KEY, (E, C, d), dtype)
    w = jax.random.normal(KEY, (E, d, f), dtype)
    out = ops.grouped_matmul(x, w)
    ref = grouped_matmul_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


@given(
    e=st.integers(1, 4),
    c=st.integers(1, 64),
    d=st.integers(1, 192),
    f=st.integers(1, 96),
)
@settings(max_examples=15, deadline=None)
def test_grouped_matmul_property(e, c, d, f):
    x = jax.random.normal(KEY, (e, c, d))
    w = jax.random.normal(KEY, (e, d, f))
    out = ops.grouped_matmul(x, w)
    ref = grouped_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
