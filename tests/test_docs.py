"""Docs hygiene: the docs/ tree exists and every relative link in
README.md / docs/**.md resolves (the same check CI's docs job runs,
via tools/check_links.py)."""
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_links  # noqa: E402


def test_docs_tree_exists():
    for name in ("architecture.md", "scheduling.md", "benchmarks.md"):
        assert (REPO / "docs" / name).is_file(), f"docs/{name} missing"


def test_readme_and_docs_links_resolve():
    files = check_links.default_files(REPO)
    assert any(f.name == "README.md" for f in files)
    assert sum(f.parent.name == "docs" for f in files) >= 3
    errors = []
    for f in files:
        errors.extend(check_links.check_file(f, REPO))
    assert not errors, "\n".join(errors)


def test_docs_cover_every_benchmark_suite():
    """docs/benchmarks.md must document every suite registered in
    benchmarks/run.py (so new figures can't ship undocumented)."""
    sys.path.insert(0, str(REPO))
    from benchmarks.run import SUITES

    text = (REPO / "docs" / "benchmarks.md").read_text(encoding="utf-8")
    for key, mod in SUITES.items():
        mod_file = Path(mod.__file__).name
        assert mod_file[:-3] in text, (
            f"docs/benchmarks.md does not mention {mod_file}")


def test_checker_flags_broken_link(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](no/such/file.md) and "
                   "[ok](bad.md) and [web](https://x.invalid/y)\n",
                   encoding="utf-8")
    errors = check_links.check_file(bad, tmp_path)
    assert len(errors) == 1 and "no/such/file.md" in errors[0]
