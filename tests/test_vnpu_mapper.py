"""vNPU lifecycle + vNPU->pNPU mapping + memory isolation (§III-A/C)."""
import pytest

from repro.core.mapper import VNPUManager
from repro.core.vnpu import PRESETS, VNPUConfig, VNPUState
from repro.npu.hw_config import NPUCoreConfig


def test_lifecycle_and_release():
    mgr = VNPUManager()
    v = mgr.create(VNPUConfig(2, 2, hbm_bytes=2 << 30))
    assert v.state == VNPUState.MAPPED
    assert len(v.me_ids) == 2 and len(v.ve_ids) == 2
    core = mgr.cores[0]
    assert len(core.free_mes) == 2
    mgr.destroy(v)
    assert v.state == VNPUState.DESTROYED
    assert len(core.free_mes) == 4 and len(core.free_ves) == 4
    assert len(core.free_hbm_segs) == core.core.hbm_bytes // core.core.hbm_segment


def test_validation():
    with pytest.raises(ValueError, match="at least 1"):
        VNPUConfig(0, 2).validate()
    with pytest.raises(ValueError, match="exceeds"):
        VNPUConfig(8, 8).validate(NPUCoreConfig(n_me=4, n_ve=4))


def test_spatial_no_overcommit():
    mgr = VNPUManager()
    mgr.create(VNPUConfig(3, 3))
    with pytest.raises(RuntimeError, match="no pNPU core fits"):
        mgr.create(VNPUConfig(2, 2))


def test_temporal_oversubscription_allowed():
    mgr = VNPUManager()
    a = mgr.create(VNPUConfig(4, 4), mapping="temporal")
    b = mgr.create(VNPUConfig(4, 4), mapping="temporal")
    assert a.core_id == b.core_id
    assert mgr.collocated(a) == [b]


def test_segment_isolation_and_translation():
    mgr = VNPUManager()
    a = mgr.create(VNPUConfig(2, 2, hbm_bytes=2 << 30))
    b = mgr.create(VNPUConfig(2, 2, hbm_bytes=2 << 30))
    # disjoint physical segments
    assert not (set(a.segments.hbm_segments) & set(b.segments.hbm_segments))
    assert not (set(a.segments.sram_segments) & set(b.segments.sram_segments))
    # translation: base-plus-offset within the vNPU's own segments
    pa = a.segments.translate("hbm", 10)
    pb = b.segments.translate("hbm", 10)
    assert pa != pb
    # page fault outside the allocation
    with pytest.raises(MemoryError, match="page fault"):
        a.segments.translate("hbm", a.segments.hbm_bytes + 1)


def test_greedy_balances_eu_vs_memory():
    """EU-hungry vNPUs should collocate with memory-hungry ones."""
    core = NPUCoreConfig()
    mgr = VNPUManager(n_pnpus=2, core=core)
    big_mem = mgr.create(VNPUConfig(1, 1, hbm_bytes=32 << 30))
    big_eu = mgr.create(VNPUConfig(3, 3, hbm_bytes=1 << 30))
    assert (big_mem.pnpu_id, big_mem.core_id) == (big_eu.pnpu_id, big_eu.core_id)


def test_reconfigure_preserves_name():
    mgr = VNPUManager()
    v = mgr.create(VNPUConfig(1, 1), name="tenantA")
    v2 = mgr.reconfigure(v, VNPUConfig(2, 2))
    assert v2.name == "tenantA"
    assert v2.config.n_me == 2
    assert v.state == VNPUState.DESTROYED


def test_presets_valid():
    for name, cfg in PRESETS.items():
        cfg.validate(NPUCoreConfig(n_me=8, n_ve=8))
