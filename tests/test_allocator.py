"""§III-B allocator: Eq. 1-4 correctness + optimality properties."""
import math

import pytest
from hypothesis_compat import given, settings, st

from repro.core.allocator import (
    allocate_eus,
    eu_utilization,
    estimate_memory,
    normalized_exec_time,
    optimal_ratio,
)
from repro.npu.cost_model import WorkloadTrace, vector_op
from repro.npu.hw_config import NPUCoreConfig


def test_eq1_known_values():
    # m=1, v=1: fully overlapped -> T = 0/n_m + 0/n_v + 1/min
    assert normalized_exec_time(1.0, 1.0, 2, 2) == pytest.approx(0.5)
    # m=1, v=0.5 on 1ME/1VE: T = 0.5 + 0 + 0.5 = 1
    assert normalized_exec_time(1.0, 0.5, 1, 1) == pytest.approx(1.0)


def test_eq4_closed_form():
    assert optimal_ratio(0.2, 0.9) == pytest.approx(math.sqrt(0.2 / 0.8))
    assert optimal_ratio(0.9, 0.2) == pytest.approx(math.sqrt(0.8 / 0.2))
    assert optimal_ratio(0.7, 0.6) == 1.0


@given(
    m=st.floats(0.05, 1.0),
    v=st.floats(0.05, 1.0),
)
@settings(max_examples=200, deadline=None)
def test_eq4_maximizes_eq2(m, v):
    """The paper's closed-form k* must beat any other ratio on the
    continuous relaxation (checked on a fine grid)."""
    if m + v < 1.0:  # infeasible per the paper's model
        return
    k_star = optimal_ratio(m, v)
    # evaluate U on the continuous relaxation (n_m, n_v) = (10k, 10)
    # (scaled so both exceed the >=1 engine-count guard)
    def u_of_k(k):
        return eu_utilization(m, v, max(k, 1e-6) * 10.0, 10.0)

    u_star = u_of_k(k_star)
    for k in [0.1, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 4.0, 8.0]:
        assert u_star >= u_of_k(k) - 1e-6


@given(
    m=st.floats(0.05, 1.0),
    v=st.floats(0.05, 1.0),
    total=st.integers(2, 8),
)
@settings(max_examples=200, deadline=None)
def test_integer_allocation_is_exhaustive_optimum(m, v, total):
    if m + v < 1.0:
        return
    core = NPUCoreConfig(n_me=8, n_ve=8)
    alloc = allocate_eus(m, v, total, core)
    assert alloc.n_me + alloc.n_ve == total
    assert alloc.n_me >= 1 and alloc.n_ve >= 1
    best = max(
        eu_utilization(m, v, nm, total - nm) for nm in range(1, total))
    assert alloc.utilization == pytest.approx(best)


def test_memory_rounding_to_segments():
    core = NPUCoreConfig()
    tr = WorkloadTrace("w", [vector_op("x", 1024, core)],
                       hbm_footprint=1.5 * core.hbm_segment, core=core)
    sram, hbm = estimate_memory(tr, 2, core)
    assert hbm == 2 * core.hbm_segment
    assert sram % core.sram_segment == 0
    assert sram == core.sram_bytes // 2  # proportional to 2/4 MEs
