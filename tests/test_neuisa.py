"""NeuISA compiler + program structure (§III-D)."""
import pytest

from repro.core.compiler import compile_neuisa, compile_vliw, neuisa_overhead_terms
from repro.core.neuisa import ME, VE, MuTOp, MuTOpGroup, NeuISAProgram
from repro.npu.cost_model import matmul_op, vector_op, WorkloadTrace
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.workloads import get_workload


def _trace():
    core = DEFAULT_CORE
    return WorkloadTrace("t", [
        matmul_op("mm1", 512, 1024, 1024, core),
        vector_op("act", 512 * 1024, core),
        matmul_op("mm_small", 8, 4096, 8, core),   # reduction split
    ], core=core)


def test_group_structure_constraints():
    prog = compile_neuisa(_trace())
    prog.validate()
    for g in prog.groups:
        assert len(g.me_utops) <= prog.n_x
    table = prog.exec_table()
    assert len(table) == len(prog.groups)
    assert all(len(row) == prog.n_x + 1 for row in table)


def test_work_conservation():
    tr = _trace()
    prog = compile_neuisa(tr)
    me_t, ve_t, hbm_t = tr.totals()
    me_p, ve_p, hbm_p = prog.total_work()
    assert me_p == pytest.approx(me_t, rel=1e-9)
    assert hbm_p == pytest.approx(hbm_t, rel=1e-9)
    assert ve_p >= ve_t - 1e-9  # reduction split may add VE work


def test_reduction_split_adds_trailing_group():
    tr = _trace()
    prog = compile_neuisa(tr)
    names = [g.op_name for g in prog.groups]
    assert "mm_small:reduce" in names
    i = names.index("mm_small:reduce")
    assert prog.groups[i].ve_utop is not None
    assert not prog.groups[i].me_utops


def test_conflicting_next_group_raises():
    g = MuTOpGroup(me_utops=[
        MuTOp(ME, 10.0, 0, "a", 0, next_group=0),
        MuTOp(ME, 10.0, 0, "a", 0, next_group=1),
    ])
    prog = NeuISAProgram("bad", [g, MuTOpGroup(ve_utop=MuTOp(VE, 1.0, 0, "b", 1))],
                         n_x=4, n_y=4)
    with pytest.raises(ValueError, match="conflicting"):
        prog.validate()


def test_snippet_sharing_bounds_code_size():
    """μTOps partitioned from one operator share a snippet."""
    prog = compile_neuisa(get_workload("BERT"))
    assert prog.code_inflation() > 1.5  # heavy sharing on 24 layers
    assert prog.n_snippets() < prog.n_utops() / 2


def test_vliw_work_conservation():
    tr = _trace()
    prog = compile_vliw(tr)
    me_t, ve_t, hbm_t = tr.totals()
    me_p, ve_p, hbm_p = prog.total_work()
    assert me_p == pytest.approx(me_t)
    assert ve_p == pytest.approx(ve_t)
    assert hbm_p == pytest.approx(hbm_t)


def test_neuisa_overhead_small():
    """Fig. 16: NeuISA costs <1% on average over VLIW for the paper's
    workload set (reduction splits are rare in real traces)."""
    overheads = []
    for name in ("BERT", "TFMR", "RsNt", "ENet", "DLRM"):
        t_vliw, t_neu = neuisa_overhead_terms(get_workload(name))
        overheads.append(t_neu / t_vliw - 1.0)
        assert t_neu >= t_vliw - 1e-9
    assert sum(overheads) / len(overheads) < 0.01


def test_loop_control_flow():
    core = DEFAULT_CORE
    tr = WorkloadTrace("loop", [
        matmul_op("body", 256, 512, 512, core),
        vector_op("tail", 1024, core),
    ], core=core)
    prog = compile_neuisa(tr)
    prog.with_loop(0, 0, trips=3)
    assert prog.loop_trips[0] == 3
    assert all(u.next_group == 0 for u in prog.groups[0].all_utops())
