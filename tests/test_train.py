"""Training substrate: convergence, checkpoint/resume exactness,
gradient compression, elastic restart, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (
    Checkpointer, list_checkpoints, restore_checkpoint, save_checkpoint)
from repro.checkpoint.elastic import plan_elastic_restart, reshard_state
from repro.configs import SMOKES
from repro.distributed.compress import (
    compress_tree, decompress_tree, init_residuals, quantize_ef)
from repro.distributed.fault import StragglerMonitor
from repro.train.loop import TrainConfig, train

CFG = SMOKES["qwen2-0.5b"]


def test_loss_decreases():
    out = train(CFG, TrainConfig(steps=12, batch=4, seq=64, peak_lr=1e-3,
                                 warmup=2, log_every=100),
                log_fn=lambda *_: None)
    first = np.mean(out["losses"][:3])
    last = np.mean(out["losses"][-3:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_resume_is_exact(tmp_path):
    """train(6) == train(3) + crash + resume(3): identical losses."""
    tc = dict(batch=2, seq=32, peak_lr=5e-4, warmup=2, log_every=100)
    full = train(CFG, TrainConfig(steps=6, **tc), log_fn=lambda *_: None)

    d = str(tmp_path / "ck")
    train(CFG, TrainConfig(steps=3, ckpt_dir=d, ckpt_every=3, **tc),
          log_fn=lambda *_: None)
    resumed = train(CFG, TrainConfig(steps=6, ckpt_dir=d, ckpt_every=3, **tc),
                    log_fn=lambda *_: None)
    np.testing.assert_allclose(full["losses"][3:], resumed["losses"],
                               rtol=1e-5)


def test_checkpoint_integrity_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    state = {"a": jnp.arange(8, dtype=jnp.float32),
             "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    ck = Checkpointer(d, keep=2)
    for s in (1, 2, 3):
        ck.save(s, state, blocking=True)
    assert list_checkpoints(d) == [2, 3]
    restored, step = restore_checkpoint(d, state)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(8, dtype=np.float32))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_under_partial_write(tmp_path):
    d = str(tmp_path / "ck")
    state = {"w": jnp.ones((4,))}
    save_checkpoint(d, 1, state)
    # simulate a crash mid-save: stray tmp dir must be ignored
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    restored, step = restore_checkpoint(d, state)
    assert step == 1


def test_quantize_error_feedback_unbiased():
    """With error feedback, accumulated dequantized grads converge to
    the true gradient sum."""
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (256,)) * 0.01
    resid = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        q, scale, resid = quantize_ef(g, resid)
        acc = acc + q.astype(jnp.float32) * scale
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                               atol=1e-4)


def test_compress_tree_roundtrip_small_error():
    key = jax.random.PRNGKey(1)
    grads = {"w": jax.random.normal(key, (32, 32)),
             "b": jax.random.normal(key, (32,)) * 10}
    resids = init_residuals(grads)
    q, s, r = compress_tree(grads, resids)
    out = decompress_tree(q, s)
    for k in grads:
        err = np.abs(np.asarray(out[k]) - np.asarray(grads[k])).max()
        scale = np.abs(np.asarray(grads[k])).max()
        assert err <= scale / 127 * 1.01


def test_elastic_plan():
    p = plan_elastic_restart(old_world=512, surviving=384,
                             model_parallel=16, global_batch=256,
                             last_step=1000)
    # 384/16 = 24 data shards, but 256 % 24 != 0 -> shrink to 16
    assert p.new_data_axis == 16
    assert p.new_world == 256
    assert 256 % p.new_data_axis == 0
    assert p.restart_step == 1000
    # exact-fit case
    p2 = plan_elastic_restart(512, 256, 16, 256, 0)
    assert p2.new_data_axis == 16
    with pytest.raises(RuntimeError, match="cannot rebuild"):
        plan_elastic_restart(512, 8, 16, 256, 0)


def test_reshard_state_roundtrip():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    state = {"w": np.arange(16, dtype=np.float32).reshape(4, 4)}
    spec = {"w": P(None, None)}
    placed = reshard_state(state, mesh, spec)
    np.testing.assert_array_equal(np.asarray(placed["w"]), state["w"])


def test_straggler_monitor():
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, grace=2)
    for step in range(6):
        for h in range(4):
            t = 1.0 if h != 3 else 3.0  # host 3 is slow
            mon.observe(step, h, t)
        mon.stragglers()
    assert mon.stragglers() == {3}
    # host 2 stops heartbeating; host 3 stays slow
    for step in range(6, 20):
        for h, t in ((0, 1.0), (1, 1.0), (3, 3.0)):
            mon.observe(step, h, t)
        mon.stragglers()
    assert 2 in mon.failed(19)
    assert set(mon.healthy_hosts(19)) == {0, 1}
    # a recovered straggler is healthy again
    for step in range(20, 30):
        for h in (0, 1, 3):
            mon.observe(step, h, 1.0)
        mon.stragglers()
    assert 3 in mon.healthy_hosts(29)
