"""Trace generation + cost model: paper-characterization properties
(Figs. 2-7) and model-FLOP consistency."""
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.npu.cost_model import matmul_op, memory_op, vector_op
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.trace import lm_trace, train_trace
from repro.npu.workloads import PAPER_PAIRS, WORKLOADS, get_workload


def test_paper_intensity_characterization():
    """Fig. 4/5: DLRM/NCF VE-intensive, BERT/ResNet ME-intensive,
    ENet mixed (the high-contention workload)."""
    prof = {n: get_workload(n).profile_mv() for n in WORKLOADS}
    assert prof["DLRM"][1] > prof["DLRM"][0]          # VE-heavy
    assert prof["NCF"][1] > 0.7
    assert prof["BERT"][0] > 0.9                      # ME-heavy
    assert prof["RsNt"][0] > 0.9
    assert abs(prof["ENet"][0] - prof["ENet"][1]) < 0.25  # contended
    for name, (m, v) in prof.items():
        assert m + v >= 1.0 - 1e-6, f"{name}: m+v < 1"


def test_pairs_cover_contention_classes():
    classes = {c for _, _, c in PAPER_PAIRS}
    assert classes == {"low", "medium", "high"}
    assert len(PAPER_PAIRS) == 9


@given(m=st.integers(1, 512), k=st.integers(1, 4096), n=st.integers(1, 4096))
@settings(max_examples=60, deadline=None)
def test_matmul_cycles_at_least_ideal(m, k, n):
    core = DEFAULT_CORE
    op = matmul_op("mm", m, k, n, core)
    ideal = 2.0 * m * k * n / core.me_flops_per_cycle
    assert op.me_cycles >= ideal * 0.99
    assert op.n_tiles >= 1


def test_decode_is_memory_paced():
    """Small-row matmuls stream weights at HBM rate (§V-F)."""
    core = DEFAULT_CORE
    op = matmul_op("mv", 8, 4096, 4096, core)
    w_stream_cycles = 4096 * 4096 * 2 / core.hbm_bytes_per_cycle
    assert op.me_cycles == pytest.approx(w_stream_cycles, rel=0.1)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_traces_well_formed(arch):
    cfg = ARCHS[arch]
    tr = lm_trace(cfg, batch=4, seq=256, phase="prefill")
    me, ve, hbm = tr.totals()
    assert me > 0 and ve > 0
    assert tr.hbm_footprint > 0
    m, v = tr.profile_mv()
    assert m + v >= 1.0 - 1e-6
    # decode phase is lighter per call and memory-heavier relatively
    td = lm_trace(cfg, batch=4, seq=256, phase="decode")
    assert td.ideal_cycles(4, 4) < tr.ideal_cycles(4, 4)


def test_trace_flops_track_param_count():
    """Prefill ME work should scale with active params: cycles *
    flops_per_cycle ~ 2 * N_active * tokens (within 2x: attention +
    drain overheads)."""
    core = DEFAULT_CORE
    for arch in ("qwen2-0.5b", "minicpm-2b"):
        cfg = ARCHS[arch]
        T = 4 * 512
        tr = lm_trace(cfg, 4, 512, "prefill", core)
        me, _, _ = tr.totals()
        flops = me * core.me_flops_per_cycle
        model_flops = 2.0 * cfg.active_param_count() * T
        assert 0.7 <= flops / model_flops <= 2.5, flops / model_flops


def test_train_trace_is_3x_forward():
    cfg = ARCHS["qwen2-0.5b"]
    fwd = lm_trace(cfg, 2, 128, "prefill")
    tr = train_trace(cfg, 2, 128)
    assert tr.totals()[0] == pytest.approx(3 * fwd.totals()[0], rel=0.01)
