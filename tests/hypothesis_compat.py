"""Import hypothesis, or degrade gracefully when it is absent.

The property tests are tier-2: on a box without ``hypothesis`` the
suite must still collect and run every example-based test, so this
module exports the real ``given``/``settings``/``st`` when available
and skipping stand-ins otherwise (each @given test then calls
``pytest.importorskip("hypothesis")`` and reports as skipped).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*a, **k):
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the stub tests never execute)."""

        def __getattr__(self, name):
            def strategy(*_a, **_k):
                return None
            return strategy

    st = _AnyStrategy()
