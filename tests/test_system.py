"""End-to-end behaviour tests for the full Neu10 system: workload ->
profile -> allocate -> map -> compile -> schedule -> report, plus the
paper's headline claims as regression gates (qualitative: our traces
are cost-model derived, not real-TPU profiles — see DESIGN.md §2)."""
import numpy as np
import pytest

from repro.core import (TenantSpec, VNPUConfig, VNPUManager,
                        compile_neuisa, compile_vliw, run_collocation)
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.workloads import PAPER_PAIRS, get_workload
from repro.serve.vserve import MultiTenantServer


def _pair_result(w1, w2, policy, n_requests=5):
    core = DEFAULT_CORE
    mgr = VNPUManager(core=core)
    mapping = "spatial" if policy.startswith("neu10") else "temporal"
    specs = []
    for name in (w1, w2):
        tr = get_workload(name, core)
        v = mgr.create(VNPUConfig(2, 2, hbm_bytes=min(
            int(tr.hbm_footprint), core.hbm_bytes // 2)), mapping=mapping)
        prog = (compile_neuisa(tr, core) if policy.startswith("neu10")
                else compile_vliw(tr, core))
        specs.append(TenantSpec(prog, v, n_requests))
    return run_collocation(specs, policy, core)


def test_full_stack_runs_all_pairs_all_policies():
    for w1, w2, _ in PAPER_PAIRS[:3]:
        for policy in ("pmt", "v10", "neu10_nh", "neu10"):
            res = _pair_result(w1, w2, policy, n_requests=3)
            assert all(t.requests_done >= 3 for t in res.tenants)


def test_headline_throughput_claim():
    """§V-B: Neu10 throughput > PMT on low-contention pairs (paper:
    1.62x average; we gate at >1.15x geomean)."""
    speedups = []
    for w1, w2, c in PAPER_PAIRS:
        if c != "low":
            continue
        pmt = _pair_result(w1, w2, "pmt")
        neu = _pair_result(w1, w2, "neu10")
        for i in range(2):
            speedups.append(neu.throughput(i) / max(pmt.throughput(i), 1e-9))
    g = float(np.exp(np.mean(np.log(speedups))))
    assert g > 1.15, f"geomean Neu10/PMT = {g:.2f}"


def test_headline_utilization_claim():
    """§V-C: Neu10 improves ME utilization over PMT (paper: 1.26x)."""
    ratios = []
    for w1, w2, _ in PAPER_PAIRS:
        pmt = _pair_result(w1, w2, "pmt")
        neu = _pair_result(w1, w2, "neu10")
        ratios.append(neu.me_utilization() / max(pmt.me_utilization(), 1e-9))
    assert float(np.mean(ratios)) > 1.1


def test_harvest_overhead_bounded():
    """Table III: blocked-because-harvested overhead is a minor
    fraction of end-to-end time, never dominant (paper worst 10.63%;
    our analytic traces have ~100x shorter ops -> more reclaim
    passes; blocked fraction scales as ctx/op-length. Gate at 25%;
    the benefit-outweighs-cost claim is gated in the benchmarks)."""
    for w1, w2, _ in PAPER_PAIRS[:4]:
        res = _pair_result(w1, w2, "neu10")
        for t in res.tenants:
            assert t.reclaim_blocked / res.makespan < 0.25


def test_control_plane_e2e():
    srv = MultiTenantServer(policy="neu10")
    srv.register("llm", get_workload("LLaMA"), eu_budget=4)
    srv.register("bert", get_workload("BERT"), eu_budget=4)
    res, reports = srv.simulate(n_requests=3)
    assert {r.name for r in reports} == {"llm", "bert"}
    assert all(np.isfinite(r.p95_ms) and r.p95_ms > 0 for r in reports)
