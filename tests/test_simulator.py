"""Event-driven simulator: conservation, isolation, harvesting, and
policy-ordering properties (§III-E / §V)."""
import pytest
from hypothesis_compat import given, settings, st

from repro.core.compiler import compile_neuisa, compile_vliw
from repro.core.mapper import VNPUManager
from repro.core.simulator import Simulator, TenantSpec
from repro.core.vnpu import VNPUConfig
from repro.npu.cost_model import Operator, WorkloadTrace, matmul_op, vector_op
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig
from repro.npu.workloads import get_workload


def _mk_tenants(traces, policy, core=DEFAULT_CORE, n_requests=4,
                me_ve=(2, 2)):
    mgr = VNPUManager(core=core)
    specs = []
    mapping = "spatial" if policy.startswith("neu10") else "temporal"
    for tr in traces:
        v = mgr.create(VNPUConfig(*me_ve, hbm_bytes=1 << 30), mapping=mapping)
        prog = (compile_neuisa(tr, core) if policy.startswith("neu10")
                else compile_vliw(tr, core))
        specs.append(TenantSpec(prog, v, n_requests))
    return specs


def _simple_trace(name="w", me=200_000.0, ve=50_000.0, n_ops=10):
    core = DEFAULT_CORE
    ops = []
    for i in range(n_ops):
        ops.append(Operator(f"{name}_mm{i}", me_cycles=me / n_ops,
                            ve_cycles=ve / n_ops, n_tiles=8))
    return WorkloadTrace(name, ops, core=core)


def test_single_tenant_work_conservation_and_bounds():
    core = DEFAULT_CORE
    tr = _simple_trace()
    specs = _mk_tenants([tr], "neu10", n_requests=3, me_ve=(4, 4))
    res = Simulator(specs, policy="neu10", core=core).run()
    me_t, ve_t, _ = tr.totals()
    assert res.tenants[0].requests_done >= 3
    n = res.tenants[0].requests_done
    assert res.tenants[0].me_work == pytest.approx(me_t * n, rel=1e-6)
    assert res.me_utilization() <= 1.0 + 1e-9
    assert res.ve_utilization() <= 1.0 + 1e-9
    # makespan can't beat the perfect-parallelism lower bound
    assert res.makespan >= 3 * tr.ideal_cycles(4, 4) * 0.999


def test_determinism():
    traces = [get_workload("ENet"), get_workload("TFMR")]
    r1 = Simulator(_mk_tenants(traces, "neu10"), policy="neu10").run()
    r2 = Simulator(_mk_tenants(traces, "neu10"), policy="neu10").run()
    assert r1.makespan == r2.makespan
    assert r1.tenants[0].latencies == r2.tenants[0].latencies


def test_spatial_isolation_no_harvest():
    """Under Neu10-NH with no memory traffic, a tenant's latency is
    unaffected by its neighbor (hardware isolation)."""
    solo = Simulator(_mk_tenants([_simple_trace("a")], "neu10_nh"),
                     policy="neu10_nh").run()
    pair = Simulator(
        _mk_tenants([_simple_trace("a"), _simple_trace("b", me=500_000)],
                    "neu10_nh"),
        policy="neu10_nh").run()
    assert pair.tenants[0].mean() == pytest.approx(solo.tenants[0].mean(),
                                                   rel=1e-6)


def test_harvesting_improves_on_static_partition():
    """Paper's core claim: Neu10 >= Neu10-NH when demands are
    imbalanced (ME-heavy next to VE-heavy)."""
    traces = [get_workload("RsNt"), get_workload("DLRM")]
    nh = Simulator(_mk_tenants(traces, "neu10_nh"), policy="neu10_nh").run()
    h = Simulator(_mk_tenants(traces, "neu10"), policy="neu10").run()
    assert h.makespan < nh.makespan
    assert (h.tenants[0].harvested_me_work
            + h.tenants[1].harvested_me_work) > 0


def test_harvest_does_not_break_owner():
    """Reclaim keeps the harvested-from tenant near its isolated
    performance (Table III: small blocked overhead)."""
    traces = [get_workload("RsNt"), get_workload("DLRM")]
    nh = Simulator(_mk_tenants(traces, "neu10_nh"), policy="neu10_nh").run()
    h = Simulator(_mk_tenants(traces, "neu10"), policy="neu10").run()
    # DLRM (the donor) must not slow down materially
    assert h.tenants[1].mean() <= nh.tenants[1].mean() * 1.15


def test_v10_false_contention_vs_neu10():
    """V10's whole-array ME ops serialize against each other; Neu10's
    μTOp scheduling removes the false contention for ME+ME pairs."""
    traces = [get_workload("RNRS"), get_workload("RtNt")]
    v10 = Simulator(_mk_tenants(traces, "v10"), policy="v10").run()
    neu = Simulator(_mk_tenants(traces, "neu10"), policy="neu10").run()
    assert neu.total_throughput() >= v10.total_throughput() * 0.95


def test_pmt_is_weakest_on_mixed_pairs():
    traces = [get_workload("BERT"), get_workload("ENet")]
    pmt = Simulator(_mk_tenants(traces, "pmt"), policy="pmt").run()
    neu = Simulator(_mk_tenants(traces, "neu10"), policy="neu10").run()
    assert neu.total_throughput() > pmt.total_throughput()


def test_hbm_contention_stretches():
    core = DEFAULT_CORE
    tr = WorkloadTrace("mem", [
        Operator("ld", ve_cycles=1000.0, hbm_bytes=50e6, n_tiles=1)
        for _ in range(5)
    ], core=core)
    fast = Simulator(_mk_tenants([tr], "neu10"), policy="neu10",
                     hbm_scale=1.0).run()
    slow = Simulator(_mk_tenants([tr], "neu10"), policy="neu10",
                     hbm_scale=0.5).run()
    assert slow.makespan > fast.makespan * 1.5


@given(
    n_ops=st.integers(1, 6),
    me=st.floats(1e3, 1e6),
    ve=st.floats(1e2, 1e5),
    tiles=st.integers(1, 16),
    policy=st.sampled_from(["pmt", "v10", "neu10_nh", "neu10"]),
)
@settings(max_examples=40, deadline=None)
def test_property_no_deadlock_and_conservation(n_ops, me, ve, tiles, policy):
    core = DEFAULT_CORE
    tr = WorkloadTrace("p", [
        Operator(f"op{i}", me_cycles=me, ve_cycles=ve, n_tiles=tiles)
        for i in range(n_ops)
    ], core=core)
    tr2 = WorkloadTrace("q", [
        Operator(f"op{i}", ve_cycles=ve * 2, n_tiles=1)
        for i in range(n_ops)
    ], core=core)
    specs = _mk_tenants([tr, tr2], policy, n_requests=2)
    res = Simulator(specs, policy=policy, core=core).run()
    assert all(t.requests_done >= 2 for t in res.tenants)
    assert res.me_utilization() <= 1.0 + 1e-9
    assert res.ve_utilization() <= 1.0 + 1e-9
    for t, tr_ in zip(res.tenants, (tr, tr2)):
        me_t, _, _ = tr_.totals()
        assert t.me_work >= me_t * 2 * 0.999  # all submitted work done
