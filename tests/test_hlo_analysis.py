"""Loop-trip-aware HLO metrics: unit tests on synthetic HLO text and
an end-to-end check against a live compiled module."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (
    _split_computations, collective_bytes, hlo_metrics)

SYNTH = """\
HloModule m

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %dot.1 = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %a = f32[8,16]{1,0} fusion(%p), kind=kLoop, calls=%fc.1
  %b = f32[16,8]{1,0} fusion(%p), kind=kLoop, calls=%fc.2
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%sum
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %c = s32[] constant(10)
  %lt = pred[] compare(%gte, %c), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,8] {
  %x = f32[8,16]{1,0} parameter(0)
  %w = (s32[], f32[8,8]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %dot.0 = f32[8,8]{1,0} dot(%x, %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[64,8]{1,0} all-gather(%dot.0), dimensions={0}
}
"""


def test_split_computations_nested_tuple_params():
    comps = _split_computations(SYNTH)
    assert set(comps) >= {"body.1", "cond.1", "main"}
    assert any(" dot(" in l for l in comps["body.1"])


def test_loop_multiplied_flops():
    m = hlo_metrics(SYNTH)
    # entry dot: 2*8*8*16 = 2048; body dot x10 trips = 20480
    assert m["hlo_flops"] == pytest.approx(2048 + 20480)


def test_loop_multiplied_collectives():
    c = collective_bytes(SYNTH)
    # all-reduce f32[8,8] = 256B * 2 (factor) * 10 trips = 5120
    assert c["all-reduce_bytes"] == pytest.approx(5120)
    # all-gather f32[64,8] = 2048B * 1
    assert c["all-gather_bytes"] == pytest.approx(2048)


def test_live_module_scan_scaling():
    """A scanned matmul's FLOPs must be counted trip_count times."""
    w = jnp.ones((32, 32), jnp.float32)

    def f(x):
        def body(c, _):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    hlo = jax.jit(f).lower(jnp.ones((32, 32))).compile().as_text()
    m = hlo_metrics(hlo)
    one = 2 * 32 * 32 * 32
    assert m["hlo_flops"] == pytest.approx(7 * one, rel=0.01)


BRANCH_SYNTH = """\
HloModule b

%br.1 (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
  %dot.9 = f32[4,4]{1,0} dot(%p, %p), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%br.2 (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4]{1,0} parameter(0)
  %cc = f32[4,4]{1,0} conditional(%pred, %x, %x), true_computation=%br.1, false_computation=%br.2
}
"""


def test_branch_scale():
    full = hlo_metrics(BRANCH_SYNTH, branch_scale=1.0)["hlo_flops"]
    half = hlo_metrics(BRANCH_SYNTH, branch_scale=0.5)["hlo_flops"]
    assert full == pytest.approx(2 * 4 * 4 * 4)
    assert half == pytest.approx(full / 2)
