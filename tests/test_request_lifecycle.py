"""Phase-aware request lifecycle: RequestPlan IR, the per-(phase,
context-bucket) program cache, phase chains + continuous batching in
the simulator, TTFT/TBT accounting, determinism under live
reconfigure, and the shared percentile helper."""
import math

import pytest

from repro.configs import SMOKES
from repro.core.compiler import ProgramCache, compile_request_plan
from repro.core.stats import mean, p50, p95, p99, percentile
from repro.core.simulator import SimResult, Simulator, TenantStats
from repro.npu.cost_model import (Operator, RequestPlan, WorkloadTrace,
                                  decode_bucket)
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.trace import lm_trace, request_plan
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, ServingSession)

CFG = SMOKES["qwen2-0.5b"]


def _session(policy="neu10", **kw):
    return ServingSession(NPUCluster(policy=policy), **kw)


def _gen_tenant(sess, name="g", prompt_len=128, gen=8, max_gen=0, **kw):
    gl = (GenLenDistribution(mean=gen, max_len=max_gen, seed=5)
          if max_gen else gen)
    return sess.register_generative(name, CFG, prompt_len=prompt_len,
                                    gen_lens=gl, eu_budget=4, **kw)


# ----------------------------------------------------------------------
# RequestPlan IR (trace / cost-model layer)
# ----------------------------------------------------------------------
def test_decode_bucket_powers_of_two():
    assert decode_bucket(1) == 512
    assert decode_bucket(512) == 512
    assert decode_bucket(513) == 1024
    assert decode_bucket(2049) == 4096
    assert decode_bucket(300, base=256) == 512


def test_request_plan_buckets_cover_generation():
    plan = request_plan(CFG, batch=1, prompt_len=500, gen_len=64,
                        max_gen=2048)
    assert plan.prefill.name.endswith(":prefill:b1s500")
    ctxs = [c for c, _ in plan.decode]
    # buckets double from the first decode context to prompt+max_gen
    assert ctxs[0] == decode_bucket(502)
    assert ctxs[-1] >= 500 + 2048
    assert all(b == a * 2 for a, b in zip(ctxs, ctxs[1:]))
    # each bucket's trace is a decode trace at the bucket ceiling
    for ctx, tr in plan.decode:
        assert f":decode:b1s{ctx}" in tr.name
    # a step at any live context resolves to a covering bucket
    ctx, _ = plan.decode_trace_for(501)
    assert ctx == ctxs[0]
    ctx, _ = plan.decode_trace_for(10 ** 9)   # clamps to largest
    assert ctx == ctxs[-1]


def test_request_plan_decode_steps_and_profile():
    plan = request_plan(CFG, batch=1, prompt_len=128, gen_len=16)
    assert plan.decode_steps() == 15          # prefill emits token 1
    assert plan.decode_steps(1) == 0
    prof = plan.profile_trace()
    # profile = prefill ops + gen-weighted decode ops: its ME/VE totals
    # must dominate the bare prefill's
    me_p, ve_p, _ = plan.prefill.totals()
    me, ve, _ = prof.totals()
    assert me > me_p and ve > ve_p
    m, v = prof.profile_mv()
    assert 0 < m <= 1 and 0 < v <= 1


def test_single_phase_plan_is_degenerate():
    tr = lm_trace(CFG, 4, 256, "prefill")
    plan = RequestPlan(name="p", prefill=tr, prompt_len=256, gen_len=1)
    assert not plan.has_decode
    assert plan.decode_steps() == 0
    assert plan.hbm_footprint == tr.hbm_footprint
    with pytest.raises(ValueError, match="no decode phases"):
        plan.decode_trace_for(300)


def test_lifecycle_guards():
    """Misuse fails loudly: a multi-token request on a tenant without
    decode phases, and half-built TenantSpecs."""
    from repro.core.simulator import TenantSpec

    sess = _session()
    h = sess.register("w", lm_trace(CFG, 2, 256, "prefill"), eu_budget=4)
    with pytest.raises(ValueError, match="no decode phases"):
        sess.submit(h, gen_len=8)
    with pytest.raises(ValueError, match="gen_len"):
        sess.sim.inject_request(h.sim_idx, sess.sim.now, gen_len=0)
    with pytest.raises(ValueError, match="program or a plan"):
        TenantSpec(vnpu=h.vnpu)
    with pytest.raises(ValueError, match="vnpu"):
        TenantSpec(program=sess.cluster.compile(h.trace))


# ----------------------------------------------------------------------
# compiler: per-(phase, bucket) program cache
# ----------------------------------------------------------------------
def test_program_cache_shares_decode_buckets():
    cache = ProgramCache()
    plan = request_plan(CFG, batch=1, prompt_len=128, gen_len=32,
                        max_gen=1024)
    c1 = compile_request_plan(plan, DEFAULT_CORE, isa="neuisa", cache=cache)
    misses = cache.misses
    assert misses == 1 + len(plan.decode)
    # a second tenant with the same shape compiles NOTHING new
    c2 = compile_request_plan(plan, DEFAULT_CORE, isa="neuisa", cache=cache)
    assert cache.misses == misses
    assert cache.hits == misses
    for a, b in zip(c1.decode, c2.decode):
        assert a.program is b.program        # shared, not re-built
    # ... but a different ISA (policy front-end) does
    compile_request_plan(plan, DEFAULT_CORE, isa="vliw", cache=cache)
    assert cache.misses == 2 * misses


def test_program_cache_fingerprints_trace_content():
    """Two traces sharing a name but with different op costs must not
    collide in the cache."""
    cache = ProgramCache()
    a = WorkloadTrace("same", [Operator("mm", me_cycles=1000.0, n_tiles=4)],
                      core=DEFAULT_CORE)
    b = WorkloadTrace("same", [Operator("mm", me_cycles=9000.0, n_tiles=4)],
                      core=DEFAULT_CORE)
    pa = cache.compile(a, DEFAULT_CORE, "neuisa")
    pb = cache.compile(b, DEFAULT_CORE, "neuisa")
    assert pa is not pb
    assert cache.misses == 2 and cache.hits == 0


def test_cluster_program_cache_shared_across_tenants():
    cluster = NPUCluster(policy="neu10")
    sess = ServingSession(cluster)
    sess.register_generative("a", CFG, prompt_len=128, gen_lens=8,
                             eu_budget=2)
    baseline = len(cluster.programs)
    sess.register_generative("b", CFG, prompt_len=128, gen_lens=8,
                             eu_budget=2)
    assert len(cluster.programs) == baseline  # all programs reused
    assert cluster.programs.hits >= baseline


# ----------------------------------------------------------------------
# simulator: phase chains + continuous batching
# ----------------------------------------------------------------------
def test_phase_chain_token_accounting():
    sess = _session()
    h = _gen_tenant(sess, gen=8)
    sess.submit(h, at_s=0.0)
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.requests_done == 1
    assert st.tokens == 8                      # 1 prefill + 7 decode
    assert len(st.ttft) == 1 and len(st.tbt) == 7
    assert st.decode_iterations == 7
    # TTFT < e2e, and e2e == TTFT + sum(TBT) for a lone request
    assert st.ttft[0] < st.latencies[0]
    assert st.latencies[0] == pytest.approx(st.ttft[0] + sum(st.tbt))


def test_continuous_batching_coalesces_decodes():
    sess = _session()
    h = _gen_tenant(sess, gen=12)
    for _ in range(3):
        sess.submit(h, at_s=0.0)               # 3 simultaneous arrivals
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.requests_done == 3
    assert st.max_decode_batch >= 2            # shared decode iterations
    # coalescing means far fewer iterations than per-request steps
    assert st.decode_iterations < 3 * 11
    assert st.tokens == 3 * 12


def test_gen_len_distribution_varies_requests():
    sess = _session()
    h = _gen_tenant(sess, gen=16, max_gen=64)
    sess.submit_arrivals(h, PoissonArrivals(rate_rps=500.0, n=12, seed=2))
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.requests_done == 12
    # geometric lengths: not all requests emitted the same token count
    assert st.tokens != 12 * 16
    r = sess.report(h)[0]
    assert r.tokens_done == st.tokens
    assert r.ttft_p95_ms > 0 and r.tbt_p95_ms > 0


def test_single_phase_tenants_unchanged_by_lifecycle():
    """A plain trace registration is the degenerate one-phase plan:
    per-request latencies equal the seed semantics (TTFT == e2e, no
    decode iterations)."""
    tr = WorkloadTrace("w", [
        Operator(f"mm{i}", me_cycles=20_000.0, ve_cycles=5_000.0, n_tiles=8)
        for i in range(6)
    ], core=DEFAULT_CORE)
    sess = _session()
    h = sess.register("w", tr, eu_budget=4)
    sess.submit_arrivals(h, PoissonArrivals(rate_rps=1000.0, n=10, seed=1))
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.requests_done == 10
    assert st.decode_iterations == 0
    assert st.ttft == st.latencies
    assert st.tbt == []


def test_phase_kind_reaches_policy_chunks():
    """SchedulerPolicy dispatch sees the phase kind on every chunk."""
    seen = set()

    sess = _session()
    h = _gen_tenant(sess, gen=4)
    sim = sess.sim
    orig = sim.dispatch

    def spy(chunk, engines, t, harvested=False):
        seen.add(chunk.phase)
        return orig(chunk, engines, t, harvested)

    sim.dispatch = spy
    sess.submit(h, at_s=0.0)
    sess.drain()
    assert {"prefill", "decode"} <= seen


def test_closed_loop_replays_full_phase_chain():
    """run_closed_loop (and the MultiTenantServer shim) replay a
    generative tenant's prefill+decode chain per request — with
    request concurrency 1, decode steps never coalesce."""
    from repro.serve.session import run_closed_loop

    cluster = NPUCluster(policy="neu10")
    cluster.register_generative("chat", CFG, prompt_len=128, gen_lens=8,
                                eu_budget=4)
    res, reports = run_closed_loop(cluster, n_requests=3)
    st = res.tenants[0]
    assert st.requests_done >= 3
    assert st.tokens == st.requests_done * 8
    assert st.max_decode_batch == 1
    assert reports[0].ttft_p95_ms > 0 and reports[0].tbt_p95_ms > 0
    assert reports[0].ttft_p95_ms < reports[0].p95_ms


# ----------------------------------------------------------------------
# satellite: open-loop determinism incl. live reconfigure mid-decode
# ----------------------------------------------------------------------
def _lifecycle_run():
    sess = _session()
    a = _gen_tenant(sess, "a", gen=16, max_gen=48)
    b = sess.register("b", lm_trace(CFG, 2, 256, "prefill"), eu_budget=2)
    sess.submit_arrivals(a, PoissonArrivals(rate_rps=3000.0, n=20, seed=7))
    sess.submit_arrivals(b, PoissonArrivals(rate_rps=2000.0, n=10, seed=8))
    sess.run_until(0.002)
    st = sess.sim.tenants[a.sim_idx].stats
    assert st.decode_iterations > 0            # resize lands mid-decode
    sess.resize(a, 6)
    sess.run_until(0.004)
    sess.drain()
    out = []
    for h in (a, b):
        s = sess.sim.tenants[h.sim_idx].stats
        out.append((s.latencies, s.completions, s.ttft, s.tbt,
                    s.requests_done, s.tokens, s.max_decode_batch,
                    s.preemptions))
    return out


def test_open_loop_determinism_with_reconfigure():
    """Same arrivals + seed => identical completion order and stats
    across two runs, including a live resize mid-decode."""
    assert _lifecycle_run() == _lifecycle_run()


# ----------------------------------------------------------------------
# satellite: zero-makespan / empty-run guards
# ----------------------------------------------------------------------
def test_zero_makespan_division_guards():
    res = SimResult(policy="neu10", makespan=0.0, tenants=[],
                    n_me=4, n_ve=4, freq_hz=1e9)
    assert res.me_utilization() == 0.0
    assert res.ve_utilization() == 0.0
    assert res.total_throughput() == 0.0
    res2 = SimResult(policy="neu10", makespan=0.0,
                     tenants=[TenantStats(name="t")],
                     n_me=4, n_ve=4, freq_hz=1e9)
    assert res2.throughput(0) == 0.0


def test_empty_open_loop_run_reports_cleanly():
    sim = Simulator((), policy="neu10")
    sim.run_until(1000.0)
    res = sim.result()
    assert res.me_utilization() == 0.0
    assert res.ve_utilization() == 0.0
    sess = _session()
    h = _gen_tenant(sess, gen=4)
    r = sess.report(h)[0]                      # no traffic yet
    assert r.p95_ms == 0.0 and r.throughput_rps == 0.0
    assert r.ttft_p95_ms == 0.0 and r.tbt_p95_ms == 0.0


# ----------------------------------------------------------------------
# satellite: shared percentile helper
# ----------------------------------------------------------------------
def test_percentile_matches_seed_convention():
    """percentile() reproduces the seed's TenantStats.p95 nearest-rank
    index arithmetic exactly."""
    for n in (1, 2, 5, 19, 20, 100):
        xs = [float((7 * i) % n) for i in range(n)]
        ys = sorted(xs)
        i = min(len(ys) - 1, max(0, math.ceil(0.95 * len(ys)) - 1))
        assert percentile(xs, 0.95) == ys[i]
    assert percentile([], 0.95) == 0.0
    assert p50([1.0, 2.0, 3.0]) == 2.0
    assert p95(list(map(float, range(1, 101)))) == 95.0
    assert p99(list(map(float, range(1, 101)))) == 99.0
    assert mean([2.0, 4.0]) == 3.0
    assert mean([]) == 0.0
    with pytest.raises(ValueError):
        percentile([1.0], 1.5)


def test_tenant_stats_use_shared_percentile():
    st = TenantStats(name="t", latencies=[5.0, 1.0, 3.0],
                     ttft=[2.0, 4.0], tbt=[0.5, 0.25])
    assert st.p95() == percentile(st.latencies, 0.95)
    assert st.ttft_p95() == percentile(st.ttft, 0.95)
    assert st.tbt_p95() == percentile(st.tbt, 0.95)
