"""Vectorized fluid simulator vs the discrete-event oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TenantSpec, VNPUConfig, VNPUManager, compile_neuisa)
from repro.core.sim_jax import (fleet_sweep, pack_pair, simulate_pair,
                                sweep_collocations)
from repro.core.simulator import Simulator
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.workloads import get_workload

PAIRS = [("RsNt", "DLRM"), ("BERT", "ENet"), ("ENet", "TFMR")]


def _oracle(w1, w2, policy, n_requests=4):
    core = DEFAULT_CORE
    mgr = VNPUManager(core=core)
    specs = []
    for name in (w1, w2):
        v = mgr.create(VNPUConfig(2, 2, hbm_bytes=1 << 30))
        specs.append(TenantSpec(compile_neuisa(get_workload(name, core),
                                               core), v, n_requests))
    return Simulator(specs, policy=policy, core=core).run()


def _fluid(w1, w2, harvest, n_requests=4):
    core = DEFAULT_CORE
    p1 = compile_neuisa(get_workload(w1, core), core)
    p2 = compile_neuisa(get_workload(w2, core), core)
    return simulate_pair(pack_pair(p1, p2), jnp.array([2.0, 2.0]),
                         jnp.array([2.0, 2.0]), n_requests,
                         harvest=harvest, core=core)


@pytest.mark.parametrize("pair", PAIRS)
def test_fluid_matches_oracle_without_harvest(pair):
    """With static partitions the fluid group model is EXACT (same
    group spans, no scheduling friction to approximate)."""
    oracle = _oracle(*pair, "neu10_nh")
    fluid = _fluid(*pair, False)
    ratio = float(fluid["makespan"]) / oracle.makespan
    assert 0.98 < ratio < 1.02, (pair, ratio)


@pytest.mark.parametrize("pair", PAIRS)
def test_fluid_harvest_is_optimistic_bound(pair):
    """Fluid harvesting re-partitions engines continuously with zero
    preemption cost -> a LOWER bound on makespan (upper bound on the
    collocation benefit), within ~2x of the discrete oracle. That is
    the intended fleet-screening semantic."""
    oracle = _oracle(*pair, "neu10")
    fluid = _fluid(*pair, True)
    ratio = float(fluid["makespan"]) / oracle.makespan
    assert 0.45 < ratio <= 1.02, (pair, ratio)


def test_fluid_preserves_policy_ordering():
    for pair in PAIRS:
        h = float(_fluid(*pair, True)["makespan"])
        nh = float(_fluid(*pair, False)["makespan"])
        assert h <= nh * 1.02


def test_fleet_sweep_one_program():
    core = DEFAULT_CORE
    progs = [
        (compile_neuisa(get_workload(a, core), core),
         compile_neuisa(get_workload(b, core), core))
        for a, b in PAIRS
    ]
    out = fleet_sweep(progs, hbm_scales=(0.75, 1.0, 2.0), n_requests=3)
    assert out["makespan"].shape == (3, 3)   # pairs x scales
    assert bool(jnp.all(out["makespan"] > 0))
    assert bool(jnp.all(out["me_util"] <= 1.0 + 1e-6))
    # more bandwidth never slows a fleet cell down
    ms = np.asarray(out["makespan"])
    assert np.all(ms[:, 0] >= ms[:, 2] * 0.999)


def test_sweep_collocations_matches_oracle_and_orders():
    """The 3-level (pair × split × bandwidth) collocation sweep:
    the no-harvest half-split column is EXACT against the discrete
    oracle per pair (same guarantee as simulate_pair — it's the same
    kernel under two more vmap levels), so the sweep RANKS pairs the
    way the oracle does; bandwidth stays monotone along its axis."""
    core = DEFAULT_CORE
    progs = [
        (compile_neuisa(get_workload(a, core), core),
         compile_neuisa(get_workload(b, core), core))
        for a, b in PAIRS
    ]
    splits = (((2, 2), (2, 2)), ((3, 1), (3, 1)))
    out = sweep_collocations(progs, splits, bw_points=(0.75, 1.0, 2.0),
                             n_requests=4, harvest=False, core=core)
    assert out["makespan"].shape == (len(PAIRS), len(splits), 3)
    oracle_ms = [_oracle(*p, "neu10_nh").makespan for p in PAIRS]
    sweep_ms = np.asarray(out["makespan"])[:, 0, 1]   # half split, bw=1
    for pair, o, s in zip(PAIRS, oracle_ms, sweep_ms):
        assert 0.98 < float(s) / o < 1.02, (pair, float(s) / o)
    # capacity-planning semantics: pair ranking == oracle ranking
    assert (np.argsort(oracle_ms).tolist()
            == np.argsort(sweep_ms).tolist())
    # more bandwidth never slows any cell down
    ms = np.asarray(out["makespan"])
    assert np.all(ms[:, :, 0] >= ms[:, :, 2] * 0.999)
