"""Scheduler policy registry: resolution, extension, and exact
behavioral equivalence of the four built-ins with the pre-registry
simulator (golden values captured from the seed implementation)."""
import pytest

from benchmarks.common import run_pair
from repro.core import policies as pol
from repro.core.policies import (SchedulerPolicy, UnknownPolicyError,
                                 available_policies, get_policy,
                                 register_policy, resolve_policy)
from repro.core.simulator import Simulator, TenantSpec
from repro.core.mapper import VNPUManager
from repro.core.vnpu import VNPUConfig
from repro.npu.cost_model import Operator, WorkloadTrace
from repro.npu.hw_config import DEFAULT_CORE


def test_builtins_registered():
    assert {"pmt", "v10", "neu10_nh", "neu10"} <= set(available_policies())


def test_unknown_policy_error_names_available():
    with pytest.raises(UnknownPolicyError) as ei:
        get_policy("nope")
    msg = str(ei.value)
    assert "nope" in msg and "neu10" in msg
    with pytest.raises(UnknownPolicyError):
        Simulator((), policy="also_nope")


def test_resolve_accepts_name_class_instance():
    cls = get_policy("neu10")
    assert resolve_policy("neu10").name == "neu10"
    assert resolve_policy(cls).name == "neu10"
    inst = cls()
    assert resolve_policy(inst) is inst
    with pytest.raises(TypeError):
        resolve_policy(42)


def test_policy_declares_mapping_and_isa():
    assert get_policy("neu10").spatial is True
    assert get_policy("neu10").isa == "neuisa"
    assert get_policy("pmt").spatial is False
    assert get_policy("pmt").isa == "vliw"
    assert resolve_policy("v10").mapping == "temporal"
    assert resolve_policy("neu10_nh").mapping == "spatial"


# ----------------------------------------------------------------------
# Exact equivalence with the pre-registry Simulator (seed commit),
# captured on the fixed BERT+DLRM §V-A scenario with n_requests=4.
# ----------------------------------------------------------------------
GOLDEN = {
    #            makespan             p95(tenant0)         p95(tenant1)
    "pmt":      (533531764.55165005, 133509284.63199885, 20028.07200000435),
    "v10":      (344284417.11341304, 86178433.86729868, 22076.07200000435),
    "neu10_nh": (498662424.576, 124665606.144, 19361.45562505722),
    "neu10":    (308027475.27508926, 77007456.66018084, 20641.455624997616),
}


@pytest.mark.parametrize("policy", sorted(GOLDEN))
def test_builtin_matches_pre_refactor_simulator(policy):
    makespan, p95_a, p95_b = GOLDEN[policy]
    res = run_pair("BERT", "DLRM", policy, n_requests=4)
    assert res.makespan == pytest.approx(makespan, rel=1e-9)
    assert res.tenants[0].p95() == pytest.approx(p95_a, rel=1e-9)
    assert res.tenants[1].p95() == pytest.approx(p95_b, rel=1e-9)


def test_policy_state_fresh_per_simulator():
    """Stateful policies (PMT's core-holder) must not leak across
    runs: back-to-back identical simulations give identical results."""
    r1 = run_pair("BERT", "ENet", "pmt", n_requests=3)
    r2 = run_pair("BERT", "ENet", "pmt", n_requests=3)
    assert r1.makespan == r2.makespan
    assert r1.tenants[0].latencies == r2.tenants[0].latencies


# ----------------------------------------------------------------------
# A fifth policy, registered from OUTSIDE repro.core.
# ----------------------------------------------------------------------
def _toy_trace(name="toy", n_ops=6):
    return WorkloadTrace(name, [
        Operator(f"{name}_mm{i}", me_cycles=20_000.0, ve_cycles=5_000.0,
                 n_tiles=4)
        for i in range(n_ops)
    ], core=DEFAULT_CORE)


def test_third_party_policy_registers_and_runs():
    @register_policy("toy_rr")
    class ToyRoundRobin(SchedulerPolicy):
        """Whole-core round robin: one operator per turn, no
        preemption (a strictly-FIFO variant would starve its neighbor
        in closed loop — tenants re-issue work instantly)."""

        spatial = False
        isa = "vliw"

        def __init__(self):
            self._next = 0

        def schedule(self, sim, t):
            if any(not e.free for e in sim.mes + sim.ves):
                return
            tenants = sim.active_tenants()
            for k in range(len(tenants)):
                rt = tenants[(self._next + k) % len(tenants)]
                if rt.ready_me or rt.ready_ve:
                    self._next = (self._next + k + 1) % len(tenants)
                    if rt.ready_me:
                        sim.dispatch(rt.ready_me.pop(0), list(sim.mes), t)
                    else:
                        sim.dispatch(rt.ready_ve.pop(0), list(sim.ves), t)
                    return

    try:
        assert "toy_rr" in available_policies()
        mgr = VNPUManager(core=DEFAULT_CORE)
        specs = []
        for name in ("a", "b"):
            v = mgr.create(VNPUConfig(2, 2, hbm_bytes=1 << 30),
                           name=name, mapping=ToyRoundRobin().mapping)
            prog = ToyRoundRobin.compile_program(_toy_trace(name), DEFAULT_CORE)
            specs.append(TenantSpec(prog, v, n_requests=3))
        res = Simulator(specs, policy="toy_rr", core=DEFAULT_CORE).run()
        assert res.policy == "toy_rr"
        assert all(t.requests_done >= 3 for t in res.tenants)
        assert res.me_utilization() <= 1.0 + 1e-9
        # and the whole benchmark harness accepts it by name
        res2 = run_pair("MNIST", "MNIST", "toy_rr", n_requests=2)
        assert all(t.requests_done >= 2 for t in res2.tenants)
    finally:
        pol._REGISTRY.pop("toy_rr", None)


def test_register_rejects_non_policy():
    with pytest.raises(TypeError):
        register_policy("bad")(int)
