"""Fault injection + failover: schedule determinism, fabric / mapper
fault primitives, whole-vNPU evacuation round-trips, kill-and-restart
suspension with deadline/retry re-admission, graceful HBM degradation,
fault-free golden bit-identity across all three engines, and a
property suite interleaving chaos with arrivals / resizes / borrows."""
import pytest

from repro.configs import SMOKES
from repro.core.fabric import FabricLink, FabricTopology
from repro.core.faults import FaultEvent, FaultSchedule
from repro.core.mapper import VNPUManager
from repro.core.vnpu import KVLedgerError, VNPUConfig
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import (NPUCluster, PoissonArrivals,
                                 PrefixProfile, ServingSession)
from tests.hypothesis_compat import given, settings, st

CFG = SMOKES["qwen2-0.5b"]
SEG = 64 * 1024
CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)
LINK = FabricLink(bandwidth=16.0, latency=400_000.0)
HBM = 256 * SEG


# ----------------------------------------------------------------------
# FaultEvent / FaultSchedule: validation + seeded determinism
# ----------------------------------------------------------------------
def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(at=0.0, kind="meteor")
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent(at=-1.0, kind="core_down", core=0)
    with pytest.raises(ValueError, match="needs a core"):
        FaultEvent(at=0.0, kind="core_down")
    with pytest.raises(ValueError, match="link"):
        FaultEvent(at=0.0, kind="link_degrade", link=(2, 2))
    with pytest.raises(ValueError, match="n_segments"):
        FaultEvent(at=0.0, kind="hbm_fault", core=0, n_segments=0)
    assert FaultEvent(at=1.0, kind="core_down", core=0,
                      recovery=2.0).transient
    assert not FaultEvent(at=1.0, kind="core_down", core=0).transient


def test_chaos_schedule_is_seed_deterministic():
    kw = dict(horizon=10.0, n_cores=4, links=[(0, 1), (1, 2)],
              core_fault_rate=3.0, link_fault_rate=2.0,
              hbm_fault_rate=1.0, transient_frac=0.5, recovery=1.0)
    a = FaultSchedule.chaos(seed=7, **kw)
    b = FaultSchedule.chaos(seed=7, **kw)
    assert list(a) == list(b)
    assert list(a) != list(FaultSchedule.chaos(seed=8, **kw))
    assert all(0.0 <= ev.at < 10.0 for ev in a)
    assert all(ev.at <= nxt.at for ev, nxt in zip(a.events, a.events[1:]))
    # every degrade injected with a matching restore inside the window
    degrades = sum(ev.kind == "link_degrade" for ev in a)
    restores = sum(ev.kind == "link_restore" for ev in a)
    assert restores == degrades


# ----------------------------------------------------------------------
# fabric link faults
# ----------------------------------------------------------------------
def test_link_degrade_outage_restore():
    topo = FabricTopology.ring(4, LINK)
    base = topo.transfer_cycles(0, 1, 1 << 20)
    topo.degrade_link(0, 1, 0.25)
    assert topo.transfer_cycles(0, 1, 1 << 20) > base
    topo.restore_link(0, 1)
    assert topo.transfer_cycles(0, 1, 1 << 20) == base
    # outage removes the link: the ring reroutes the long way around
    hops_before = topo.hops(0, 1)
    topo.degrade_link(0, 1, 0.0)
    assert topo.hops(0, 1) > hops_before
    topo.restore_link(0, 1)
    assert topo.hops(0, 1) == hops_before
    with pytest.raises(ValueError):
        topo.degrade_link(0, 2, 0.5)       # not a ring edge


# ----------------------------------------------------------------------
# mapper fault primitives
# ----------------------------------------------------------------------
def test_fail_core_restore_and_placement():
    man = VNPUManager(n_pnpus=2, core=CORE)
    v = man.create(VNPUConfig(1, 1, hbm_bytes=4 * SEG), core_hint=0)
    assert man.fail_core(0) == [v.vnpu_id]
    assert man.healthy_cores() == [1]
    w = man.create(VNPUConfig(1, 1, hbm_bytes=4 * SEG))
    assert man.core_index_of(w) == 1       # failed core never placed on
    with pytest.raises(RuntimeError):
        man.create(VNPUConfig(1, 1, hbm_bytes=4 * SEG), core_hint=0)
    man.restore_core(0)
    assert man.healthy_cores() == [0, 1]


def test_hbm_fault_conserves_census_and_guards_occupancy():
    man = VNPUManager(core=CORE)
    total = CORE.hbm_bytes // CORE.hbm_segment
    v = man.create(VNPUConfig(1, 1, hbm_bytes=4 * SEG))
    v.kv_ledger.alloc(1, 3 * SEG)
    with pytest.raises(KVLedgerError, match="evict first"):
        man.fault_hbm_segments(v, 2)       # 3 live segs can't fit in 2
    free0, res0, flt0, tot0 = man.hbm_census()[0]
    assert (free0, res0, flt0, tot0) == (total - 4, 4, 0, total)
    v.kv_ledger.free(1)
    assert man.fault_hbm_segments(v, 2) == 2 * SEG
    assert v.kv_ledger.capacity == 2 * SEG
    free1, res1, flt1, tot1 = man.hbm_census()[0]
    assert (free1, res1, flt1) == (total - 4, 2, 2)
    assert free1 + res1 + flt1 == tot1     # parked, not leaked
    # free-pool faults clamp and conserve too
    assert man.fault_free_hbm_segments(0, total) == total - 4
    f, r, x, tt = man.hbm_census()[0]
    assert (f, r) == (0, 2) and f + r + x == tt


# ----------------------------------------------------------------------
# session failover: shared scenario helpers
# ----------------------------------------------------------------------
def _serve(faults=None, failover="evacuate", n_cores=4, retry=True,
           deadline_ms=50.0, max_retries=3, engine="inc", **reg_kw):
    topo = FabricTopology.mesh(n_cores, LINK)
    cluster = NPUCluster(core=CORE, policy="neu10", topology=topo)
    sess = ServingSession(cluster, faults=faults, failover=failover,
                          incremental=(engine != "full"))
    if engine == "ref":
        for s in sess.sims:
            s.fast_path = False
    kw = dict(deadline_ms=deadline_ms, max_retries=max_retries,
              retry_backoff_ms=0.05) if retry else {}
    kw.update(reg_kw)
    chat = sess.register_generative(
        "chat", CFG, prompt_len=256, gen_lens=32, eu_budget=4,
        kv_policy="evict", hbm_bytes=HBM, **kw)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=100_000.0,
                                               n=24, seed=1))
    sess.drain()
    return sess, chat, sess.report(chat)[0]


def _census_ok(sess):
    return all(f + r + x == t
               for f, r, x, t in sess.cluster.manager.hbm_census())


TRANSIENT = FaultSchedule([FaultEvent(at=0.0002, kind="core_down",
                                      core=0, recovery=0.002)])


def test_evacuation_round_trip():
    sess, chat, r = _serve(TRANSIENT)
    assert r.requests_done == 24
    assert r.evacuations == 1 and r.faults_survived >= 1
    assert chat.core_idx != 0              # really moved off core 0
    assert r.evacuated_bytes > 0
    assert r.downtime_ms > 0 and r.availability < 1.0
    led = chat.vnpu.kv_ledger
    assert led.in_use == 0 and led.shared_in_use == 0
    assert _census_ok(sess)


def test_permanent_core_fault_evacuates_and_completes():
    sch = FaultSchedule([FaultEvent(at=0.0002, kind="core_down", core=0)])
    sess, chat, r = _serve(sch)
    assert r.requests_done == 24 and r.evacuations == 1
    assert 0 not in sess.cluster.manager.healthy_cores()
    assert _census_ok(sess)


def test_restart_failover_resumes_and_retries_to_completion():
    sess, chat, r = _serve(TRANSIENT, failover="restart")
    assert r.requests_done == 24
    assert r.evacuations == 0
    assert chat.core_idx == 0              # resumed on the home core
    assert r.retries >= 1 and r.retry_successes >= 1
    assert r.faults_survived >= 1          # the suspend/resume round-trip
    assert r.downtime_ms >= 2.0            # parked the whole outage
    assert _census_ok(sess)


def test_restart_without_retry_budget_drops_aborted_work():
    sess, chat, r = _serve(TRANSIENT, failover="restart", retry=False)
    assert r.requests_done < 24            # fault-aborted work is lost...
    assert r.retries == 0
    led = chat.vnpu.kv_ledger
    assert led.in_use == 0                 # ...but never leaked
    assert _census_ok(sess)


def test_evacuation_beats_restart_on_tail_latency():
    _, _, ev = _serve(TRANSIENT, failover="evacuate")
    _, _, rs = _serve(TRANSIENT, failover="restart")
    assert rs.p95_ms / ev.p95_ms >= 1.3


def test_deadline_misses_and_retry_exhaustion_are_counted():
    # a 2 ms outage under restart failover floods admission at resume;
    # with a 0.05 ms per-attempt deadline the backlogged tail expires,
    # re-enters once (max_retries=1), and the unlucky rest drop —
    # every path counted, nothing leaked
    sess, chat, r = _serve(TRANSIENT, failover="restart",
                           deadline_ms=0.05, max_retries=1)
    assert r.deadline_misses >= 1
    assert r.retries >= 1 and r.retry_successes >= 1
    assert r.retries_exhausted >= 1
    assert r.requests_done + r.retries_exhausted == 24
    assert chat.vnpu.kv_ledger.in_use == 0
    assert _census_ok(sess)


def test_hbm_fault_degrades_gracefully():
    sch = FaultSchedule([FaultEvent(at=0.0002, kind="hbm_fault", core=0,
                                    n_segments=4)])
    sess, chat, r = _serve(sch)
    assert r.requests_done == 24
    assert r.hbm_fault_segments == 4 and r.evacuations == 0
    led = chat.vnpu.kv_ledger
    assert led.capacity == HBM - 4 * SEG
    assert chat.hbm_bytes == led.capacity  # resizes honor the new size
    f, res, flt, tot = sess.cluster.manager.hbm_census()[0]
    assert flt == 4 and f + res + flt == tot
    assert led.in_use == 0


def test_hbm_fault_on_vacant_core_hits_free_pool():
    sch = FaultSchedule([FaultEvent(at=0.0002, kind="hbm_fault", core=3,
                                    n_segments=2)])
    sess, chat, r = _serve(sch)
    assert r.requests_done == 24 and r.hbm_fault_segments == 0
    f, res, flt, tot = sess.cluster.manager.hbm_census()[3]
    assert flt == 2 and f + res + flt == tot


def test_link_faults_reroute_without_breaking_service():
    sch = FaultSchedule([
        FaultEvent(at=0.0001, kind="link_degrade", link=(0, 1),
                   bw_scale=0.0),
        FaultEvent(at=0.0002, kind="core_down", core=0, recovery=0.002),
        FaultEvent(at=0.003, kind="link_restore", link=(0, 1)),
    ])
    sess, chat, r = _serve(sch)
    assert r.requests_done == 24
    assert r.evacuations == 1
    assert _census_ok(sess)


def test_evacuation_carries_shared_prefix_and_retention():
    prof = PrefixProfile(prefix_len=64, share_ratio=1.0, n_prefixes=1,
                         seed=3)
    sess, chat, r = _serve(TRANSIENT, prefix_profile=prof,
                           kv_retention_ms=5.0)
    assert r.requests_done == 24
    assert r.evacuations == 1
    assert r.kv_prefix_hits > 0
    led = chat.vnpu.kv_ledger
    assert led.in_use == 0
    # retained (zero-holder) entries are the only shared bytes left —
    # the retention table survived the evacuation — and flushing them
    # drains the ledger completely
    assert led.shared_in_use == led.retired_bytes
    led.flush_retired()
    assert led.shared_in_use == 0 and led.retired_bytes == 0
    assert _census_ok(sess)


# ----------------------------------------------------------------------
# fault-free golden bit-identity (all three engines)
# ----------------------------------------------------------------------
# Captured from the PR 9 tree on the burst scenario above: with every
# fault knob off — no schedule, deadline/retry/retention zero — the
# failover-capable session must not perturb a single event, for the
# incremental, full-rebuild AND reference (fast_path off) engines, and
# an EMPTY schedule must match no schedule at all.
FAULT_OFF_GOLDEN = [24, 768, 0.332126]


def _fingerprint(sess, chat):
    st_ = sess.sims[chat.core_idx].tenants[chat.sim_idx].stats
    return [st_.requests_done, st_.tokens,
            round(max(s.now for s in sess.sims) / CORE.freq_hz * 1e3, 6)]


@pytest.mark.parametrize("engine", ["inc", "full", "ref"])
@pytest.mark.parametrize("empty_schedule", [False, True])
def test_fault_free_golden_bit_identical(engine, empty_schedule):
    faults = FaultSchedule([]) if empty_schedule else None
    sess, chat, r = _serve(faults, retry=False, engine=engine)
    assert _fingerprint(sess, chat) == FAULT_OFF_GOLDEN
    assert r.faults_survived == 0 and r.availability == 1.0


# ----------------------------------------------------------------------
# property: chaos x arrivals x resizes x borrows, vs the conservation
# mirror (all-transient faults + retry budget => nothing is ever lost)
# ----------------------------------------------------------------------
@given(seed=st.integers(0, 31),
       core_rate=st.floats(0.0, 3.0),
       hbm_rate=st.floats(0.0, 2.0),
       link_rate=st.floats(0.0, 2.0),
       failover=st.sampled_from(["evacuate", "restart"]),
       resize_eus=st.sampled_from([0, 2, 6]))
@settings(max_examples=12, deadline=None)
def test_chaos_interleaving_conserves_everything(seed, core_rate,
                                                 hbm_rate, link_rate,
                                                 failover, resize_eus):
    """Whatever transient chaos hits the cluster — core outages, HBM
    segment faults, link degradation — interleaved with bursty
    arrivals, a mid-run EU resize, and cross-tenant borrowing: every
    request either completes or exhausts its (generous) retry budget,
    no ledger leaks a byte, and every core's HBM segments stay exactly
    conserved (free + resident + faulted == total)."""
    pytest.importorskip("hypothesis")
    topo = FabricTopology.mesh(4, LINK)
    sch = FaultSchedule.chaos(
        horizon=0.004, n_cores=4, links=list(topo.links), seed=seed,
        core_fault_rate=core_rate, hbm_fault_rate=hbm_rate,
        link_fault_rate=link_rate, transient_frac=1.0, recovery=0.0015,
        bw_scale=0.25)
    cluster = NPUCluster(core=CORE, policy="neu10", topology=topo)
    sess = ServingSession(cluster, faults=sch, failover=failover)
    tenants = []
    for i, n in ((0, 16), (1, 8)):
        h = sess.register_generative(
            f"t{i}", CFG, prompt_len=256, gen_lens=16, eu_budget=4,
            kv_policy="evict", hbm_bytes=HBM, kv_borrow=True,
            max_retries=12, retry_backoff_ms=0.05)
        sess.submit_arrivals(h, PoissonArrivals(rate_rps=50_000.0,
                                                n=n, seed=i + 1))
        tenants.append((h, n))
    if resize_eus:
        sess.run_until(0.0001)
        h0, n0 = tenants[0]
        if h0.sim_idx >= 0:                # may be parked mid-failover
            try:
                tenants[0] = (sess.resize(h0, resize_eus), n0)
            except RuntimeError:           # no room on a shrunken core
                pass
    sess.drain()
    assert all(f + r + x == t
               for f, r, x, t in cluster.manager.hbm_census())
    for h, n in tenants:
        rep = sess.report(h)[0]
        # transient faults + 12 retries: every arrival is accounted for
        assert rep.requests_done + rep.retries_exhausted == n
        if h.vnpu is not None:             # not parked at drain time
            led = h.vnpu.kv_ledger
            assert led.in_use == 0 and led.shared_in_use == 0
        lent, borrowed = cluster.manager.loans_of(h.vnpu) \
            if h.vnpu is not None else (0, 0)
        assert lent >= 0 and borrowed >= 0
