"""Piggybacked iterations with an adaptive per-iteration token budget
(SARATHI-SF): mixed chunk+decode trace costing with shared weight
reads counted once, bounded on-demand program compilation, the
budgeted scheduling loop's floor/cap edges, and the guarantee that an
UNSET budget stays bit-identical to the static-chunk/monolithic
engine."""
import pytest

from repro.configs import SMOKES
from repro.core.compiler import compile_request_plan
from repro.npu.cost_model import (PIGGYBACK_CHUNK_FLOOR, RequestPlan,
                                  batch_bucket)
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.trace import lm_trace, piggyback_trace, request_plan
from repro.serve.session import (NPUCluster, PoissonArrivals,
                                 ServingSession, run_closed_loop)

CFG = SMOKES["qwen2-0.5b"]


def _session(policy="neu10"):
    return ServingSession(NPUCluster(policy=policy))


def _tenant(sess, budget, gen=8, prompt=1024, name="g", **kw):
    return sess.register_generative(name, CFG, prompt_len=prompt,
                                    gen_lens=gen, eu_budget=4,
                                    iteration_token_budget=budget, **kw)


# ----------------------------------------------------------------------
# plan / trace construction
# ----------------------------------------------------------------------
def test_budget_plan_construction():
    plan = request_plan(CFG, batch=1, prompt_len=1024, gen_len=8,
                        iteration_token_budget=288)
    assert plan.piggybacked and plan.iteration_token_budget == 288
    assert plan.piggyback_builder is not None
    assert not plan.chunked          # dynamic slices, no static chunks


def test_budget_and_static_chunks_are_mutually_exclusive():
    with pytest.raises(ValueError, match="replaces"):
        request_plan(CFG, batch=1, prompt_len=1024, gen_len=8,
                     prefill_chunk_tokens=256, iteration_token_budget=288)
    with pytest.raises(ValueError, match=">= 0"):
        request_plan(CFG, batch=1, prompt_len=1024, gen_len=8,
                     iteration_token_budget=-1)


def test_unset_budget_is_bit_identical_plan():
    a = request_plan(CFG, batch=1, prompt_len=512, gen_len=16)
    b = request_plan(CFG, batch=1, prompt_len=512, gen_len=16,
                     iteration_token_budget=0)
    assert not a.piggybacked and not b.piggybacked
    assert [(o.name, o.me_cycles, o.ve_cycles, o.hbm_bytes, o.n_tiles)
            for o in a.prefill.ops] == \
           [(o.name, o.me_cycles, o.ve_cycles, o.hbm_bytes, o.n_tiles)
            for o in b.prefill.ops]
    assert [c for c, _ in a.decode] == [c for c, _ in b.decode]


def test_batch_bucket():
    assert batch_bucket(0) == 0
    assert [batch_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]


def test_piggyback_trace_counts_shared_weights_once():
    """The fused program's decode ops drop their parameter-streaming
    HBM share (the chunk already streamed those weights) but keep
    per-token traffic (KV stream, embedding gathers)."""
    chunk = lm_trace(CFG, 1, 256, "prefill", kv_prior=256,
                     include_head=False)
    dec = lm_trace(CFG, 4, 512, "decode")
    tr = piggyback_trace(CFG, 1, 256, 256, 4, 512, final=False)
    assert len(tr.ops) == len(chunk.ops) + len(dec.ops)
    merged_dec = tr.ops[len(chunk.ops):]
    streamed = {op.name for op in chunk.ops if op.weight_bytes > 0}
    saved = 0.0
    for got, orig in zip(merged_dec, dec.ops):
        assert got.name == orig.name
        if orig.name in streamed and orig.weight_bytes > 0:
            assert got.hbm_bytes == pytest.approx(
                orig.hbm_bytes - orig.weight_bytes)
            assert got.weight_bytes == 0.0
            saved += orig.weight_bytes
        else:   # per-token traffic is NOT deduped
            assert got.hbm_bytes == orig.hbm_bytes
    assert saved > 0
    # attn_decode's KV stream survives in full
    kv = [op for op in merged_dec if op.name == "attn_decode"]
    assert kv and all(op.hbm_bytes > 0 for op in kv)
    # lm_head only dedupes when the chunk carries it (final slice)
    fin = piggyback_trace(CFG, 1, 256, 256, 4, 512, final=True)
    head_non = [o for o in tr.ops if o.name == "lm_head"]
    head_fin = [o for o in fin.ops if o.name == "lm_head"]
    assert len(head_non) == 1           # decode side only, full cost
    assert head_non[0].weight_bytes > 0
    assert len(head_fin) == 2           # chunk emits token 1 + decode
    assert head_fin[1].weight_bytes == 0.0


def test_piggyback_trace_without_decode_is_plain_chunk():
    tr = piggyback_trace(CFG, 1, 256, 512, 0, 0, final=False,
                         include_head=True)
    ref = lm_trace(CFG, 1, 256, "prefill", kv_prior=512,
                   include_head=False)
    assert tr.name == ref.name
    assert [(o.name, o.me_cycles, o.hbm_bytes) for o in tr.ops] == \
           [(o.name, o.me_cycles, o.hbm_bytes) for o in ref.ops]


# ----------------------------------------------------------------------
# compiler: on-demand programs, memoized and bounded
# ----------------------------------------------------------------------
def test_piggyback_phase_memoizes_through_shared_cache():
    cluster = NPUCluster(policy="neu10")
    plan = request_plan(CFG, batch=1, prompt_len=1024, gen_len=8,
                        iteration_token_budget=288)
    c1 = cluster.compile_plan(plan)
    c2 = cluster.compile_plan(plan)
    assert c1.can_piggyback and c1.iteration_token_budget == 288
    p1 = c1.piggyback_phase(256, 256, 2, 1024, False)
    assert p1.kind == "piggyback" and p1.context == 512
    assert c1.piggyback_phase(256, 256, 2, 1024, False) is p1  # memo
    # a second compiled plan shares the ProgramCache: same program
    assert c2.piggyback_phase(256, 256, 2, 1024, False).program \
        is p1.program
    # a different quantized mix is a different program
    assert c1.piggyback_phase(256, 256, 4, 1024, False).program \
        is not p1.program


def test_plan_without_builder_rejects_piggyback():
    plan = RequestPlan(name="bare", prefill=lm_trace(CFG, 1, 256,
                                                     "prefill"))
    c = compile_request_plan(plan, DEFAULT_CORE, isa="neuisa")
    assert not c.can_piggyback
    with pytest.raises(ValueError, match="piggyback builder"):
        c.piggyback_phase(256, 0, 0, 0, True)


def test_program_cache_stays_bounded_under_load():
    """Quantized keys: many requests at many live batch sizes compile
    a bounded program set, not one per iteration."""
    sess = _session()
    h = _tenant(sess, budget=288, gen=12, prompt=1024)
    sess.submit_arrivals(h, PoissonArrivals(rate_rps=30_000.0, n=24,
                                            seed=7))
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.requests_done == 24
    assert st.piggyback_iterations > 24      # multiple slices/request
    assert len(sess.cluster.programs) < 40   # bounded, shared


# ----------------------------------------------------------------------
# simulator: budgeted iterations
# ----------------------------------------------------------------------
def test_piggyback_carries_chunk_and_decode_tokens():
    """THE tentpole property: one iteration serves a prefill slice AND
    >= 2 live decode tokens; riders sample TBT there, TTFT samples
    only come from slice owners (one per request)."""
    sess = _session()
    h = _tenant(sess, budget=288, gen=16, prompt=1024)
    sess.submit(h, at_s=0.0)
    sess.submit(h, at_s=0.00002)
    sess.submit(h, at_s=0.00003)
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.requests_done == 3 and st.tokens == 48
    assert st.piggyback_iterations >= 1
    assert st.max_piggyback_batch >= 2
    assert st.piggyback_decode_tokens >= 2
    assert len(st.ttft) == 3                 # co-riders never add TTFT
    assert len(st.tbt) == 48 - 3


def test_token_accounting_matches_chunked_and_monolithic():
    """The budget changes WHEN work runs, not what a request produces:
    same requests, same tokens, same TTFT/TBT sample counts as the
    static-chunk and monolithic engines."""
    outs = []
    for kw in ({}, {"prefill_chunk_tokens": 256},
               {"iteration_token_budget": 288}):
        sess = _session()
        h = sess.register_generative("g", CFG, prompt_len=1024,
                                     gen_lens=8, eu_budget=4, **kw)
        sess.submit(h, at_s=0.0)
        sess.submit(h, at_s=0.00002)
        sess.drain()
        st = sess.sim.tenants[h.sim_idx].stats
        outs.append((st.requests_done, st.tokens, len(st.ttft),
                     len(st.tbt)))
    assert outs[0] == outs[1] == outs[2]


def test_oversubscribed_budget_runs_decode_only_then_floors():
    """Budget smaller than the live decode batch leaves no room for a
    slice: ONE decode-only iteration runs, then the next slice is
    floored — prefill always progresses and every request finishes."""
    sess = _session()
    # budget 33: with >= 2 decoding requests (bucket 2), 33 - 2 < floor
    assert 33 - 2 < PIGGYBACK_CHUNK_FLOOR
    h = _tenant(sess, budget=33, gen=48, prompt=256)
    sim = sess.sim
    rt = sim.tenants[h.sim_idx]
    log = []
    orig = rt._start_iteration

    def spy(t):
        orig(t)
        if rt.in_request:
            log.append((rt.active_kind,
                        len(rt.prefilling) + len(rt.waiting) + (
                            1 if rt.piggy_req is not None else 0)))

    rt._start_iteration = spy
    for i in range(3):
        sess.submit(h, at_s=i * 0.00002)
    sess.drain()
    st = rt.stats
    assert st.requests_done == 3 and st.tokens == 3 * 48
    # a decode-only iteration ran while prefill work was pending...
    assert any(kind == "decode" and pending > 0 for kind, pending in log)
    # ...and piggybacked slices still progressed after it
    decode_idx = next(i for i, (k, p) in enumerate(log)
                      if k == "decode" and p > 0)
    assert any(k == "piggyback" for k, _ in log[decode_idx + 1:])


def test_budget_larger_than_prompt_is_single_final_slice():
    sess = _session()
    h = _tenant(sess, budget=288, gen=4, prompt=200)
    sess.submit(h, at_s=0.0)
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.requests_done == 1 and st.tokens == 4
    assert st.piggyback_iterations == 1      # whole prompt in one slice
    assert st.prefill_chunks == 1


def test_final_partial_slice():
    """A prompt that is not a multiple of the budget ends on a partial
    slice capped at the remaining tokens."""
    sess = _session()
    h = _tenant(sess, budget=288, gen=2, prompt=300)
    sess.submit(h, at_s=0.0)
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.requests_done == 1
    assert st.piggyback_iterations == 2      # 288 + 12 (remainder)


def test_mid_prefill_deregister_during_piggybacked_iteration():
    sess = _session()
    h = _tenant(sess, budget=96, gen=16, prompt=1024)
    sess.submit(h, at_s=0.0)
    sess.submit(h, at_s=0.0)
    sess.run_until(1e-5)              # somewhere mid-slice-chain
    sess.deregister(h)
    assert sess.drain() >= 0.0        # no deadlock, no orphaned state


def test_open_loop_determinism_with_budget():
    def run_once():
        sess = _session()
        h = _tenant(sess, budget=160, gen=12, prompt=1024)
        sess.submit_arrivals(h, PoissonArrivals(rate_rps=4000.0, n=8,
                                                seed=3))
        sess.drain()
        st = sess.sim.tenants[h.sim_idx].stats
        return (st.latencies, st.ttft, st.tbt, st.tokens,
                st.piggyback_iterations, st.piggyback_decode_tokens,
                st.max_piggyback_batch)

    assert run_once() == run_once()


def test_closed_loop_with_budget():
    cluster = NPUCluster(policy="neu10")
    cluster.register_generative("g", CFG, prompt_len=1024, gen_lens=8,
                                eu_budget=4, iteration_token_budget=288)
    res, reports = run_closed_loop(cluster, n_requests=3)
    st = res.tenants[0]
    assert st.requests_done >= 3
    assert st.tokens == st.requests_done * 8
    assert st.piggyback_iterations >= st.requests_done
    assert reports[0].ttft_p95_ms > 0


# ----------------------------------------------------------------------
# live budget control (the autoscaler-facing knob)
# ----------------------------------------------------------------------
def test_set_iteration_token_budget_live():
    sess = _session()
    h = _tenant(sess, budget=0, gen=16, prompt=1024)
    sess.submit(h, at_s=0.0)
    sess.run_until(1e-5)
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.piggyback_iterations == 0      # knob off: PR-3 engine
    sess.set_iteration_token_budget(h, 160)
    sess.submit(h)
    sess.submit(h)
    sess.drain()
    assert st.piggyback_iterations >= 1      # knob on mid-run
    assert st.requests_done == 3 and st.tokens == 48
    sess.set_iteration_token_budget(h, 0)    # and off again
    assert sess.sim.tenants[h.sim_idx].plan.iteration_token_budget == 0


def test_disable_budget_mid_prefill_restarts_parked_requests():
    """Turning the knob OFF with a request parked mid-slice drops the
    partial ingestion explicitly (KV restart): the cursor resets to 0
    and the monolithic replay is a restart, not a silent
    double-count; requests still finish with exact token counts."""
    sess = _session()
    h = _tenant(sess, budget=96, gen=8, prompt=1024)
    sess.submit(h, at_s=0.0)
    sess.submit(h, at_s=0.0)
    rt = sess.sim.tenants[h.sim_idx]
    # advance until slices have been ingested but prefill is unfinished
    t = 0.0
    while not (rt.piggy_req is not None and rt.piggy_req.prefill_done > 0
               or any(r.prefill_done > 0 for r in rt.prefilling)):
        t += 2e-6
        sess.run_until(t)
        assert t < 1.0, "never observed a request mid-slice"
    sess.set_iteration_token_budget(h, 0)
    sess.drain()
    st = rt.stats
    assert st.requests_done == 2 and st.tokens == 16
    assert len(st.ttft) == 2 and len(st.tbt) == 14
    # the restart left no stale cursors behind
    assert rt.piggy_req is None and not rt.prefilling


def test_fast_path_identical_across_mid_run_tenant_churn():
    """Deregister-then-register while harvested chunks are still in
    flight: the joiner takes ownership of engines still running a
    departed neighbor's work, and the fast path's incremental
    squatter counts must follow (regression: a stale count skipped
    reclaims the reference pass performed, diverging the
    SimResult)."""
    from repro.core import VNPUConfig
    from repro.core.simulator import Simulator, TenantSpec
    from repro.npu.workloads import get_workload

    def run(fast):
        cluster = NPUCluster(policy="neu10")
        core = cluster.core
        half = dict(hbm_bytes=core.hbm_bytes // 2,
                    sram_bytes=core.sram_bytes // 2)
        a = cluster.register_vnpu("a", get_workload("DLRM", core),
                                  VNPUConfig(2, 2, **half))
        x = cluster.register_vnpu("x", get_workload("BERT", core),
                                  VNPUConfig(2, 2, **half))
        sim = Simulator((), policy="neu10", core=core, fast_path=fast)
        ia = sim.add_tenant(TenantSpec(cluster.compile(a.trace), a.vnpu),
                            open_loop=True)
        ix = sim.add_tenant(TenantSpec(cluster.compile(x.trace), x.vnpu),
                            open_loop=True)
        for i in range(12):           # x stays idle: its engines are
            sim.inject_request(ia, i * 2_000.0)   # harvested by a
        # advance until one of a's chunks is in flight on x's engines
        t = 0.0
        while not any(e.chunk is not None and e.owner == ix
                      and e.tenant == ia
                      for e in sim.mes + sim.ves):
            t += 100.0
            sim.run_until(t)
            assert t < 5e6, "a never harvested x's engines"
        sim.remove_tenant(ix)         # ownership released, a's chunks
        cluster.deregister(x)         # on x's engines keep running
        c = cluster.register_vnpu("c", get_workload("BERT", core),
                                  VNPUConfig(2, 2, **half))
        ic = sim.add_tenant(TenantSpec(cluster.compile(c.trace), c.vnpu),
                            open_loop=True)
        sim.inject_request(ic, sim.now)
        sim.inject_request(ic, sim.now)
        sim.run_until()
        return sim.result()

    fast, ref = run(True), run(False)
    assert fast.makespan == ref.makespan
    assert fast.tenants == ref.tenants


def test_fast_schedule_honors_subclassed_dispatch():
    """A Simulator subclass overriding dispatch() (the documented
    policy-facing API) must keep seeing every chunk even though the
    fast neu10 pass normally uses the internal single-engine path."""
    from repro.core.simulator import Simulator, TenantSpec

    seen = []

    class SpySim(Simulator):
        def dispatch(self, chunk, engines, t, harvested=False):
            seen.append(chunk.phase)
            super().dispatch(chunk, engines, t, harvested)

    cluster = NPUCluster(policy="neu10")
    h = cluster.register_generative("g", CFG, prompt_len=512,
                                    gen_lens=8, eu_budget=4,
                                    iteration_token_budget=160)
    cplan = cluster.compile_plan(h.plan)
    sim = SpySim([TenantSpec(cplan.prefill.program, h.vnpu, 2,
                             plan=cplan)],
                 policy="neu10", core=cluster.core)
    res = sim.run()
    assert res.tenants[0].requests_done >= 2
    assert seen and "piggyback" in seen


def test_set_iteration_token_budget_guards():
    sess = _session()
    chunked = sess.register_generative("c", CFG, prompt_len=1024,
                                       gen_lens=8, eu_budget=4,
                                       prefill_chunk_tokens=256)
    with pytest.raises(ValueError, match="replaces"):
        sess.set_iteration_token_budget(chunked, 160)
    fixed = sess.register_model(CFG, batch=1, seq=128, eu_budget=2)
    with pytest.raises(ValueError, match="not generative"):
        sess.set_iteration_token_budget(fixed, 160)
    sess2 = _session()
    gen = _tenant(sess2, budget=160, gen=8, prompt=512, name="g2")
    with pytest.raises(ValueError, match=">= 0"):
        sess2.set_iteration_token_budget(gen, -5)


# ----------------------------------------------------------------------
# per-rider context-bucket costing (the ROADMAP refinement): the
# decode side of a fused program is priced at each rider's OWN bucket
# instead of the largest live bucket
# ----------------------------------------------------------------------
def test_piggyback_trace_groups_cheaper_than_max_bucket():
    """Splitting a mixed-context batch into per-bucket decode groups
    strictly undercuts pricing everyone at the largest bucket (the
    small riders stop paying the big riders' KV stream). On a
    KV-dominated mix the whole ideal span drops too; per-token
    traffic still scales with the real rider count."""
    grouped = piggyback_trace(CFG, 1, 128, 256, 0, 0, final=False,
                              decode_groups=[(4, 512), (4, 8192)])
    at_max = piggyback_trace(CFG, 1, 128, 256, 8, 8192, final=False)
    _, _, gh = grouped.totals()
    _, _, mh = at_max.totals()
    assert gh < mh                       # less HBM: the KV stream shrank
    core = DEFAULT_CORE
    assert grouped.ideal_cycles(core.n_me, core.n_ve) \
        < at_max.ideal_cycles(core.n_me, core.n_ve)
    # weight dedupe spans groups: the later group's projection
    # matmuls stream nothing (the chunk + first group already paid
    # the weights), so the only decode-side weight stream left is the
    # lm_head the chunk did not carry (non-final slice)
    n_chunk = len(lm_trace(CFG, 1, 128, "prefill", kv_prior=256,
                           include_head=False).ops)
    dec_weighted = [o.name for o in grouped.ops[n_chunk:]
                    if o.weight_bytes > 0]
    assert dec_weighted == ["lm_head"]


def test_single_bucket_batch_keeps_legacy_cache_key():
    """A batch whose riders share one context bucket compiles through
    the pre-grouping key — program identity proves byte-identity."""
    cluster = NPUCluster(policy="neu10")
    plan = request_plan(CFG, batch=1, prompt_len=1024, gen_len=8,
                        iteration_token_budget=288)
    c = cluster.compile_plan(plan)
    legacy = c.piggyback_phase(256, 256, 2, 1024, False)
    via_groups = c.piggyback_phase(256, 256, 2, 1024, False,
                                   decode_groups=None)
    assert via_groups is legacy


def _mixed_bucket_run(coerce_to_max: bool):
    """Four fixed-length requests staggered so late slices ride a
    mature request (bucket 1024) and a fresh one (bucket 512) at
    once; ``batch=8`` puts the decode cost in the KV-stream-dominated
    regime the refinement targets. ``coerce_to_max`` re-instates the
    PR 4 costing (everyone at the largest live bucket) for the A/B
    comparison."""
    sess = _session()
    h = sess.register_generative("g", CFG, prompt_len=256, gen_lens=400,
                                 batch=8, eu_budget=4,
                                 iteration_token_budget=96)
    rt = sess.sim.tenants[h.sim_idx]
    if coerce_to_max:
        plan = rt.plan

        def at_max(cost_tokens, pos, final):
            if not rt.decoding:
                return plan.piggyback_phase(cost_tokens, pos, 0, 0, final)
            live = max(rt._context_of(r) for r in rt.decoding)
            ctx = plan.decode_phase_for(live).context
            bb = batch_bucket(len(rt.decoding))
            return plan.piggyback_phase(cost_tokens, pos, bb, ctx, final)

        rt._piggyback_phase_for = at_max
    for i in range(4):
        sess.submit(h, at_s=i * 3e-4)
    sess.drain()
    st = rt.stats
    assert st.requests_done == 4
    return st, rt.plan


def test_per_rider_bucket_costing_improves_small_rider_tbt():
    refined, plan = _mixed_bucket_run(coerce_to_max=False)
    legacy, _ = _mixed_bucket_run(coerce_to_max=True)
    # the refinement only changes program COST, not token bookkeeping
    assert refined.tokens == legacy.tokens
    assert len(refined.tbt) == len(legacy.tbt)
    # a multi-bucket fused program was really built and memoized
    grouped_keys = [k for k in plan._piggy_memo if k[5] is not None]
    assert grouped_keys, "no mixed-bucket iteration occurred"
    assert all(len(k[5]) >= 2 for k in grouped_keys)
    # the small rider's cadence improves: every rider's token lands
    # when the fused iteration ends, and those iterations got cheaper
    # (the 512-bucket riders stopped paying the 1024-bucket KV
    # stream), so total time-between-tokens strictly drops
    assert sum(refined.tbt) < sum(legacy.tbt)
    assert sum(refined.latencies) < sum(legacy.latencies)


def test_grouped_piggyback_cache_stays_bounded():
    """Mixed-bucket keys live on the same finite quantized grid: a
    long staggered run compiles a bounded program set."""
    sess = _session()
    h = sess.register_generative("g", CFG, prompt_len=256, gen_lens=400,
                                 eu_budget=4, iteration_token_budget=96)
    sess.submit_arrivals(h, PoissonArrivals(rate_rps=5_000.0, n=12, seed=5))
    sess.drain()
    rt = sess.sim.tenants[h.sim_idx]
    assert rt.stats.requests_done == 12
    assert len(rt.plan._piggy_memo) < 64
    assert len(sess.cluster.programs) < 96
