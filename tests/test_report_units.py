"""Unit-convention regression: the simulator domain is CYCLES, the
TenantReport domain is MILLISECONDS (and requests/second), and the
conversion happens exactly once at the report boundary with
``1e3 / NPUCoreConfig.freq_hz``. These tests pin the reported numbers
for a tiny fixed trace so any future cycles/ms (or cycles/timestep)
mixup shifts a hard-coded golden, not just a ratio."""
import pytest

from repro.npu.cost_model import Operator, WorkloadTrace
from repro.npu.hw_config import DEFAULT_CORE
from repro.serve.session import NPUCluster, ServingSession

MS = 1e3 / DEFAULT_CORE.freq_hz          # the one sanctioned factor


def _tiny_session():
    """3 back-to-back requests of a 2-op trace on a lone neu10 tenant
    — every event lands at a deterministic cycle count."""
    tr = WorkloadTrace("tiny", [
        Operator("mm", me_cycles=10_000.0, ve_cycles=2_000.0, n_tiles=2),
        Operator("act", ve_cycles=4_000.0),
    ], core=DEFAULT_CORE)
    sess = ServingSession(NPUCluster(policy="neu10"))
    h = sess.register("tiny", tr, eu_budget=4, slo_p95_ms=1.0)
    for at in (0.0, 1e-6, 3e-6):
        sess.submit(h, at_s=at)
    sess.drain()
    return sess, h


def test_simulator_series_are_cycles():
    sess, h = _tiny_session()
    st = sess.sim.tenants[h.sim_idx].stats
    # pinned cycle-domain goldens for the tiny fixed trace
    assert st.latencies == [6000.0, 10950.0, 14850.0]
    assert st.ttft == st.latencies           # single-phase: TTFT == e2e
    assert st.tbt == []
    assert sess.sim.now == 18000.0           # cycles, not seconds/steps


def test_report_is_milliseconds_and_rps():
    sess, h = _tiny_session()
    r = sess.report(h)[0]
    # pinned ms-domain goldens: cycles * 1e3 / 1.05 GHz
    assert r.p95_ms == pytest.approx(14850.0 * MS, rel=1e-12)
    assert r.p95_ms == pytest.approx(0.014142857142857143, rel=1e-12)
    assert r.mean_ms == pytest.approx(0.010095238095238095, rel=1e-12)
    assert r.ttft_p95_ms == pytest.approx(r.p95_ms, rel=1e-12)
    assert r.tbt_p95_ms == 0.0
    # throughput: 3 requests over 18000 cycles of simulated time
    assert r.throughput_rps == pytest.approx(
        3 / (18000.0 / DEFAULT_CORE.freq_hz), rel=1e-12)
    assert r.throughput_rps == pytest.approx(175000.0, rel=1e-12)
    assert r.slo_ok is True                  # 0.0141 ms <= 1.0 ms
    assert sess.latencies_ms(h) == pytest.approx(
        [c * MS for c in (6000.0, 10950.0, 14850.0)], rel=1e-12)


def test_report_matches_stats_via_single_factor():
    """TenantReport must be TenantStats scaled by exactly 1e3/freq_hz
    — no second conversion path may exist."""
    sess, h = _tiny_session()
    st = sess.sim.tenants[h.sim_idx].stats
    r = sess.report(h)[0]
    assert r.p95_ms == st.p95() * MS
    assert r.mean_ms == st.mean() * MS
    assert r.ttft_p95_ms == st.ttft_p95() * MS
    assert r.harvested_me_ms == st.harvested_me_work * MS
    assert r.blocked_ms == st.reclaim_blocked * MS


def test_generative_latency_decomposition_in_one_unit():
    """For a lone generative request, e2e == TTFT + sum(TBT) — only
    true when all three series share one unit (cycles)."""
    from repro.configs import SMOKES

    sess = ServingSession(NPUCluster(policy="neu10"))
    h = sess.register_generative("g", SMOKES["qwen2-0.5b"], prompt_len=1024,
                                 gen_lens=8, eu_budget=4,
                                 prefill_chunk_tokens=256)
    sess.submit(h, at_s=0.0)
    sess.drain()
    st = sess.sim.tenants[h.sim_idx].stats
    assert st.latencies[0] == pytest.approx(st.ttft[0] + sum(st.tbt))


def test_no_samples_means_no_slo_verdict():
    """A tenant with an SLO but zero completions must report None (not
    a vacuous pass from p95 == 0.0)."""
    tr = WorkloadTrace("idle", [Operator("mm", me_cycles=1000.0)],
                       core=DEFAULT_CORE)
    sess = ServingSession(NPUCluster(policy="neu10"))
    h = sess.register("idle", tr, eu_budget=2, slo_p95_ms=1.0)
    r = sess.report(h)[0]
    assert r.slo_ok is None
    assert r.p95_ms == 0.0 and r.throughput_rps == 0.0
