"""Shared test configuration.

``HYPOTHESIS_SEED`` (any value) opts into the derandomized hypothesis
profile: every property test runs its deterministic example set, so a
CI failure replays byte-identically instead of depending on a random
draw. CI's docs-and-hygiene job pins it; local runs stay randomized
(better long-run coverage). No-op when hypothesis is absent (the
property tests then skip via tests/hypothesis_compat.py).
"""
import os

try:
    from hypothesis import settings as _hyp_settings
except ModuleNotFoundError:
    _hyp_settings = None

if _hyp_settings is not None and os.environ.get("HYPOTHESIS_SEED"):
    _hyp_settings.register_profile("ci-deterministic", derandomize=True,
                                   print_blob=True)
    _hyp_settings.load_profile("ci-deterministic")
