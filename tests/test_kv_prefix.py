"""Refcounted cross-request KV prefix sharing + cross-tenant HBM
borrowing: ledger property suite vs a mirror model, sharing-off golden
bit-identity across all three engines, composition tests (shared-
holder eviction, borrow/reclaim ordering, cross-core migration of a
shared-prefix holder), and the shrink-with-resident-shared-segments
resize regression."""
import pytest

from repro.configs import SMOKES
from repro.core.fabric import FabricTopology, Placement
from repro.core.mapper import ReconfigureError
from repro.core.policies import pick_eviction_victim
from repro.core.vnpu import KVLedger, KVLedgerError
from repro.npu.hw_config import DEFAULT_CORE
from repro.npu.trace import request_plan
from repro.serve.session import (GenLenDistribution, NPUCluster,
                                 PoissonArrivals, PrefixProfile,
                                 ServingSession)
from tests.hypothesis_compat import given, settings, st

CFG = SMOKES["qwen2-0.5b"]
SEG = 64 * 1024
SMALL_CORE = DEFAULT_CORE.with_(hbm_bytes=1024 * SEG, hbm_segment=SEG)
WEIGHTS = CFG.param_count() * 2
WSEG = -(-WEIGHTS // SEG) * SEG


# ----------------------------------------------------------------------
# PrefixProfile: deterministic, monotone in the ratio, validated
# ----------------------------------------------------------------------
def test_prefix_profile_deterministic_and_monotone():
    lo = PrefixProfile(prefix_len=64, share_ratio=0.3, n_prefixes=3, seed=9)
    hi = PrefixProfile(prefix_len=64, share_ratio=0.8, n_prefixes=3, seed=9)
    a, b = lo.sample(200, stream=4), lo.sample(200, stream=4)
    assert (a == b).all()                       # same (seed, stream)
    assert (a != lo.sample(200, stream=5)).any()
    klo, khi = lo.sample(200, stream=4), hi.sample(200, stream=4)
    # raising the ratio only ADDS shared arrivals: every request shared
    # at the low ratio keeps its exact group at the high ratio
    assert all(h == l for l, h in zip(klo, khi) if l != 0)
    assert (khi != 0).sum() > (klo != 0).sum()
    assert set(khi) <= {0, 1, 2, 3}


def test_prefix_profile_validation():
    with pytest.raises(ValueError, match="prefix_len"):
        PrefixProfile(prefix_len=0)
    with pytest.raises(ValueError, match="share_ratio"):
        PrefixProfile(prefix_len=8, share_ratio=1.5)
    with pytest.raises(ValueError, match="n_prefixes"):
        PrefixProfile(prefix_len=8, n_prefixes=0)


def test_register_prefix_profile_validation():
    sess = ServingSession(NPUCluster(core=SMALL_CORE, policy="neu10"))
    prof = PrefixProfile(prefix_len=64)
    with pytest.raises(ValueError, match="kv_policy"):
        sess.register_generative("t0", CFG, prompt_len=128,
                                 gen_lens=8, eu_budget=4,
                                 prefix_profile=prof)
    with pytest.raises(ValueError, match="kv_borrow"):
        sess.register_generative("t1", CFG, prompt_len=128,
                                 gen_lens=8, eu_budget=4, kv_borrow=True)
    with pytest.raises(ValueError, match="unshared suffix"):
        # the prefix must leave at least one suffix token
        request_plan(CFG, 1, 64, 8, core=SMALL_CORE, prefix_len=64)
    # a plan built with a DIFFERENT prefix_len than the profile's is
    # a caller bug, not something to paper over
    plan = request_plan(CFG, 1, 128, 8, core=SMALL_CORE, prefix_len=32)
    with pytest.raises(ValueError, match="does not match"):
        sess.cluster.register("t2", plan.profile_trace(), eu_budget=4,
                              plan=plan, kv_policy="evict",
                              hbm_bytes=WSEG + 8 * SEG,
                              prefix_profile=prof)
    # a prefix-keyed arrival against a tenant without the machinery
    # must fail loudly, not silently drop the key
    chat = sess.register_generative("t3", CFG, prompt_len=128,
                                    gen_lens=8, eu_budget=4,
                                    kv_policy="evict",
                                    hbm_bytes=WSEG + 8 * SEG)
    with pytest.raises(ValueError, match="prefix"):
        sess.submit(chat, prefix_key=5)


# ----------------------------------------------------------------------
# eviction policy: multi-ref shared holders go LAST
# ----------------------------------------------------------------------
class _FakePlan:
    has_decode = False


class _FakeReq:
    def __init__(self, arrival, gen_len, tokens_done=1, refs=0):
        self.arrival = arrival
        self.gen_len = gen_len
        self.tokens_done = tokens_done
        self.refs = refs


def test_eviction_victim_spares_multi_ref_shared_holders():
    # r2 has the largest remaining service — the legacy pick — but it
    # holds a shared entry other requests still reference
    r0 = _FakeReq(arrival=0.0, gen_len=4)
    r1 = _FakeReq(arrival=1.0, gen_len=9, refs=1)     # sole holder
    r2 = _FakeReq(arrival=2.0, gen_len=50, refs=3)    # multi-ref
    reqs = [r0, r1, r2]
    ctx = lambda r: 0
    # without the callback: pure PREMA estimate picks r2
    assert pick_eviction_victim(reqs, _FakePlan(), ctx) is r2
    # with it: multi-ref holders are last-out; r1 (refcount 1) counts
    # as evictable like any private request and out-estimates r0
    pick = pick_eviction_victim(reqs, _FakePlan(), ctx,
                                shared_refs_of=lambda r: r.refs)
    assert pick is r1
    # all candidates multi-ref: the picker still returns one (the
    # ledger falls back to swapping suffixes, never deadlocks)
    allshared = [_FakeReq(0.0, 5, refs=2), _FakeReq(1.0, 9, refs=2)]
    assert pick_eviction_victim(allshared, _FakePlan(), ctx,
                                shared_refs_of=lambda r: r.refs) \
        is allshared[1]


# ----------------------------------------------------------------------
# property suite: shared + borrow interleavings vs a mirror model
# ----------------------------------------------------------------------
_PREFIX_OPS = st.lists(
    st.tuples(
        st.sampled_from(["alloc", "grow", "free", "acq", "rel",
                         "borrow", "reclaim", "resize"]),
        st.integers(min_value=0, max_value=1),        # acting ledger
        st.integers(min_value=0, max_value=4),        # rid / prefix key
        st.integers(min_value=0, max_value=3 * SEG),  # bytes
    ),
    max_size=80,
)


def _kb(key: int) -> int:
    """Shared-entry size as a pure function of the key (the hash names
    the exact token range)."""
    return (key + 1) * SEG // 2


@given(ops=_PREFIX_OPS,
       caps=st.lists(st.integers(min_value=2, max_value=12),
                     min_size=2, max_size=2))
@settings(max_examples=150, deadline=None)
def test_shared_borrow_interleavings_match_mirror(ops, caps):
    """Any interleaving of alloc/grow/free, shared acquire/release,
    manager-style borrow (lend+grant) / reclaim (revoke+reclaim_lent),
    and capacity resize against a lender/borrower ledger pair matches
    a mirror model: refcounts never go negative, byte totals are
    conserved, ``reserved + in_use + shared_in_use + lent`` never
    exceeds ``capacity + borrowed``, and a full drain leaks nothing."""
    pytest.importorskip("hypothesis")
    leds = [KVLedger(c * SEG, SEG, reserved_bytes=SEG) for c in caps]
    entries = [dict() for _ in leds]          # rid -> bytes
    shared = [dict() for _ in leds]           # key -> [bytes, refs]
    loan = 0                                  # ledger 0 lends TO 1

    def check():
        for led, ent, shr in zip(leds, entries, shared):
            assert led.in_use == sum(ent.values())
            assert led.entries == ent
            assert led.shared == shr
            assert led.shared_in_use == sum(b for b, _ in shr.values())
            assert all(r >= 1 for _, r in shr.values())
            assert (led.reserved + led.in_use + led.shared_in_use
                    + led.lent <= led.capacity + led.borrowed)
        assert leds[0].lent == loan == leds[1].borrowed

    for op, i, rid, n in ops:
        led, ent, shr = leds[i], entries[i], shared[i]
        if op in ("alloc", "grow"):
            if led.alloc(rid, n):
                ent[rid] = ent.get(rid, 0) + n
            else:
                assert n > led.available      # reject only on pressure
        elif op == "free":
            if rid in ent:
                assert led.free(rid) == ent.pop(rid)
            else:
                with pytest.raises(KVLedgerError):
                    led.free(rid)
                assert led.release(rid) == 0  # lenient twin: no raise
        elif op == "acq":
            pb = _kb(rid)
            ok = led.acquire_shared(rid, pb)
            if rid in shr:
                assert ok
                shr[rid][1] += 1
            elif ok:
                shr[rid] = [pb, 1]
            else:
                assert pb > led.available
        elif op == "rel":
            if rid in shr:
                shr[rid][1] -= 1
                freed = led.release_shared(rid)
                if shr[rid][1] == 0:
                    assert freed == shr.pop(rid)[0]
                else:
                    assert freed == 0
            else:
                with pytest.raises(KVLedgerError):
                    led.release_shared(rid)
        elif op == "borrow":                  # manager pairing: 0 -> 1
            take = (n // SEG) * SEG
            if take > 0 and leds[0].lend(take):
                leds[1].grant(take)
                loan += take
            elif take > 0:
                assert take > leds[0].available
        elif op == "reclaim":                 # revoke idle, then unlend
            back = leds[1].revoke(min(n, loan))
            assert 0 <= back <= min(n, loan)
            leds[0].reclaim_lent(back)
            loan -= back
        else:                                 # resize via migrate_from
            newcap = max(n, SEG)
            fresh = KVLedger(newcap, SEG)
            need = (led.reserved + led.in_use + led.shared_in_use
                    + led.lent)
            if need > newcap + led.borrowed:
                with pytest.raises(KVLedgerError):
                    fresh.migrate_from(led)   # shrink rejected...
            else:
                fresh.migrate_from(led)       # ...or carried EXACTLY
                assert fresh.entries == ent
                assert fresh.shared == shr
                assert fresh.lent == led.lent
                assert fresh.borrowed == led.borrowed
                leds[i] = fresh
        check()

    # drain: free every rid and release every refcount — zero leak
    for led, ent, shr in zip(leds, entries, shared):
        for rid in list(ent):
            led.free(rid)
        for key, (_, refs) in list(shr.items()):
            for _ in range(refs):
                led.release_shared(key)
        assert led.in_use == 0 and led.shared_in_use == 0
        assert not led.entries and not led.shared


def test_acquire_shared_size_mismatch_raises():
    led = KVLedger(8 * SEG, SEG)
    assert led.acquire_shared(7, 2 * SEG)
    with pytest.raises(KVLedgerError, match="collision"):
        led.acquire_shared(7, SEG)            # hash collision guard
    assert led.shared_refs(7) == 1            # nothing changed


# ----------------------------------------------------------------------
# sharing-off golden bit-identity (all three engines)
# ----------------------------------------------------------------------
# Captured from the PR 7 tree on the fixed pressure / fabric scenarios
# below: with no prefix_profile and no kv_borrow, the sharing-capable
# engine must not perturb a single event — every counter, latency sum
# and the final event clock stay byte-identical, for the incremental,
# full-rebuild AND reference (fast_path off) engines.
PREFIX_OFF_GOLDEN = {
    "pressure/evict": [24, 2132, 30, 30, 0, 0, 0,
                       12212025.457979, 4016983.948854,
                       8195041.509125, 1624303.296264],
    "pressure/reject": [24, 3088, 42, 0, 42, 0, 0,
                        17339038.22723, 9544632.355104,
                        6852367.88375, 2498489.253263],
    "fabric": [[0, 20, 20, 655360.0, 0, 62566.72, 0],
               [20, 140, 0, 0.0, 406532.357501, 0, 343965.637501],
               103331327.755612],
}


def _pressure_stats(kv_policy, engine):
    cluster = NPUCluster(core=SMALL_CORE, policy="neu10")
    sess = ServingSession(cluster, incremental=(engine != "full"))
    if engine == "ref":
        for s in sess.sims:
            s.fast_path = False
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=96.0, max_len=256, seed=11),
        eu_budget=4, kv_policy=kv_policy,
        hbm_bytes=WSEG + 2 * SEG)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=200_000.0,
                                               n=24, seed=1))
    sess.drain()
    st_ = sess.sim.tenants[chat.sim_idx].stats
    return [st_.requests_done, st_.tokens, st_.kv_evictions,
            st_.kv_swapins, st_.kv_restarts, st_.kv_rejected,
            st_.kv_truncated,
            round(sum(st_.latencies), 6), round(sum(st_.ttft), 6),
            round(sum(st_.tbt), 6), round(sess.sim.now, 6)]


def _fabric_stats(engine):
    sess = ServingSession(
        NPUCluster(core=SMALL_CORE, policy="neu10",
                   topology=FabricTopology.mesh(4)),
        incremental=(engine != "full"))
    if engine == "ref":
        for s in sess.sims:
            s.fast_path = False
    ft = sess.register_generative(
        "chat", CFG, prompt_len=128, gen_lens=8, eu_budget=4,
        placement=Placement(), kv_policy="evict", hbm_bytes=256 * SEG)
    sess.submit_arrivals(ft, PoissonArrivals(rate_rps=200.0, n=20, seed=1))
    sess.drain()
    out = []
    for h in (ft.prefill, ft.decode):
        st_ = sess.sims[h.core_idx].tenants[h.sim_idx].stats
        out.append([st_.requests_done, st_.tokens, st_.kv_migrations,
                    round(st_.kv_migrated_bytes, 6),
                    round(sum(st_.latencies), 6), round(sum(st_.ttft), 6),
                    round(sum(st_.tbt), 6)])
    out.append(round(max(s.now for s in sess.sims), 6))
    return out


@pytest.mark.parametrize("engine", ["inc", "full", "ref"])
@pytest.mark.parametrize("kv_policy", ["evict", "reject"])
def test_sharing_off_pressure_goldens_bit_identical(kv_policy, engine):
    assert (_pressure_stats(kv_policy, engine)
            == PREFIX_OFF_GOLDEN[f"pressure/{kv_policy}"])


@pytest.mark.parametrize("engine", ["inc", "full", "ref"])
def test_sharing_off_fabric_golden_bit_identical(engine):
    assert _fabric_stats(engine) == PREFIX_OFF_GOLDEN["fabric"]


# ----------------------------------------------------------------------
# sharing ON: determinism, engine agreement, pressure composition
# ----------------------------------------------------------------------
def _sharing_session(kv_segs=4, ratio=1.0, n=24, engine="inc",
                     gen_mean=96.0, **reg_kw):
    cluster = NPUCluster(core=SMALL_CORE, policy="neu10")
    sess = ServingSession(cluster, incremental=(engine != "full"))
    if engine == "ref":
        for s in sess.sims:
            s.fast_path = False
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=gen_mean, max_len=256, seed=11),
        eu_budget=4, kv_policy="evict", hbm_bytes=WSEG + kv_segs * SEG,
        prefix_profile=PrefixProfile(prefix_len=64, share_ratio=ratio,
                                     n_prefixes=1, seed=3), **reg_kw)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=200_000.0,
                                               n=n, seed=1))
    return sess, chat


def _sharing_fingerprint(sess, chat):
    st_ = sess.sim.tenants[chat.sim_idx].stats
    return (st_.requests_done, st_.tokens, st_.kv_prefix_hits,
            round(st_.kv_shared_bytes, 6), st_.kv_evictions,
            st_.kv_swapins, round(sum(st_.latencies), 6),
            round(sess.sim.now, 6))


def test_same_seed_hit_miss_sequence_is_deterministic():
    """Two identical sharing runs produce the SAME hit/miss sequence,
    counters and event clock — and all three engines agree."""
    prints = []
    for engine in ("inc", "inc", "full", "ref"):
        sess, chat = _sharing_session(engine=engine)
        sess.drain()
        prints.append(_sharing_fingerprint(sess, chat))
    assert prints[0] == prints[1] == prints[2] == prints[3]
    assert prints[0][2] > 0                   # hits actually happened


def test_evict_mode_swapin_with_resident_prefix():
    """Tight budget + fully-shared prefixes: evictions and swap-resume
    round trips interleave with prefix hits, every request completes,
    and BOTH pools (per-rid and refcounted) drain to zero."""
    sess, chat = _sharing_session(kv_segs=2, ratio=1.0)
    sess.drain()
    st_ = sess.sim.tenants[chat.sim_idx].stats
    assert st_.requests_done == 24
    assert st_.kv_evictions >= 1 and st_.kv_swapins >= 1
    assert st_.kv_prefix_hits >= 1
    led = chat.vnpu.kv_ledger
    assert led.peak_bytes <= led.capacity     # never over-committed
    assert led.in_use == 0 and led.shared_in_use == 0
    assert not led.entries and not led.shared


def test_prefix_hits_charge_suffix_only():
    """On a roomy budget the shared arm's peak ledger occupancy stays
    BELOW the unshared arm's — hits charge the suffix, not the whole
    prompt (the effective-capacity mechanism, asserted on bytes)."""
    sess_on, chat_on = _sharing_session(kv_segs=24, ratio=1.0,
                                        gen_mean=8.0)
    sess_on.drain()
    sess_off, chat_off = _sharing_session(kv_segs=24, ratio=0.0,
                                          gen_mean=8.0)
    sess_off.drain()
    on = sess_on.sim.tenants[chat_on.sim_idx].stats
    off = sess_off.sim.tenants[chat_off.sim_idx].stats
    assert on.requests_done == off.requests_done == 24
    assert on.kv_prefix_hits > 0 and off.kv_prefix_hits == 0
    assert on.kv_peak_bytes < off.kv_peak_bytes


# ----------------------------------------------------------------------
# cross-tenant borrowing: pressure relief + reclaim ordering
# ----------------------------------------------------------------------
def test_borrow_then_owner_burst_reclaims_loans():
    """A squeezed borrower takes idle segments from a co-resident
    owner; when the owner's OWN load arrives, its pressure hook
    reclaims the (by then idle) loans before its admission blocks —
    both tenants complete everything, loans unwind, zero leak."""
    cluster = NPUCluster(core=SMALL_CORE, policy="neu10")
    sess = ServingSession(cluster)
    needy = sess.register_generative(
        "needy", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=96.0, max_len=256, seed=11),
        eu_budget=2, kv_policy="evict", hbm_bytes=WSEG + 2 * SEG,
        kv_borrow=True)
    owner = sess.register_generative(
        "owner", CFG, prompt_len=128, gen_lens=64, eu_budget=2,
        kv_policy="evict", hbm_bytes=WSEG + 8 * SEG)
    man = cluster.manager
    # phase 1: the needy burst borrows the owner's idle segments
    sess.submit_arrivals(needy, PoissonArrivals(rate_rps=200_000.0,
                                                n=24, seed=1))
    sess.run_until(0.01)
    n_st = sess.sim.tenants[needy.sim_idx].stats
    assert n_st.kv_borrowed_bytes > 0
    lent0 = man.loans_of(owner.vnpu)[0]
    assert lent0 > 0
    assert owner.vnpu.kv_ledger.lent == lent0
    assert needy.vnpu.kv_ledger.borrowed == lent0
    # phase 2: the owner bursts — reclaim-on-pressure pulls the loans
    # back (the needy burst is drained, so its segments are idle)
    for i in range(8):
        sess.submit(owner, at_s=0.01 + i * 1e-6)
    sess.drain()
    o_st = sess.sim.tenants[owner.sim_idx].stats
    assert o_st.kv_reclaimed_bytes > 0
    assert man.loans_of(owner.vnpu)[0] < lent0
    assert n_st.requests_done == 24 and o_st.requests_done == 8
    oled, nled = owner.vnpu.kv_ledger, needy.vnpu.kv_ledger
    assert oled.in_use == 0 and nled.in_use == 0
    # conservation: whatever loan remains is symmetric on both sides
    assert oled.lent == nled.borrowed == man.loans_of(owner.vnpu)[0]


def test_borrow_disabled_never_touches_peers():
    """Without kv_borrow the same squeeze stays inside the tenant's
    own allocation: no loans, the co-resident ledger untouched — and
    the run is bit-identical to the single-tenant golden."""
    cluster = NPUCluster(core=SMALL_CORE, policy="neu10")
    sess = ServingSession(cluster)
    chat = sess.register_generative(
        "chat", CFG, prompt_len=128,
        gen_lens=GenLenDistribution(mean=96.0, max_len=256, seed=11),
        eu_budget=2, kv_policy="evict", hbm_bytes=WSEG + 2 * SEG)
    idle = sess.register_generative(
        "idle", CFG, prompt_len=128, gen_lens=8, eu_budget=2,
        kv_policy="evict", hbm_bytes=WSEG + 8 * SEG)
    sess.submit_arrivals(chat, PoissonArrivals(rate_rps=200_000.0,
                                               n=24, seed=1))
    sess.drain()
    st_ = sess.sim.tenants[chat.sim_idx].stats
    assert st_.kv_borrowed_bytes == 0
    assert cluster.manager.loans_of(idle.vnpu) == (0, 0)
    assert idle.vnpu.kv_ledger.lent == 0
    assert chat.vnpu.kv_ledger.borrowed == 0
    assert st_.requests_done == 24


# ----------------------------------------------------------------------
# composition: cross-core migration of a shared-prefix holder
# ----------------------------------------------------------------------
def _fabric_prefix_session(dec_hbm=None, rate=200_000.0, n=20):
    sess = ServingSession(
        NPUCluster(core=SMALL_CORE, policy="neu10",
                   topology=FabricTopology.mesh(4)))
    ft = sess.register_generative(
        "chat", CFG, prompt_len=128, gen_lens=8, eu_budget=4,
        placement=Placement(decode_hbm_bytes=dec_hbm),
        kv_policy="evict", hbm_bytes=256 * SEG,
        prefix_profile=PrefixProfile(prefix_len=64, share_ratio=1.0,
                                     n_prefixes=1, seed=3))
    sess.submit_arrivals(ft, PoissonArrivals(rate_rps=rate, n=n, seed=1))
    return sess, ft


def test_fabric_migration_shared_holder_suffix_only_on_hit():
    """A migrating shared-prefix holder whose key is already resident
    on the decode core moves (and charges) ONLY its suffix; the first
    hand-off first-fills the destination entry. Wire bytes therefore
    undercut the sharing-off baseline, and both cores drain clean."""
    sess, ft = _fabric_prefix_session()
    sess.drain()
    r = sess.report(ft)[0]
    dec_rt = sess.sims[ft.decode.core_idx].tenants[ft.decode.sim_idx]
    assert r.requests_done == 20
    assert r.kv_migrations == 20
    assert r.kv_migration_rejects == 0
    assert dec_rt.stats.kv_prefix_hits > 0    # dst-side resident hits
    # sharing-off baseline on the same scenario moves full context
    plan = ft.prefill.plan
    full_wire = 20 * (plan.kv_prompt_bytes + plan.kv_token_bytes)
    assert r.kv_migrated_bytes < full_wire
    for h in (ft.prefill, ft.decode):
        led = h.vnpu.kv_ledger
        assert led.in_use == 0 and led.shared_in_use == 0
        assert not led.entries and not led.shared


def test_fabric_migration_reject_releases_dst_prefix_ref():
    """Destination pressure with sharing on: a rejected hand-off must
    leave ALL ledgers untouched — including the destination's shared
    pool (the attach is rolled back) — and the request still
    completes locally."""
    probe = request_plan(CFG, 1, 256, 1, core=SMALL_CORE)
    dec_hbm = -(-int(probe.weight_bytes
                     + 1.2 * probe.kv_prompt_bytes) // SEG) * SEG
    sess = ServingSession(
        NPUCluster(core=SMALL_CORE, policy="neu10",
                   topology=FabricTopology.ring(4)))
    ft = sess.register_generative(
        "chat", CFG, prompt_len=256, gen_lens=32, eu_budget=4,
        placement=Placement(decode_hbm_bytes=dec_hbm),
        kv_policy="evict", hbm_bytes=512 * SEG,
        prefix_profile=PrefixProfile(prefix_len=64, share_ratio=1.0,
                                     n_prefixes=1, seed=3))
    sess.submit_arrivals(ft, PoissonArrivals(rate_rps=3000.0, n=16, seed=3))
    sess.drain()
    r = sess.report(ft)[0]
    assert r.requests_done == 16              # rejects completed locally
    assert r.kv_migration_rejects >= 1
    assert r.kv_migrations + r.kv_migration_rejects == 16
    for h in (ft.prefill, ft.decode):
        led = h.vnpu.kv_ledger
        assert led.peak_bytes <= led.capacity
        assert led.in_use == 0 and led.shared_in_use == 0
        assert not led.entries and not led.shared


# ----------------------------------------------------------------------
# resize regression: shared + lent segments are never stranded
# ----------------------------------------------------------------------
def test_migrate_from_refuses_to_strand_shared_and_lent():
    led = KVLedger(12 * SEG, SEG, reserved_bytes=2 * SEG)
    assert led.alloc(1, 2 * SEG)
    assert led.acquire_shared(7, 3 * SEG)
    assert led.lend(2 * SEG)
    # occupancy = 2 (weights) + 2 (rid) + 3 (shared) + 2 (lent) = 9 seg
    small = KVLedger(8 * SEG, SEG)
    with pytest.raises(KVLedgerError, match="occupancy"):
        small.migrate_from(led)
    assert led.shared_refs(7) == 1            # source untouched
    assert led.lent == 2 * SEG
    fits = KVLedger(9 * SEG, SEG)
    fits.migrate_from(led)                    # exact carry at the floor
    assert fits.shared == {7: [3 * SEG, 1]}
    assert fits.lent == 2 * SEG and fits.in_use == 2 * SEG


def test_live_resize_keeps_shared_prefix_segments():
    """Session-level regression for the shrink audit: a mid-run resize
    while refcounted prefix entries are resident must keep the HBM
    allocation >= the FULL occupancy (weights + rid KV + shared) —
    shrinking the shared segments out from under their holders would
    corrupt every other holder's hit."""
    sess, chat = _sharing_session(kv_segs=4)
    sess.run_until(2e-4)
    led = chat.vnpu.kv_ledger
    assert led.shared_in_use > 0              # a prefix entry is live
    for eu in (6, 2, 4):
        try:
            sess.resize(chat, eu)
        except ReconfigureError:
            pass                              # reject is legal...
        led = chat.vnpu.kv_ledger
        # ...stranding resident shared segments is not
        assert led.capacity >= led.occupancy
        assert led.shared_in_use == sum(b for b, _ in led.shared.values())
    sess.drain()
    st_ = sess.sim.tenants[chat.sim_idx].stats
    assert st_.requests_done == 24
    led = chat.vnpu.kv_ledger
    assert led.in_use == 0 and led.shared_in_use == 0 and not led.shared


# ----------------------------------------------------------------------
# LRU retention window: zero-holder entries linger, revive for free,
# expire on schedule, and are first-choice eviction victims
# ----------------------------------------------------------------------
def test_retention_parks_and_revives_for_free():
    led = KVLedger(8 * SEG, SEG)
    led.retention_window = 100.0
    led.acquire_shared(7, 2 * SEG)
    assert led.release_shared(7, now=10.0) == 0   # parked, not freed
    assert led.shared_refs(7) == 0
    assert led.retired_bytes == 2 * SEG
    assert led.shared_in_use == 2 * SEG           # bytes stay charged
    assert led.acquire_shared(7, 2 * SEG)         # retention HIT...
    assert led.shared_refs(7) == 1
    assert led.retired_bytes == 0                 # ...revived in place
    with pytest.raises(KVLedgerError, match="collision"):
        led.release_shared(7, now=20.0)
        led.acquire_shared(7, SEG)                # size mismatch guard


def test_retention_expiry_and_pressure_eviction_order():
    led = KVLedger(8 * SEG, SEG)
    led.retention_window = 100.0
    for key, t in ((1, 0.0), (2, 50.0)):
        led.acquire_shared(key, SEG)
        led.release_shared(key, now=t)            # expire at 100 / 150
    assert led.expire_retired(now=99.0) == 0      # neither lapsed yet
    assert led.expire_retired(now=100.0) == SEG   # exactly on schedule
    assert led.retired_bytes == SEG
    # pressure eviction takes the oldest-expiry survivor
    assert led.evict_retired(SEG, now=120.0) == SEG
    assert led.retired_bytes == 0 and led.shared_in_use == 0


def test_retention_flush_clear_and_migrate_carry():
    led = KVLedger(8 * SEG, SEG)
    led.retention_window = 100.0
    led.acquire_shared(3, 2 * SEG)
    led.release_shared(3, now=5.0)
    dst = KVLedger(8 * SEG, SEG)
    dst.migrate_from(led)                         # failover carries the
    assert dst.retired_bytes == 2 * SEG           # retention table...
    assert dst.retention_window == 100.0          # ...and the window
    assert dst.acquire_shared(3, 2 * SEG)         # still revivable
    assert led.flush_retired() == 2 * SEG         # teardown frees all
    assert led.shared_in_use == 0
    led.acquire_shared(4, SEG)
    led.release_shared(4, now=6.0)
    led.clear()                                   # per-request wipe
    assert led.retired_bytes == 0 and led.shared_in_use == 0


def test_retention_off_is_inert():
    led = KVLedger(8 * SEG, SEG)                  # window defaults to 0
    led.acquire_shared(9, SEG)
    assert led.release_shared(9, now=10.0) == SEG  # frees immediately
    assert led.retired_bytes == 0 and led.shared_in_use == 0


def test_session_retention_turns_thrash_into_hits():
    """Same sharing workload, zero vs generous retention: with the
    window on, a prefix whose holders all drain before the next
    arrival revives from the retired table instead of re-filling."""
    base_sess, base_chat = _sharing_session(kv_segs=8, gen_mean=8.0)
    base_sess.drain()
    base = _sharing_fingerprint(base_sess, base_chat)

    sess, chat = _sharing_session(kv_segs=8, gen_mean=8.0,
                                  kv_retention_ms=50.0)
    sess.drain()
    led = chat.vnpu.kv_ledger
    st_ = sess.sim.tenants[chat.sim_idx].stats
    assert st_.requests_done == base[0]
    assert st_.kv_prefix_hits >= base_sess.sim.tenants[
        base_chat.sim_idx].stats.kv_prefix_hits
    assert led.in_use == 0
    assert led.shared_in_use == led.retired_bytes  # only retained bytes
    led.flush_retired()
    assert led.shared_in_use == 0
