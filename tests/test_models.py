"""Per-architecture smoke tests (deliverable f): every assigned arch,
reduced config, one forward/train step on CPU — shapes + no NaNs —
plus decode-vs-prefill consistency and analytic-param-count cross
checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKES
from repro.models import build_model
from repro.launch.steps import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    k1, k2 = jax.random.split(KEY)
    if cfg.family == "audio":
        shape = (B, cfg.n_codebooks, S)
    else:
        shape = (B, S)
    batch = {
        "tokens": jax.random.randint(k1, shape, 0, cfg.vocab_size),
        "targets": jax.random.randint(k2, shape, 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, : S - cfg.n_patches]
        batch["targets"] = batch["targets"][:, : S - cfg.n_patches]
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_smoke_train_step(arch):
    cfg = SMOKES[arch]
    model = build_model(cfg)
    state = init_train_state(model, KEY)
    step = jax.jit(make_train_step(model, warmup=1))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # logits shape sanity via model.logits
    lg, _ = build_model(cfg, remat=False).logits(state["params"], batch)
    if cfg.family == "audio":
        assert lg.shape == (2, 32, cfg.n_codebooks, cfg.vocab_size)
    elif cfg.family == "vlm":
        assert lg.shape[-1] == cfg.vocab_size
        assert lg.shape[1] == 32  # patches + text
    else:
        assert lg.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg)))


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_decode_matches_prefill(arch):
    """Greedy decode logits must equal full-sequence forward logits —
    the KV-cache/state machinery is exact."""
    cfg = SMOKES[arch]
    model = build_model(cfg, remat=False)
    params = model.init(KEY)
    B, S = 2, 16
    if cfg.family == "audio":
        toks = jax.random.randint(KEY, (B, cfg.n_codebooks, S + 1), 0,
                                  cfg.vocab_size)
        ctx, nxt = toks[..., :S], toks[..., S:]
    else:
        toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
        ctx, nxt = toks[:, :S], toks[:, S:]
    batch = {"tokens": ctx}
    n_prefix = cfg.n_patches if cfg.family == "vlm" else 0
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_patches, cfg.d_model))
    cache = model.init_cache(B, S + n_prefix + 8)
    lg_pre, cache = jax.jit(model.prefill)(params, batch, cache)
    lg_dec, _ = jax.jit(model.decode_step)(
        params, cache, {"tokens": nxt,
                        "cache_index": jnp.asarray(S + n_prefix, jnp.int32)})
    # reference: full forward over S+1 tokens
    batch_ext = dict(batch, tokens=toks)
    cache2 = model.init_cache(B, S + n_prefix + 8)
    lg_full, _ = jax.jit(model.prefill)(params, batch_ext, cache2)
    np.testing.assert_allclose(
        np.asarray(lg_full[:, -1]), np.asarray(lg_dec[:, 0]),
        rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", sorted(SMOKES))
def test_param_count_formula_matches_init(arch):
    """The analytic count that feeds 6ND model-FLOPs and the NPU cost
    model must equal the real initialized parameter count."""
    cfg = SMOKES[arch]
    model = build_model(cfg)
    params = model.init(KEY)
    real = sum(int(np.prod(p.shape))
               for p in jax.tree_util.tree_leaves(params))
    assert real == cfg.param_count(), (
        f"{arch}: analytic {cfg.param_count()} != real {real}")


def test_full_configs_match_published_sizes():
    expected_b = {
        "qwen2-moe-a2.7b": (13.5, 15.0),
        "dbrx-132b": (125.0, 136.0),
        "xlstm-350m": (0.3, 0.55),
        "qwen3-14b": (13.5, 15.5),
        "minicpm-2b": (2.4, 3.0),
        "qwen2-0.5b": (0.4, 0.55),
        "qwen2-72b": (70.0, 75.0),
        "internvl2-1b": (0.4, 0.6),   # LM backbone only (ViT stub)
        "zamba2-7b": (6.0, 7.6),
        "musicgen-large": (1.5, 2.6),
    }
    for arch, (lo, hi) in expected_b.items():
        n = ARCHS[arch].param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_capacity_drops_gracefully():
    """Force tiny capacity: outputs stay finite (dropped tokens fall
    through on the residual)."""
    from repro.models.moe import moe_apply, moe_init

    cfg = SMOKES["qwen2-moe-a2.7b"]
    p = moe_init(cfg, KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = moe_apply(p, cfg, x, capacity_factor=0.25)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0
