"""Sharding rules: DP over (`pod`,`data`), TP/EP over `model`.

Every rule checks divisibility against the mesh axis size and falls
back (alternate dim, then replicate) — non-divisible cases (e.g.
qwen3's 40 heads or minicpm's 122753 vocab on a 16-way axis) degrade
gracefully instead of failing to lower. The fallbacks taken are
queryable (``explain_params``) and recorded in the dry-run report.

Only params + inputs + caches are constrained; intermediate layouts
are left to GSPMD propagation (and then audited via the roofline HLO
dump).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell

MODEL_AX = "model"


def dp_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def _ax(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _div(n: int, k: int) -> bool:
    return n % k == 0


# ----------------------------------------------------------------------
def param_specs(cfg: ModelConfig, params_shapes, mesh: Mesh):
    """PartitionSpec pytree matching the params pytree.

    ``params_shapes``: pytree of ShapeDtypeStruct (from eval_shape).
    """
    M = _ax(mesh, MODEL_AX)
    heads_ok = _div(cfg.n_heads, M)
    kv_ok = _div(cfg.n_kv_heads, M)

    def spec(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        nd = len(shape)
        # stacked-layer leading dims (L,) / (reps, k) are never sharded.
        def col(last_ok: bool, row_dim: int = -2) -> P:
            """shard last dim on model (col-parallel) w/ fallbacks."""
            spec = [None] * nd
            if last_ok and _div(shape[-1], M):
                spec[-1] = MODEL_AX
            return P(*spec)

        def row() -> P:
            spec = [None] * nd
            if _div(shape[-2], M):
                spec[-2] = MODEL_AX
            return P(*spec)

        # ---- embeddings / unembeddings ----
        if name == "embed":
            spec = [None] * nd
            if _div(shape[-2], M):          # vocab
                spec[-2] = MODEL_AX
            elif _div(shape[-1], M):        # d_model fallback
                spec[-1] = MODEL_AX
            return P(*spec)
        if name == "lm_head":
            spec = [None] * nd
            if _div(shape[-1], M):
                spec[-1] = MODEL_AX
            elif _div(shape[-2], M):
                spec[-2] = MODEL_AX
            return P(*spec)

        # ---- attention ----
        if cfg.xlstm_pattern and name in ("wq", "wk", "wv"):
            # mLSTM q/k/v: 4 heads never divide the model axis; shard
            # the contraction/feature dim instead (GSPMD inserts the
            # per-chunk psum) — xlstm-350m is tiny, traffic negligible.
            return col(True)
        if name == "wq":
            return col(heads_ok)
        if name in ("wk", "wv"):
            return col(kv_ok)
        if name == "wo":
            sp = [None] * nd
            if heads_ok and _div(shape[-2], M):
                sp[-2] = MODEL_AX
            return P(*sp)
        if name == "bq":
            return col(heads_ok)
        if name in ("bk", "bv"):
            return col(kv_ok)

        # ---- MoE experts: leading (L, E, ...) ----
        if name in ("w_gate", "w_up", "w_down") and nd >= 4:
            expert_mode = cfg.moe_shard == "expert" and _div(cfg.n_experts, M)
            sp = [None] * nd
            if expert_mode:
                sp[-3] = MODEL_AX            # E dim
            elif name in ("w_gate", "w_up") and _div(shape[-1], M):
                sp[-1] = MODEL_AX            # ffn cols
            elif name == "w_down" and _div(shape[-2], M):
                sp[-2] = MODEL_AX            # ffn rows
            return P(*sp)

        # ---- dense / shared-expert MLP ----
        if name in ("w_gate", "w_up"):
            return col(True)
        if name == "w_down":
            return row()

        # ---- mamba2 ----
        if name in ("w_z", "w_x"):
            return col(True)
        if name == "out_proj":
            return row()
        if name in ("conv_w", "conv_b") and cfg.family in ("ssm", "hybrid"):
            return col(True)
        if name in ("A_log", "D", "dt_bias"):
            return col(_div(cfg.ssm_heads or 1, M))
        if name == "gate_norm":
            return col(True)

        # ---- xLSTM ----
        if name == "w_gates":               # sLSTM (up, 4up): aligned splits
            return col(_div(shape[-1], 4 * M))
        # mLSTM w_up (d, 2up): z/u split aligns iff up % shard == 0
        if name == "w_up" and cfg.xlstm_pattern:
            return col(_div(shape[-1], 2 * M))

        # everything else (norms, router, small gates): replicate
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec, params_shapes)


def explain_params(cfg: ModelConfig, params_shapes, mesh: Mesh) -> Dict[str, str]:
    """Human-readable {param_path: spec} — used in the dry-run report."""
    specs = param_specs(cfg, params_shapes, mesh)
    out = {}

    def fmt(path, s, leaf):
        key = "/".join(str(getattr(k, "key", k)) for k in path)
        out[key] = f"{tuple(leaf.shape)} -> {s}"

    jax.tree_util.tree_map_with_path(
        lambda pth, s, l: fmt(pth, s, l), specs, params_shapes)
    return out


# ----------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, kind: str, mesh: Mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    toks = P(dp, None, None) if cfg.family == "audio" else P(dp, None)
    d: Dict[str, P] = {"tokens": toks}
    if kind == "train":
        d["targets"] = toks
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        d["patch_embeds"] = P(dp, None, None)
    if kind == "decode":
        d["cache_index"] = P()
    return d


def cache_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh, cache_shapes,
                kv_hd_shard: bool = False):
    """Specs for the KV-cache / recurrent-state pytree.

    decode_32k: batch over DP, kv-heads over model (when divisible).
    long_500k (batch=1): SEQUENCE over `data` (KV) and SSM heads over
    `model` — the sub-quadratic long-context layout.
    ``kv_hd_shard``: perf-iteration knob — when the kv-head count
    doesn't divide the model axis (GQA kv=8 on 16 shards), shard the
    HEAD-DIM channels instead (128/16=8); attention contracts hd so
    GSPMD adds a small psum but the cache bytes/device drop 16x.
    """
    M = _ax(mesh, MODEL_AX)
    dp = dp_axes(mesh)
    batch_shardable = _div(cell.global_batch, int(np.prod(
        [mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,))])))
    kv_ok = _div(cfg.n_kv_heads, M)
    ssm_heads_ok = _div(cfg.ssm_heads or 1, M)

    def spec(path, leaf) -> P:
        keys = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        nd = len(shape)
        if name in ("kv_k", "kv_v") or nd == 5 and "mamba" not in keys:
            # attention KV: (L|n_attn, B, S, kv, hd)
            sp = [None] * nd
            if batch_shardable:
                sp[1] = dp
            elif _div(shape[2], 16) and cell.global_batch == 1:
                sp[2] = "data"               # sequence-sharded cache
            if kv_ok:
                sp[3] = MODEL_AX
            elif kv_hd_shard and _div(shape[4], M):
                sp[4] = MODEL_AX
            return P(*sp)
        if name == "ssm":
            # (L, B, nh, st, hd)
            sp = [None] * nd
            if batch_shardable:
                sp[1] = dp
            if ssm_heads_ok:
                sp[2] = MODEL_AX
            return P(*sp)
        if name in ("conv", "conv_bc"):
            sp = [None] * nd
            if batch_shardable:
                sp[1] = dp
            if name == "conv" and _div(shape[-1], M):
                sp[-1] = MODEL_AX
            return P(*sp)
        if name == "C":                      # mLSTM matrix memory
            sp = [None] * nd
            if batch_shardable:
                sp[2] = dp
            return P(*sp)
        if name in ("c", "n", "h"):          # sLSTM
            sp = [None] * nd
            if batch_shardable:
                sp[2] = dp
            return P(*sp)
        # plain transformer tuple-cache leaves: (L, B, S, kv, hd)
        sp = [None] * nd
        if nd == 5:
            if batch_shardable:
                sp[1] = dp
            elif _div(shape[2], 16) and cell.global_batch == 1:
                sp[2] = "data"
            if kv_ok:
                sp[3] = MODEL_AX
        return P(*sp)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def state_specs(cfg: ModelConfig, mesh: Mesh, param_spec_tree):
    """Train state = {params, m, v, step}: moments follow params."""
    return {
        "params": param_spec_tree,
        "m": param_spec_tree,
        "v": param_spec_tree,
        "step": P(),
    }


def named(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def sanitize_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the
    dim — last-resort guard so no input can fail to lower."""
    out = []
    for i, entry in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        k = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if shape[i] % k == 0 else None)
    return P(*out)


def maybe_constrain(x, *entries):
    """with_sharding_constraint that degrades to identity when no
    ambient mesh (or none with the named axes) is present — layers can
    call it unconditionally; single-device tests are unaffected."""
    try:
        mesh = None
        try:  # legacy `with mesh:` context (what pjit tracing sees)
            from jax._src import mesh as _mesh_lib

            env = _mesh_lib.thread_resources.env.physical_mesh
            if env is not None and not env.empty:
                mesh = env
        except Exception:
            pass
        if mesh is None:
            mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return x
        names = set(mesh.axis_names)
        cleaned = []
        for e in entries:
            if e is None:
                cleaned.append(None)
                continue
            axes = tuple(a for a in (e if isinstance(e, tuple) else (e,))
                         if a in names)
            if not axes:
                cleaned.append(None)
            elif len(axes) == 1:
                cleaned.append(axes[0])
            else:
                cleaned.append(axes)
        return jax.lax.with_sharding_constraint(x, P(*cleaned))
    except Exception:
        return x


def shardings_for(shapes_tree, spec_tree, mesh: Mesh):
    """Zip a ShapeDtypeStruct tree with a PartitionSpec tree into
    NamedShardings, sanitizing non-divisible entries. (P is a tuple
    subclass, so a plain two-tree tree_map would recurse into it —
    flatten with is_leaf instead.)"""
    flat_shapes, tdef = jax.tree_util.tree_flatten(shapes_tree)
    flat_specs = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    out = [
        NamedSharding(mesh, sanitize_spec(tuple(s.shape), sp, mesh))
        for s, sp in zip(flat_shapes, flat_specs)
    ]
    return jax.tree_util.tree_unflatten(tdef, out)
