from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    dp_axes,
    param_specs,
    state_specs,
)

__all__ = ["param_specs", "batch_specs", "cache_specs", "state_specs",
           "dp_axes"]
