"""Gradient compression for slow cross-pod links: int8 quantization
with error feedback (1-bit-Adam-style residual correction).

At 2x16x16 scale the `pod` axis crosses the slow inter-pod links;
all-reducing fp32/bf16 grads there dominates step time. Per-tensor
symmetric int8 cuts that traffic 4x (vs fp32); the quantization error
is carried in a residual and re-added next step, which keeps
convergence (tested in tests/test_train.py::test_compressed_matches).

Usage inside a step:
    q, scale, new_resid = quantize_ef(g, resid)
    q_sum = lax.psum(q.astype(f32), 'pod')     # int8 payload on the wire
    g = dequantize(q_sum, scale_sum)
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_ef(g: jax.Array, resid: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Symmetric per-tensor int8 with error feedback.
    Returns (q_int8, scale, new_resid)."""
    g32 = g.astype(jnp.float32) + resid
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_resid = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_resid


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, resids):
    """Tree-wise quantize; returns (q_tree, scale_tree, resid_tree)."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(resids)
    qs, ss, rs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = quantize_ef(g, r)
        qs.append(q)
        ss.append(s)
        rs.append(nr)
    unf = lambda xs: jax.tree_util.tree_unflatten(tdef, xs)
    return unf(qs), unf(ss), unf(rs)


def decompress_tree(q_tree, scale_tree):
    return jax.tree_util.tree_map(dequantize, q_tree, scale_tree)


def init_residuals(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
