"""Fault tolerance runtime helpers: straggler detection and the
failure/restart state machine used by the train loop.

On real clusters step times are collected per host via the
coordination service; here the monitor consumes whatever step-time
stream the loop feeds it (the tests feed synthetic distributions).
Policy mirrors production practice:

* straggler: host's EMA step time > `threshold` x median EMA
  -> flagged; after `grace` consecutive flags it is declared failed
  (the scheduler would then evict + trigger elastic restart).
* failure: missing heartbeat for `heartbeat_timeout` steps.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set


@dataclass
class StragglerMonitor:
    n_hosts: int
    threshold: float = 1.5
    grace: int = 3
    ema_alpha: float = 0.3
    _ema: Dict[int, float] = field(default_factory=dict)
    _flags: Dict[int, int] = field(default_factory=dict)
    _last_seen: Dict[int, int] = field(default_factory=dict)
    heartbeat_timeout: int = 10

    def observe(self, step: int, host: int, step_time: float) -> None:
        prev = self._ema.get(host, step_time)
        self._ema[host] = (self.ema_alpha * step_time
                           + (1 - self.ema_alpha) * prev)
        self._last_seen[host] = step

    def stragglers(self) -> Set[int]:
        if len(self._ema) < 2:
            return set()
        med = sorted(self._ema.values())[len(self._ema) // 2]
        out = set()
        for h, t in self._ema.items():
            if t > self.threshold * med:
                self._flags[h] = self._flags.get(h, 0) + 1
                if self._flags[h] >= self.grace:
                    out.add(h)
            else:
                self._flags[h] = 0
        return out

    def failed(self, now_step: int) -> Set[int]:
        return {
            h for h in range(self.n_hosts)
            if now_step - self._last_seen.get(h, now_step)
            > self.heartbeat_timeout
        }

    def healthy_hosts(self, now_step: int) -> List[int]:
        bad = self.failed(now_step) | self.stragglers()
        return [h for h in range(self.n_hosts) if h not in bad]
