import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input
shape) on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh,
prove it fits (memory_analysis), and harvest the roofline terms
(cost_analysis + post-SPMD collective bytes).

The two lines above MUST stay the first statements in this module —
jax locks the device count at first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
      --shape train_4k --mesh multi                           # one cell
  ... --out results/dryrun.jsonl        (resumable: done cells skipped)
"""
import argparse
import gc
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_SHAPES, ARCHS, SHAPES_BY_NAME, shape_applicable
from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    dp_axes,
    named,
    param_specs,
    sanitize_spec,
    shardings_for,
    state_specs,
)
from repro.launch.hlo_analysis import hlo_metrics
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models.registry import build_model
from repro.npu.hw_config import V5E

DTYPE = jnp.bfloat16


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for every model input of this cell."""
    model = build_model(cfg)
    shapes = model.batch_shapes(cell.kind, cell.global_batch, cell.seq_len)
    specs = batch_specs(cfg, cell.kind, mesh)
    out = {}
    for k, (shp, dt) in shapes.items():
        sh = None
        if k in specs:
            sh = jax.sharding.NamedSharding(
                mesh, sanitize_spec(shp, specs[k], mesh))
        out[k] = _sds(shp, dt, sh)
    return out


def _eval_shapes(fn, *args):
    return jax.eval_shape(fn, *args)


def analyze_cell(
    arch_id: str,
    cfg: ModelConfig,
    cell: ShapeCell,
    mesh,
    mesh_name: str,
    opts: frozenset = frozenset(),
) -> Dict[str, Any]:
    """opts — perf-iteration knobs (see EXPERIMENTS.md §Perf):
      flash        chunked online-softmax attention (no S^2 scores)
      pad_vocab    pad vocab to 256-multiple so it TP-shards
      kv_shard_hd  shard KV cache head-dim when kv-heads don't divide
      last_logit   prefill emits last-position logits only
    """
    t0 = time.time()
    if "pad_vocab" in opts:
        cfg = cfg.replace(pad_vocab=True)
    use_flash: Any = "flash" in opts
    if "flash_cp" in opts:
        use_flash = "cp"   # chunked + context-parallel q
    model = build_model(
        cfg, remat=True,
        use_flash=use_flash,
        prefill_last_only="last_logit" in opts)
    key = jax.random.PRNGKey(0)

    p_shapes = jax.eval_shape(lambda k: model.init(k, DTYPE), key)
    p_spec = param_specs(cfg, p_shapes, mesh)
    p_shard = named(mesh, p_spec)
    params_in = jax.tree_util.tree_map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), p_shapes, p_shard)
    batch_in = input_specs(cfg, cell, mesh)

    n_chips = int(np.prod(list(mesh.shape.values())))
    branch_scale = 1.0
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        branch_scale = 1.0 / cfg.hybrid_attn_every

    # some perf variants use with_sharding_constraint(PartitionSpec)
    # internally, which needs an ambient mesh during tracing
    mesh_ctx = mesh
    if cell.kind == "train":
        st_spec = state_specs(cfg, mesh, p_spec)
        st_shard = named(mesh, st_spec)
        m_in = jax.tree_util.tree_map(
            lambda s, sh: _sds(s.shape, jnp.float32, sh), p_shapes,
            st_shard["m"])
        state_in = {
            "params": params_in,
            "m": m_in,
            "v": m_in,
            "step": _sds((), jnp.int32),
        }
        step = make_train_step(model)
        jitted = jax.jit(step, donate_argnums=(0,))
        with mesh_ctx:
            lowered = jitted.lower(state_in, batch_in)
    elif cell.kind == "prefill":
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(cell.global_batch, cell.seq_len, DTYPE))
        c_shard = shardings_for(
            cache_shapes,
            cache_specs(cfg, cell, mesh, cache_shapes,
                        kv_hd_shard="kv_shard_hd" in opts), mesh)
        cache_in = jax.tree_util.tree_map(
            lambda s, sh: _sds(s.shape, s.dtype, sh), cache_shapes, c_shard)
        step = make_prefill_step(model)
        jitted = jax.jit(step, donate_argnums=(2,))
        with mesh_ctx:
            lowered = jitted.lower(params_in, batch_in, cache_in)
    else:  # decode
        kv_dtype = jnp.float8_e4m3fn if "kv8" in opts else DTYPE
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(cell.global_batch, cell.seq_len,
                                     kv_dtype))
        c_shard = shardings_for(
            cache_shapes,
            cache_specs(cfg, cell, mesh, cache_shapes,
                        kv_hd_shard="kv_shard_hd" in opts), mesh)
        cache_in = jax.tree_util.tree_map(
            lambda s, sh: _sds(s.shape, s.dtype, sh), cache_shapes, c_shard)
        step = make_decode_step(model)
        jitted = jax.jit(step, donate_argnums=(1,))
        with mesh_ctx:
            lowered = jitted.lower(params_in, cache_in, batch_in)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-trip-aware accounting from the partitioned HLO text —
    # XLA's cost_analysis() visits scan bodies once and would
    # under-count layer-scanned models by ~n_layers x.
    colls = hlo_metrics(hlo, branch_scale=branch_scale)

    flops = colls.pop("hlo_flops")
    bytes_accessed = colls.pop("hlo_bytes")
    coll_b = colls["total_weighted_bytes"]

    compute_s = flops / V5E.peak_flops_bf16
    memory_s = bytes_accessed / V5E.hbm_bw
    collective_s = coll_b / V5E.ici_bw

    # model flops (6ND train / 2ND forward) across the whole step
    n_active = cfg.active_param_count()
    tokens = cell.tokens
    model_flops = (6.0 if cell.kind == "train" else 2.0) * n_active * tokens
    model_flops_per_chip = model_flops / n_chips

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    row: Dict[str, Any] = {
        "arch": arch_id,
        "shape": cell.name,
        "mesh": mesh_name,
        "opts": sorted(opts),
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "xla_cost_flops": float(cost.get("flops", 0.0) or 0.0),
        "xla_cost_bytes": float(cost.get("bytes accessed", 0.0) or 0.0),
        "collective_bytes_per_device": coll_b,
        "compute_term_s": compute_s,
        "memory_term_s": memory_s,
        "collective_term_s": collective_s,
        "dominant": dominant,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": (model_flops_per_chip / flops) if flops else 0.0,
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "collectives": {k: v for k, v in colls.items() if v},
    }
    return row


def run(args) -> None:
    done = set()
    if args.out and os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skip"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    opts = frozenset(o for o in (args.opt or "").split(",") if o)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    out_f = open(args.out, "a") if args.out else None
    n_ok = n_fail = 0
    for mesh_name, mesh in meshes:
        for arch_id, cfg in ARCHS.items():
            if args.arch and arch_id != args.arch:
                continue
            for cell in ALL_SHAPES:
                if args.shape and cell.name != args.shape:
                    continue
                if (arch_id, cell.name, mesh_name) in done:
                    continue
                ok, why = shape_applicable(cfg, cell)
                if not ok:
                    row = {"arch": arch_id, "shape": cell.name,
                           "mesh": mesh_name, "status": "skip",
                           "reason": why}
                else:
                    try:
                        row = analyze_cell(arch_id, cfg, cell, mesh,
                                           mesh_name, opts=opts)
                        n_ok += 1
                    except Exception as e:  # record and continue
                        row = {"arch": arch_id, "shape": cell.name,
                               "mesh": mesh_name, "status": "error",
                               "error": f"{type(e).__name__}: {e}",
                               "trace": traceback.format_exc()[-2000:]}
                        n_fail += 1
                msg = (f"[{mesh_name}] {arch_id} x {cell.name}: "
                       f"{row['status']}")
                if row["status"] == "ok":
                    msg += (f" compile={row['compile_s']:.1f}s"
                            f" dom={row['dominant']}"
                            f" flops/dev={row['flops_per_device']:.3e}")
                elif row["status"] == "error":
                    msg += f" ({row['error'][:160]})"
                print(msg, flush=True)
                if out_f:
                    out_f.write(json.dumps(row) + "\n")
                    out_f.flush()
                gc.collect()
    print(f"dry-run finished: {n_ok} ok, {n_fail} failed", flush=True)
    if out_f:
        out_f.close()
    if n_fail:
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS) + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES_BY_NAME) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--opt", default="",
                    help="comma list: flash,pad_vocab,kv_shard_hd,last_logit")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    run(args)


if __name__ == "__main__":
    main()
