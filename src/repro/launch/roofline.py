"""Roofline report: reads the dry-run JSONL and emits the
EXPERIMENTS.md tables.

Per (arch x shape) on the single-pod mesh:
  compute_s    = HLO_FLOPs_per_device / 197e12     (bf16 peak, v5e)
  memory_s     = HLO_bytes_per_device / 819e9      (HBM BW)
  collective_s = collective_bytes_per_device / 50e9 (ICI link BW)
plus the dominant term, MODEL_FLOPS (6ND / 2ND) per chip, and the
useful-FLOPs ratio (model/HLO — remat & redundancy show up here).

Usage: PYTHONPATH=src python -m repro.launch.roofline \
           [--in results/dryrun.jsonl] [--mesh single]
"""
from __future__ import annotations

import argparse
import json
from collections import OrderedDict
from typing import Dict, List


def load_rows(path: str, mesh: str = "single") -> List[Dict]:
    rows: "OrderedDict[tuple, Dict]" = OrderedDict()
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except Exception:
                continue
            if r.get("mesh") != mesh:
                continue
            rows[(r["arch"], r["shape"])] = r  # last write wins (resume)
    return list(rows.values())


def _fmt_s(x: float) -> str:
    if x <= 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | status | compute | memory | collective | "
           "dominant | roofline frac | useful FLOPs | mem/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        if r["status"] == "skip":
            out.append(
                f"| {r['arch']} | {r['shape']} | SKIP | - | - | - | - | - |"
                f" - | {r.get('reason','')[:48]} |")
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | - | - |"
                f" - | {r.get('error','')[:48]} |")
            continue
        c, m, k = (r["compute_term_s"], r["memory_term_s"],
                   r["collective_term_s"])
        bound = max(c, m, k)
        total = c + m + k
        frac = c / bound if bound else 0.0  # compute frac of the bound
        temp = r["mem"]["temp_bytes"] / 2**30
        args = (r["mem"]["argument_bytes"] - r["mem"]["alias_bytes"]) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {_fmt_s(c)} | {_fmt_s(m)} |"
            f" {_fmt_s(k)} | {r['dominant'].replace('_term_s','').replace('_s','')} |"
            f" {frac:.2f} | {r['useful_flops_ratio']:.2f} |"
            f" {args + temp:.2f}GiB |")
    return "\n".join(out)


def summarize(rows: List[Dict]) -> Dict:
    ok = [r for r in rows if r["status"] == "ok"]
    dom: Dict[str, int] = {}
    for r in ok:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    worst = sorted(
        ok, key=lambda r: r["compute_term_s"]
        / max(r["compute_term_s"] + r["memory_term_s"]
              + r["collective_term_s"], 1e-30))
    coll = sorted(ok, key=lambda r: -r["collective_term_s"])
    return {
        "n_ok": len(ok),
        "dominant_counts": dom,
        "worst_compute_frac": [(r["arch"], r["shape"]) for r in worst[:5]],
        "most_collective": [(r["arch"], r["shape"],
                             r["collective_term_s"]) for r in coll[:5]],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = load_rows(args.inp, args.mesh)
    print(markdown_table(rows))
    print()
    s = summarize(rows)
    print(f"cells ok: {s['n_ok']}; dominant terms: {s['dominant_counts']}")
    print("worst compute fraction:", s["worst_compute_frac"])
    print("most collective-bound:", s["most_collective"])


if __name__ == "__main__":
    main()
