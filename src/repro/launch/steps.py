"""Step functions the launch layer lowers/executes: train_step (fwd +
bwd + AdamW + WSD schedule), prefill_step, decode_step."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import wsd_schedule

TrainState = Dict[str, Any]


def init_train_state(model: Model, key, dtype=jnp.float32) -> TrainState:
    params = model.init(key, dtype)
    opt = adamw_init(params)
    return {"params": params, **opt}


def make_train_step(model: Model, peak_lr: float = 3e-4,
                    warmup: int = 2000, stable: int = 80_000,
                    decay: int = 20_000):
    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        def loss_fn(params):
            loss, metrics = model.loss(params, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        lr = wsd_schedule(state["step"], peak_lr, warmup, stable, decay)
        new_params, new_opt = adamw_update(
            state["params"], grads,
            {"m": state["m"], "v": state["v"], "step": state["step"]}, lr)
        new_state = {"params": new_params, **new_opt}
        metrics = dict(metrics, loss=loss, lr=lr)
        return new_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    return decode_step
