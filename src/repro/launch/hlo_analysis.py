"""Post-SPMD HLO analysis: collective-byte accounting for the
roofline's third term.

``compiled.cost_analysis()`` has FLOPs and memory bytes but no
collective traffic, so we parse ``compiled.as_text()`` (the optimized,
partitioned HLO) and sum the tensor sizes of every collective op.

Two subtleties handled here:

1. **Loop bodies execute trip_count times.** Layer-scanned models put
   their per-layer collectives inside a `while` body that appears once
   in the text. We reconstruct the computation graph (entry -> while
   bodies -> nested bodies), recover each loop's trip count from the
   largest integer constant in its condition computation (XLA emits
   `compare(iv, constant(L))`), and multiply.

2. **Conditional branches** (e.g. zamba2's shared-attention block runs
   on 13 of 81 scan iterations) are scaled by an optional
   ``branch_scale`` the caller provides; default 1.0 (upper bound).

Byte accounting per op (per participating device):
  all-reduce         2x result   (ring: reduce-scatter + all-gather)
  all-gather         1x result
  reduce-scatter     1x result
  all-to-all         1x result
  collective-permute 1x result
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_FACTORS = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(.+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
# dot ops: result shape = lhs batch+free x rhs free; flops = 2 * prod
# (result) * prod(contracted lhs dims). Operands are captured as one
# blob and split on top-level commas: live XLA prints inline operand
# types (`f32[32,32]{1,0} %x`) whose brackets/braces contain commas.
_DOT_RE = re.compile(
    r"=\s*(\w+\[[\d,]*\])[^=]*\bdot\(([^)]*)\)"
    r".*?lhs_contracting_dims=\{([\d,]*)\}")
_DOT_LHS_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# top-level ops whose results plausibly materialize in HBM
_MATERIALIZE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(fusion|dot|copy|dynamic-update-slice|dynamic-slice|gather|scatter|"
    r"convolution|transpose|broadcast|reduce|custom-call)\b")
# header: `%name (args...) -> type {` — args may contain nested tuple
# parens, so match only the leading name.
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"conditional\(")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUEFALSE_RE = re.compile(
    r"true_computation=%?([\w.\-]+).*?false_computation=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if stripped == "}":  # computations close on a bare brace
            cur = None
            continue
        comps[cur].append(line)
    return comps


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",") if d] if s else []


def _split_top_level(s: str) -> List[str]:
    """Split an operand list on commas outside []/{}/()."""
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return [p for p in parts if p]


def _dot_flops(line: str, operand_shapes: Dict[str, str]) -> float:
    """FLOPs of one HLO dot line: 2 * |result| * K_contracted."""
    m = _DOT_RE.search(line)
    if not m:
        return 0.0
    result = _shape_elems(m.group(1))
    operands = _split_top_level(m.group(2))
    if not operands:
        return 0.0
    lhs = operands[0]
    lhs_name = lhs.split(" ")[-1]
    # lhs shape: prefer inline type annotation, else operand table
    lm = _DOT_LHS_SHAPE_RE.search(lhs)
    lhs_shape = None
    if lm:
        lhs_shape = _dims(lm.group(2))
    else:
        ref = operand_shapes.get(lhs_name.lstrip("%"))
        if ref:
            lhs_shape = _dims(_SHAPE_RE.search(ref).group(2))
    if lhs_shape is None:
        return 0.0
    k = 1
    for ci in _dims(m.group(3)):
        if ci < len(lhs_shape):
            k *= lhs_shape[ci]
    return 2.0 * result * k


def _shape_elems(shape_str: str) -> float:
    total = 0.0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def hlo_metrics(hlo_text: str, branch_scale: float = 1.0) -> Dict[str, float]:
    """Loop-trip-aware FLOPs / bytes / collective accounting.

    XLA's ``cost_analysis()`` visits while bodies ONCE, so for
    layer-scanned models it under-counts by ~n_layers x. We rebuild
    the numbers from the optimized HLO text:

      flops  — every `dot` op: 2 * |result| * K, weighted by the
               enclosing loop-trip product (matmul-only: the MXU
               roofline term; elementwise VPU flops are ignored).
      bytes  — result sizes of top-level materializing ops (fusion /
               dot / copy / (dynamic-)slice / gather / scatter /
               reduce / transpose / broadcast / custom-call), weighted
               likewise. This mirrors XLA's own bytes-accessed
               heuristic (post-fusion buffer writes), not a cache
               simulation.
      collectives — as collective_bytes().
    """
    comps = _split_computations(hlo_text)
    mult = _multipliers(hlo_text, comps, branch_scale)
    flops = 0.0
    bytes_ = 0.0
    for name, lines in comps.items():
        w = mult.get(name, 0.0)
        if w <= 0:
            continue
        # operand shape table for dot lhs lookups within this comp
    # (cheap single pass: map '%name' -> full line)
        table: Dict[str, str] = {}
        for ln in lines:
            mm = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)", ln)
            if mm:
                table[mm.group(1)] = mm.group(2)
        for ln in lines:
            if " dot(" in ln:
                flops += _dot_flops(ln, table) * w
            mm = _MATERIALIZE_RE.search(ln)
            if mm:
                bytes_ += _shape_bytes(mm.group(1)) * w
    out = collective_bytes(hlo_text, branch_scale)
    out["hlo_flops"] = flops
    out["hlo_bytes"] = bytes_
    return out


def _multipliers(hlo_text: str, comps: Dict[str, List[str]],
                 branch_scale: float) -> Dict[str, float]:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    entry = m.group(1) if m else next(iter(comps), None)
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry in mult:
        mult[entry] = 1.0

    def trip_count(line: str, cond_name: str) -> float:
        # XLA prints the loop analysis on the while op itself
        tm = _TRIP_RE.search(line)
        if tm:
            return float(tm.group(1))
        # fallback: the bound constant in the condition computation
        lines = comps.get(cond_name, [])
        consts = [int(c) for ln in lines for c in _CONST_RE.findall(ln)]
        return float(max(consts)) if consts else 1.0

    for _ in range(32):
        changed = False
        for name, lines in comps.items():
            base = mult.get(name, 0.0)
            if base <= 0:
                continue
            for ln in lines:
                for mm in _WHILE_RE.finditer(ln):
                    cond, body = mm.group(1), mm.group(2)
                    t = base * trip_count(ln, cond)
                    if mult.get(body, 0.0) < t:
                        mult[body] = t
                        changed = True
                for mm in _CALL_RE.finditer(ln):
                    callee = mm.group(1)
                    if mult.get(callee, 0.0) < base:
                        mult[callee] = base
                        changed = True
                if _COND_RE.search(ln):
                    branches: List[str] = []
                    bm = _BRANCH_RE.search(ln)
                    if bm:
                        branches = [b.strip().lstrip("%")
                                    for b in bm.group(1).split(",")]
                    tf = _TRUEFALSE_RE.search(ln)
                    if tf:
                        branches = [tf.group(1), tf.group(2)]
                    for b in branches:
                        v = base * branch_scale
                        if mult.get(b, 0.0) < v:
                            mult[b] = v
                            changed = True
        if not changed:
            break
    return mult


def collective_bytes(hlo_text: str, branch_scale: float = 1.0
                     ) -> Dict[str, float]:
    """Weighted collective bytes per kind, loop-trip aware."""
    comps = _split_computations(hlo_text)
    mult = _multipliers(hlo_text, comps, branch_scale)
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    for name, lines in comps.items():
        w = mult.get(name, 0.0)
        if w <= 0:
            continue
        for ln in lines:
            cm = _COLL_RE.search(ln)
            if not cm or "-done(" in ln:
                continue
            shape_str, kind = cm.group(1), cm.group(2)
            out[kind] += _shape_bytes(shape_str) * _FACTORS[kind] * w
            counts[kind] += 1
    res = {f"{k}_bytes": v for k, v in out.items()}
    res.update({f"{k}_count": float(counts[k]) for k in COLLECTIVES})
    res["total_weighted_bytes"] = sum(out.values())
    return res
