"""Production meshes.

Functions, not module-level constants — importing this module never
touches jax device state. The dry-run forces 512 host devices via
XLA_FLAGS before any jax import (see dryrun.py).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults all
    # mesh axes to Auto anyway, so omitting the kwarg is equivalent
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_local_mesh(model: int = 1):
    """Smoke/test mesh over whatever devices exist (usually 1 CPU)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"), **_axis_type_kwargs(2))
