"""Production meshes.

Functions, not module-level constants — importing this module never
touches jax device state. The dry-run forces 512 host devices via
XLA_FLAGS before any jax import (see dryrun.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_local_mesh(model: int = 1):
    """Smoke/test mesh over whatever devices exist (usually 1 CPU)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
