"""Shared substrate layers: RMSNorm, RoPE, GQA attention (with
optional qk-norm / QKV bias / KV cache), gated & plain MLPs.

Conventions
-----------
* Params are plain dict pytrees; every init fn takes an explicit key.
* Activations: (batch, seq, d_model). Attention uses (B, S, H, hd).
* ``dtype`` is the compute/param dtype (fp32 for CPU smoke tests,
  bf16 for the dry-run target); softmax/norm statistics in fp32.
* KV caches: (B, S_max, n_kv, hd) per layer, stacked over layers by
  the stacks' scan.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


# ----------------------------------------------------------------------
def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"].astype(x.dtype)


# ----------------------------------------------------------------------
def rope_freqs(hd: int, theta: float, dtype=jnp.float32) -> jax.Array:
    return (1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
def _dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def attention_init(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    d, dq, dkv = cfg.d_model, cfg.d_q, cfg.d_kv
    p: Params = {
        "wq": _dense_init(ks[0], d, dq, dtype),
        "wk": _dense_init(ks[1], d, dkv, dtype),
        "wv": _dense_init(ks[2], d, dkv, dtype),
        "wo": _dense_init(ks[3], dq, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((dq,), dtype)
        p["bk"] = jnp.zeros((dkv,), dtype)
        p["bv"] = jnp.zeros((dkv,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.d_head, dtype)
        p["k_norm"] = rmsnorm_init(cfg.d_head, dtype)
    return p


def _project_qkv(p: Params, cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                  chunk: int = 512, context_parallel: bool = False
                  ) -> jax.Array:
    """Flash-style causal attention: scan over KV blocks with online
    softmax. Pure jnp (lowers on every backend — the dry-run's
    stand-in for the Pallas kernel, same blocking): O(S * chunk)
    working set instead of O(S^2) materialized scores + mask.
    q: (B,S,H,hd), k/v: (B,S,Hkv,hd).

    ``context_parallel``: shard the Q sequence over the `model` mesh
    axis (K/V replicated). For archs whose head count doesn't divide
    the TP axis (qwen3's 40 heads on 16 shards), attention is
    otherwise fully replicated per device; CP cuts the per-device
    score traffic by the axis size.
    """
    B, S, H, hd = q.shape
    if context_parallel:
        from jax.sharding import PartitionSpec as P

        q = jax.lax.with_sharding_constraint(
            q, P(None, "model", None, None))
    Hkv = k.shape[2]
    g = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(B, nc, chunk, Hkv, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, chunk, Hkv, hd), 1, 0)
    qg = q.reshape(B, S, Hkv, g, hd)
    q_pos = jnp.arange(S)

    def block(carry, inp):
        m_run, l_run, acc, ci = carry
        k_b, v_b = inp
        s = jnp.einsum("bskgh,btkh->bskgt", qg, k_b).astype(jnp.float32)
        s = s * scale
        k_pos = ci * chunk + jnp.arange(chunk)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bskgt,btkh->bskgh", p.astype(v_b.dtype), v_b).astype(jnp.float32)
        return (m_new, l_new, acc, ci + 1), None

    m0 = jnp.full((B, S, Hkv, g), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, g), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, g, hd), jnp.float32)
    (m_f, l_f, acc, _), _ = jax.lax.scan(
        block, (m0, l0, a0, jnp.zeros((), jnp.int32)), (kc, vc))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.astype(q.dtype).reshape(B, S, H, hd)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array]) -> jax.Array:
    """Reference attention: q (B,S,H,hd), k/v (B,T,Hkv,hd), GQA via
    head-group reshape. fp32 softmax."""
    B, S, H, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    q = q.reshape(B, S, Hkv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H, hd)


def attention_apply(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    causal: bool = True,
    use_flash: bool = False,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full-sequence (train/prefill) or single-step (decode) attention.

    cache: (k_cache, v_cache) each (B, S_max, n_kv, hd). In decode,
    ``x`` is (B, 1, d) and ``cache_index`` the write position.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    new_cache = None
    if cache is not None and cache_index is not None and S == 1:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, cache_index, 0, 0))
        new_cache = (k_cache, v_cache)
        T = k_cache.shape[1]
        valid = jnp.arange(T)[None, None, None, None, :] <= cache_index
        # low-precision KV caches (fp8) are upcast at read; scores/
        # softmax math stays in the compute dtype
        out = _sdpa(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                    valid)
    else:
        if use_flash == "pallas" and causal and S >= 128:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True)
        elif use_flash and causal and S >= 256:
            out = _sdpa_chunked(q, k, v,
                                context_parallel=(use_flash == "cp"))
        else:
            mask = None
            if causal:
                mask = jnp.tril(jnp.ones((S, S), bool))[None, None, None]
            out = _sdpa(q, k, v, mask)
        if cache is not None:
            k_cache, v_cache = cache
            k_cache = jax.lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, 0, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, 0, 0, 0))
            new_cache = (k_cache, v_cache)
    out = out.reshape(B, S, cfg.d_q) @ p["wo"]
    return out, new_cache


# ----------------------------------------------------------------------
def mlp_init(cfg: ModelConfig, key, dtype=jnp.float32,
             d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_gated:
        return {
            "w_gate": _dense_init(ks[0], d, ff, dtype),
            "w_up": _dense_init(ks[1], d, ff, dtype),
            "w_down": _dense_init(ks[2], ff, d, dtype),
        }
    return {
        "w_up": _dense_init(ks[0], d, ff, dtype),
        "w_down": _dense_init(ks[1], ff, d, dtype),
    }


def mlp_apply(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_gated:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]
