"""Non-transformer stacks: xLSTM (ssm family) and Zamba2 (hybrid).

xLSTM: the layer pattern ``([m]*k_m + [s]*k_s) * reps`` is scanned as
``reps`` segments with inner scans over the stacked mLSTM / sLSTM
params — compile size stays O(1) in depth.

Zamba2: one scan over all Mamba2 layers; the SHARED attention+MLP
block (single param set) is applied via ``lax.cond`` after every
``hybrid_attn_every``-th layer, with its per-application KV cache
carried as a stacked buffer and indexed dynamically.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm

Params = Dict[str, Any]


# ======================================================================
# xLSTM
# ======================================================================
def parse_xlstm_pattern(cfg: ModelConfig) -> Tuple[int, int, int]:
    pat = list(cfg.xlstm_pattern)
    assert pat and pat[0] == "m", "pattern must start with mLSTM blocks"
    k_m = pat.index("s") if "s" in pat else len(pat)
    k_s = 0
    for c in pat[k_m:]:
        if c != "s":
            break
        k_s += 1
    seg = ["m"] * k_m + ["s"] * k_s
    reps, rem = divmod(len(pat), len(seg))
    if rem or pat != seg * reps:
        raise ValueError(f"irregular xLSTM pattern {pat}")
    return k_m, k_s, reps


def xlstm_init(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    k_m, k_s, reps = parse_xlstm_pattern(cfg)
    keys = jax.random.split(key, 4)

    def stack(init_fn, k, outer, inner):
        if inner == 0:
            return None
        flat = jax.random.split(k, outer * inner)
        return jax.vmap(jax.vmap(lambda kk: init_fn(cfg, kk, dtype)))(
            flat.reshape(outer, inner, *flat.shape[1:]))

    p: Params = {
        "embed": (jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype),
        "mlstm": stack(ssm.mlstm_init, keys[1], reps, k_m),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if k_s:
        p["slstm"] = stack(ssm.slstm_init, keys[2], reps, k_s)
    if not cfg.tie_embeddings:
        p["lm_head"] = L._dense_init(keys[3], cfg.d_model, cfg.vocab_size,
                                     dtype)
    return p


def xlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    k_m, k_s, reps = parse_xlstm_pattern(cfg)

    def rep_stack(state_fn, inner):
        one = state_fn(cfg, batch, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a, (reps, inner) + a.shape).copy(), one)

    st: Params = {"mlstm": rep_stack(ssm.mlstm_state, k_m)}
    if k_s:
        st["slstm"] = rep_stack(ssm.slstm_state, k_s)
    return st


def xlstm_forward(
    cfg: ModelConfig, p: Params, tokens: jax.Array,
    state: Optional[Params] = None, decode: bool = False,
    remat: bool = False,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    k_m, k_s, reps = parse_xlstm_pattern(cfg)
    x = jnp.take(p["embed"], tokens, axis=0)
    with_state = state is not None

    def m_body(carry, xs):
        if with_state:
            p_l, st_l = xs
            xc, new_st = ssm.mlstm_apply(p_l, cfg, carry, st_l, decode)
            return xc, new_st
        xc, _ = ssm.mlstm_apply(xs, cfg, carry, None, False)
        return xc, None

    def s_body(carry, xs):
        if with_state:
            p_l, st_l = xs
            xc, new_st = ssm.slstm_apply(p_l, cfg, carry, st_l, decode)
            return xc, new_st
        xc, _ = ssm.slstm_apply(xs, cfg, carry, None, False)
        return xc, None

    def seg_body(xc, xs):
        if with_state:
            (pm, sm), ps_st = xs["m"], xs.get("s")
            xc, new_m = jax.lax.scan(m_body, xc, (pm, sm))
            new_s = None
            if k_s:
                ps, ss = ps_st
                xc, new_s = jax.lax.scan(s_body, xc, (ps, ss))
            out = {"m": new_m}
            if k_s:
                out["s"] = new_s
            return xc, out
        xc, _ = jax.lax.scan(m_body, xc, xs["m"])
        if k_s:
            xc, _ = jax.lax.scan(s_body, xc, xs["s"])
        return xc, None

    if with_state:
        xs = {"m": (p["mlstm"], state["mlstm"])}
        if k_s:
            xs["s"] = (p["slstm"], state["slstm"])
    else:
        xs = {"m": p["mlstm"]}
        if k_s:
            xs["s"] = p["slstm"]
    body = jax.checkpoint(seg_body) if remat else seg_body
    x, new_states = jax.lax.scan(body, x, xs)
    new_state = None
    if with_state:
        new_state = {"mlstm": new_states["m"]}
        if k_s:
            new_state["slstm"] = new_states["s"]
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return x @ w.astype(x.dtype), new_state, jnp.zeros((), jnp.float32)


# ======================================================================
# Zamba2 (hybrid)
# ======================================================================
def zamba2_init(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 6)
    mamba = jax.vmap(lambda k: ssm.mamba2_init(cfg, k, dtype))(
        jax.random.split(keys[0], cfg.n_layers))
    p: Params = {
        "embed": (jax.random.normal(
            keys[1], (cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype),
        "mamba": mamba,
        "shared_attn_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "shared_attn": L.attention_init(cfg, keys[2], dtype),
        "shared_mlp_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "shared_mlp": L.mlp_init(cfg, keys[3], dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "lm_head": L._dense_init(keys[4], cfg.d_model, cfg.vocab_size, dtype),
    }
    return p


def n_attn_applications(cfg: ModelConfig) -> int:
    return cfg.n_layers // max(cfg.hybrid_attn_every, 1)


def zamba2_state(cfg: ModelConfig, batch: int, max_seq: int,
                 dtype=jnp.float32) -> Params:
    one = ssm.mamba2_state(cfg, batch, dtype)
    mamba = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)
    n_attn = n_attn_applications(cfg)
    kv = (cfg.n_kv_heads, cfg.d_head)
    return {
        "mamba": mamba,
        "kv_k": jnp.zeros((n_attn, batch, max_seq) + kv, dtype),
        "kv_v": jnp.zeros((n_attn, batch, max_seq) + kv, dtype),
    }


def zamba2_forward(
    cfg: ModelConfig, p: Params, tokens: jax.Array,
    state: Optional[Params] = None, cache_index: Optional[jax.Array] = None,
    decode: bool = False, remat: bool = False, use_flash: bool = False,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    every = cfg.hybrid_attn_every
    x = jnp.take(p["embed"], tokens, axis=0)
    B, S = x.shape[0], x.shape[1]
    if cache_index is not None and decode:
        positions = jnp.full((B, 1), cache_index, jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    with_state = state is not None

    def shared_block(args):
        xc, kv_k, kv_v, a_idx = args
        h = L.rmsnorm(p["shared_attn_norm"], xc, cfg.norm_eps)
        if with_state:
            k_l = jax.lax.dynamic_index_in_dim(kv_k, a_idx, 0, keepdims=False)
            v_l = jax.lax.dynamic_index_in_dim(kv_v, a_idx, 0, keepdims=False)
            attn_out, new_cache = L.attention_apply(
                p["shared_attn"], cfg, h, positions, cache=(k_l, v_l),
                cache_index=cache_index, causal=True, use_flash=use_flash)
            kv_k = jax.lax.dynamic_update_index_in_dim(
                kv_k, new_cache[0], a_idx, 0)
            kv_v = jax.lax.dynamic_update_index_in_dim(
                kv_v, new_cache[1], a_idx, 0)
        else:
            attn_out, _ = L.attention_apply(
                p["shared_attn"], cfg, h, positions, causal=True,
                use_flash=use_flash)
        xc = xc + attn_out
        h = L.rmsnorm(p["shared_mlp_norm"], xc, cfg.norm_eps)
        xc = xc + L.mlp_apply(p["shared_mlp"], cfg, h)
        return xc, kv_k, kv_v, a_idx

    def body(carry, xs):
        xc, kv_k, kv_v = carry
        if with_state:
            p_l, st_l, idx = xs
            xc, new_st = ssm.mamba2_apply(p_l, cfg, xc, st_l, decode)
        else:
            p_l, idx = xs
            xc, new_st = ssm.mamba2_apply(p_l, cfg, xc, None, False)
        do_attn = (idx + 1) % every == 0
        a_idx = (idx + 1) // every - 1
        xc, kv_k, kv_v, _ = jax.lax.cond(
            do_attn, shared_block, lambda a: a, (xc, kv_k, kv_v, a_idx))
        return (xc, kv_k, kv_v), new_st

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    if with_state:
        kv_k, kv_v = state["kv_k"], state["kv_v"]
        xs = (p["mamba"], state["mamba"], idxs)
    else:
        kv_k = jnp.zeros((n_attn_applications(cfg), B, 0, cfg.n_kv_heads,
                          cfg.d_head), x.dtype)
        kv_v = kv_k
        xs = (p["mamba"], idxs)
    body_fn = jax.checkpoint(body) if remat else body
    (x, kv_k, kv_v), new_mamba = jax.lax.scan(body_fn, (x, kv_k, kv_v), xs)
    new_state = None
    if with_state:
        new_state = {"mamba": new_mamba, "kv_k": kv_k, "kv_v": kv_v}
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    return x @ p["lm_head"].astype(x.dtype), new_state, jnp.zeros((), jnp.float32)
