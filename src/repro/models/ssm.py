"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM /
sLSTM).

The SSD chunked algorithm is shared: mLSTM is instantiated as SSD
with per-head B=k, C=q, x=v and sigmoid forget-gate log-decay, with
the mLSTM normalizer obtained by augmenting x with a ones-channel
(the denominator state n·q falls out of the same recurrence).

Prefill/train use the chunked parallel form (scan over chunks,
quadratic within a chunk); decode is the O(1) recurrent step. Both
carry an explicit state pytree so the stacks can scan over layers.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, Any]


# ======================================================================
# SSD core (shared by Mamba2 and mLSTM)
# ======================================================================
def ssd_chunked(
    x: jax.Array,      # (b, s, h, p)   already includes dt/input gate
    a: jax.Array,      # (b, s, h)      per-step log decay (<= 0)
    B: jax.Array,      # (b, s, g, n)   g in {1, h}
    C: jax.Array,      # (b, s, g, n)
    chunk: int,
    h_init: Optional[jax.Array] = None,   # (b, h, n, p)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (b,s,h,p), final_state (b,h,n,p))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    L = min(chunk, s)
    pad = (-s) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // L

    def to_chunks(t):  # (b, sp, ...) -> (nc, b, L, ...)
        return jnp.moveaxis(t.reshape(b, nc, L, *t.shape[2:]), 1, 0)

    xc, ac, Bc, Cc = map(to_chunks, (x, a, B, C))
    a32 = ac.astype(jnp.float32)
    a_cs = jnp.cumsum(a32, axis=2)                    # (nc,b,L,h)
    a_sum = a_cs[:, :, -1:, :]                        # (nc,b,1,h)

    # head-broadcast helper for grouped B/C
    def bc(t):  # (nc,b,L,g,n) -> (nc,b,L,h,n)
        return jnp.broadcast_to(
            t if g == h else jnp.repeat(t, h // g, axis=3),
            t.shape[:3] + (h, n))

    Bh, Ch = bc(Bc), bc(Cc)

    if h_init is None:
        h_init = jnp.zeros((b, h, n, p), jnp.float32)

    def chunk_step(state, inp):
        xcc, acs, asum, Bcc, Ccc = inp
        # inter-chunk: y_l += exp(a_cs[l]) * C_l . S_prev
        decay_in = jnp.exp(acs)                       # (b,L,h)
        y_inter = jnp.einsum("blhn,bhnp->blhp",
                             Ccc.astype(jnp.float32) * decay_in[..., None],
                             state)
        # intra-chunk (causal, decay-weighted). Mask BEFORE exp: the
        # anti-causal deltas are positive and overflow to inf, which
        # would poison gradients through the where (inf * 0 = nan).
        delta = acs[:, :, None, :] - acs[:, None, :, :]          # (b,L,L,h)
        causal = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        dmat = jnp.exp(jnp.where(causal, delta, -jnp.inf))
        CB = jnp.einsum("blhn,bmhn->blmh", Ccc.astype(jnp.float32),
                        Bcc.astype(jnp.float32))
        W = CB * dmat
        y_intra = jnp.einsum("blmh,bmhp->blhp", W, xcc.astype(jnp.float32))
        # chunk-local state + carry update
        decay_out = jnp.exp(asum - acs)               # (b,L,h)
        S_loc = jnp.einsum("blhn,blh,blhp->bhnp",
                           Bcc.astype(jnp.float32), decay_out,
                           xcc.astype(jnp.float32))
        state = jnp.exp(asum[:, 0, :])[..., None, None] * state + S_loc
        return state, y_inter + y_intra

    final, ys = jax.lax.scan(chunk_step, h_init, (xc, a_cs, a_sum, Bh, Ch))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_step(
    x: jax.Array,      # (b, h, p)
    a: jax.Array,      # (b, h) log decay
    B: jax.Array,      # (b, g, n)
    C: jax.Array,      # (b, g, n)
    state: jax.Array,  # (b, h, n, p) fp32
) -> Tuple[jax.Array, jax.Array]:
    b, h, p = x.shape
    g, n = B.shape[1], B.shape[2]
    if g != h:
        B = jnp.repeat(B, h // g, axis=1)
        C = jnp.repeat(C, h // g, axis=1)
    decay = jnp.exp(a.astype(jnp.float32))[..., None, None]
    state = decay * state + jnp.einsum(
        "bhn,bhp->bhnp", B.astype(jnp.float32), x.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", C.astype(jnp.float32), state)
    return y.astype(x.dtype), state


# ======================================================================
# Mamba2 block
# ======================================================================
def mamba2_init(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    """Projections kept SEPARATE (w_z / w_x / w_B / w_C / w_dt) so each
    shards cleanly under TP — a fused in_proj has split points that do
    not align with 16-way shards (see repro/distributed/sharding.py)."""
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = cfg.ssm_heads
    st = cfg.ssm_state
    kconv = cfg.ssm_conv
    ks = jax.random.split(key, 7)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_z": _dense_init(ks[0], d, d_in, dtype),
        "w_x": _dense_init(ks[1], d, d_in, dtype),
        "w_B": _dense_init(ks[2], d, st, dtype),
        "w_C": _dense_init(ks[3], d, st, dtype),
        "w_dt": _dense_init(ks[4], d, nh, dtype),
        "conv_w": (jax.random.normal(ks[5], (kconv, d_in), jnp.float32)
                   * (1.0 / math.sqrt(kconv))).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "conv_w_bc": (jax.random.normal(ks[6], (kconv, 2 * st), jnp.float32)
                      * (1.0 / math.sqrt(kconv))).astype(dtype),
        "conv_b_bc": jnp.zeros((2 * st,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": rmsnorm_init(d_in, dtype),
        "out_proj": _dense_init(ks[0], d_in, d, dtype),
    }


def mamba2_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    d_in = cfg.ssm_expand * cfg.d_model
    return {
        "ssm": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state),
                             dtype),
    }


def _causal_conv(w: jax.Array, bias: jax.Array, xc: jax.Array,
                 conv_state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv, kernel k. xc: (b, s, ch)."""
    k = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((xc.shape[0], k - 1, xc.shape[2]), xc.dtype)
    xx = jnp.concatenate([conv_state.astype(xc.dtype), xc], axis=1)
    new_state = xx[:, -(k - 1):, :]
    out = jnp.zeros_like(xc)
    for i in range(k):
        out = out + xx[:, i:i + xc.shape[1], :] * w[i]
    return jax.nn.silu(out + bias.astype(out.dtype)), new_state


def mamba2_apply(
    p: Params, cfg: ModelConfig, x: jax.Array,
    state: Optional[Params] = None, decode: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    """x: (b, s, d). decode=True requires s == 1 and a state."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    nh, st, hd = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    res = x
    x = rmsnorm(p["norm"], x, cfg.norm_eps)
    z = x @ p["w_z"]
    x_c = x @ p["w_x"]
    bc = jnp.concatenate([x @ p["w_B"], x @ p["w_C"]], axis=-1)
    dt_raw = x @ p["w_dt"]
    conv_state = state["conv"] if state is not None else None
    conv_state_bc = state["conv_bc"] if state is not None else None
    x_c, new_conv = _causal_conv(p["conv_w"], p["conv_b"], x_c, conv_state)
    bc, new_conv_bc = _causal_conv(p["conv_w_bc"], p["conv_b_bc"], bc,
                                   conv_state_bc)
    x_ssm = x_c.reshape(b, s, nh, hd)
    Bmat = bc[..., :st].reshape(b, s, 1, st)
    Cmat = bc[..., st:].reshape(b, s, 1, st)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b,s,nh)
    A = -jnp.exp(p["A_log"])                                          # (nh,)
    a_log = dt * A[None, None, :]
    x_in = x_ssm * dt.astype(x_ssm.dtype)[..., None]

    if decode:
        y, new_ssm = ssd_step(
            x_in[:, 0], a_log[:, 0], Bmat[:, 0], Cmat[:, 0], state["ssm"])
        y = y[:, None]
    else:
        h0 = state["ssm"] if state is not None else None
        y, new_ssm = ssd_chunked(x_in, a_log, Bmat, Cmat, cfg.ssm_chunk, h0)
    y = y + x_ssm * p["D"].astype(x_ssm.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = res + y @ p["out_proj"]
    new_state = None
    if state is not None or decode:
        new_state = {"ssm": new_ssm, "conv": new_conv,
                     "conv_bc": new_conv_bc}
    return out, new_state


# ======================================================================
# xLSTM blocks
# ======================================================================
def mlstm_init(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    """q/k/v and the two up-projections kept separate so each column
    dim TP-shards without split-point misalignment."""
    d = cfg.d_model
    up = int(cfg.xlstm_proj_factor * d)
    ks = jax.random.split(key, 7)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_u": _dense_init(ks[0], d, up, dtype),
        "w_z": _dense_init(ks[1], d, up, dtype),
        "wq": _dense_init(ks[2], up, up, dtype),
        "wk": _dense_init(ks[3], up, up, dtype),
        "wv": _dense_init(ks[4], up, up, dtype),
        "w_if": _dense_init(ks[5], up, 2 * cfg.n_heads, dtype),
        "out_norm": rmsnorm_init(up, dtype),
        "w_down": _dense_init(ks[6], up, d, dtype),
    }


def mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    up = int(cfg.xlstm_proj_factor * cfg.d_model)
    H = cfg.n_heads
    hd = up // H
    return {"C": jnp.zeros((batch, H, hd, hd + 1), jnp.float32)}


def mlstm_apply(
    p: Params, cfg: ModelConfig, x: jax.Array,
    state: Optional[Params] = None, decode: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    b, s, d = x.shape
    up = int(cfg.xlstm_proj_factor * d)
    H = cfg.n_heads
    hd = up // H
    res = x
    x = rmsnorm(p["norm"], x, cfg.norm_eps)
    u = x @ p["w_u"]
    z = x @ p["w_z"]
    q = (u @ p["wq"]).reshape(b, s, H, hd) / math.sqrt(hd)
    k = (u @ p["wk"]).reshape(b, s, H, hd)
    v = (u @ p["wv"]).reshape(b, s, H, hd)
    gates = (u @ p["w_if"]).astype(jnp.float32)
    i_g = jax.nn.sigmoid(gates[..., :H])                    # (b,s,H)
    f_g = jax.nn.sigmoid(gates[..., H:]) * 0.999 + 1e-4
    a_log = jnp.log(f_g)
    # augment v with a ones channel -> numerator & normalizer together
    v_aug = jnp.concatenate(
        [v, jnp.ones((b, s, H, 1), v.dtype)], axis=-1)
    x_in = v_aug * i_g.astype(v.dtype)[..., None]
    if decode:
        y_aug, newC = ssd_step(x_in[:, 0], a_log[:, 0], k[:, 0], q[:, 0],
                               state["C"])
        y_aug = y_aug[:, None]
    else:
        h0 = state["C"] if state is not None else None
        y_aug, newC = ssd_chunked(x_in, a_log, k, q,
                                  min(cfg.ssm_chunk or 128, 128), h0)
    num, den = y_aug[..., :hd], y_aug[..., hd:]
    y = num / jnp.maximum(jnp.abs(den), 1.0).astype(num.dtype)
    y = y.reshape(b, s, up)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = res + y @ p["w_down"]
    new_state = {"C": newC} if (state is not None or decode) else None
    return out, new_state


def slstm_init(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    up = int(cfg.xlstm_proj_factor * d)
    H = cfg.n_heads
    hd = up // H
    ks = jax.random.split(key, 4)
    return {
        "norm": rmsnorm_init(d, dtype),
        "w_up": _dense_init(ks[0], d, up, dtype),
        "w_gates": _dense_init(ks[1], up, 4 * up, dtype),
        "r_gates": (jax.random.normal(ks[2], (H, hd, 4 * hd), jnp.float32)
                    * (1.0 / math.sqrt(hd))).astype(dtype),
        "out_norm": rmsnorm_init(up, dtype),
        "w_down": _dense_init(ks[3], up, d, dtype),
    }


def slstm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    up = int(cfg.xlstm_proj_factor * cfg.d_model)
    return {
        "c": jnp.zeros((batch, up), jnp.float32),
        "n": jnp.ones((batch, up), jnp.float32),
        "h": jnp.zeros((batch, up), jnp.float32),
    }


def _slstm_cell(p: Params, cfg: ModelConfig, xg: jax.Array, st: Params):
    """xg: (b, 4*up) pre-activation from the input path."""
    H = cfg.n_heads
    b = xg.shape[0]
    up = xg.shape[1] // 4
    hd = up // H
    h_prev = st["h"].reshape(b, H, hd).astype(p["r_gates"].dtype)
    rec = jnp.einsum("bhd,hdk->bhk", h_prev, p["r_gates"]).reshape(b, 4 * up)
    zifo = (xg + rec).astype(jnp.float32)
    z_t, i_t, f_t, o_t = jnp.split(zifo, 4, axis=-1)
    z_t = jnp.tanh(z_t)
    i_t = jax.nn.sigmoid(i_t)
    f_t = jax.nn.sigmoid(f_t)
    o_t = jax.nn.sigmoid(o_t)
    c = f_t * st["c"] + i_t * z_t
    n = f_t * st["n"] + i_t
    h = o_t * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h}


def slstm_apply(
    p: Params, cfg: ModelConfig, x: jax.Array,
    state: Optional[Params] = None, decode: bool = False,
) -> Tuple[jax.Array, Optional[Params]]:
    b, s, d = x.shape
    up = int(cfg.xlstm_proj_factor * d)
    res = x
    x = rmsnorm(p["norm"], x, cfg.norm_eps)
    u = x @ p["w_up"]
    xg = u @ p["w_gates"]                                   # (b, s, 4*up)
    st = state if state is not None else slstm_state(cfg, b)
    if decode:
        st = _slstm_cell(p, cfg, xg[:, 0], st)
        y = st["h"][:, None].astype(x.dtype)
        new_state = st
    else:
        def step(carry, xg_t):
            carry = _slstm_cell(p, cfg, xg_t, carry)
            return carry, carry["h"]

        new_state, hs = jax.lax.scan(step, st, jnp.moveaxis(xg, 1, 0))
        y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
        if state is None:
            new_state = None
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps)
    out = res + y @ p["w_down"]
    return out, new_state
