"""Decoder-only transformer stack (families: dense, moe, vlm, audio).

Scan-over-layers with stacked params; optional remat for training.
Supports three entry points used by the launch layer:

* ``train_logits``: full-sequence forward (causal), returns logits.
* ``prefill``: forward + returns a filled KV cache.
* ``decode_step``: one token (B, 1) against the KV cache.

VLM: precomputed patch embeddings (frontend stub) are prepended to
the text embeddings. Audio: K codebook streams are embedded and
summed per frame; the head emits K logit sets.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.moe import moe_apply, moe_init

Params = Dict[str, Any]


# ----------------------------------------------------------------------
def _layer_init(cfg: ModelConfig, key, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {
        "attn_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attention_init(cfg, k1, dtype),
        "mlp_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe_init(cfg, k2, dtype)
    else:
        p["mlp"] = L.mlp_init(cfg, k2, dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 3)
    layer_params = jax.vmap(lambda k: _layer_init(cfg, k, dtype))(
        jnp.stack(keys[: cfg.n_layers]))
    n_streams = max(cfg.n_codebooks, 1)
    V = cfg.vocab_padded
    if cfg.family == "audio":
        embed = (jax.random.normal(
            keys[-1], (n_streams, V, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype)
    else:
        embed = (jax.random.normal(
            keys[-1], (V, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype)
    p: Params = {
        "embed": embed,
        "layers": layer_params,
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        if cfg.family == "audio":
            p["lm_head"] = (jax.random.normal(
                keys[-2], (cfg.d_model, n_streams * V),
                jnp.float32) * 0.02).astype(dtype)
        else:
            p["lm_head"] = L._dense_init(keys[-2], cfg.d_model, V, dtype)
    return p


# ----------------------------------------------------------------------
def _embed_tokens(p: Params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    if cfg.family == "audio":
        # tokens: (B, K, S) -> sum of per-codebook embeddings
        parts = [jnp.take(p["embed"][k], tokens[:, k], axis=0)
                 for k in range(cfg.n_codebooks)]
        return sum(parts)
    return jnp.take(p["embed"], tokens, axis=0)


def _unembed(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["embed"].T
    else:
        w = p["lm_head"]
    logits = x @ w.astype(x.dtype)
    if cfg.family == "audio":
        B, S = x.shape[0], x.shape[1]
        logits = logits.reshape(B, S, cfg.n_codebooks, cfg.vocab_padded)
    if cfg.vocab_padded != cfg.vocab_size:
        # padded slots never win softmax/sampling
        ids = jnp.arange(cfg.vocab_padded)
        logits = jnp.where(ids < cfg.vocab_size, logits, -1e30)
    return logits


def _layer_apply(
    cfg: ModelConfig, p_l: Params, x: jax.Array, positions: jax.Array,
    cache_l: Optional[Tuple[jax.Array, jax.Array]],
    cache_index: Optional[jax.Array],
    use_flash: bool,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]], jax.Array]:
    h = L.rmsnorm(p_l["attn_norm"], x, cfg.norm_eps)
    attn_out, new_cache = L.attention_apply(
        p_l["attn"], cfg, h, positions, cache=cache_l,
        cache_index=cache_index, causal=True, use_flash=use_flash)
    x = x + attn_out
    h = L.rmsnorm(p_l["mlp_norm"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        out, aux = moe_apply(p_l["moe"], cfg, h)
    else:
        out = L.mlp_apply(p_l["mlp"], cfg, h)
    return x + out, new_cache, aux


# ----------------------------------------------------------------------
def forward(
    cfg: ModelConfig,
    p: Params,
    tokens: jax.Array,
    cache: Optional[Params] = None,
    cache_index: Optional[jax.Array] = None,
    patch_embeds: Optional[jax.Array] = None,
    remat: bool = False,
    use_flash: bool = False,
    last_only: bool = False,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Shared trunk. Returns (logits, new_cache, aux_loss)."""
    x = _embed_tokens(p, cfg, tokens)
    if cfg.family == "vlm" and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[0], x.shape[1]
    if cache_index is not None:
        positions = jnp.full((B, 1), cache_index, jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)

    def body_nocache(carry, p_l):
        xc, aux = carry
        xc, _, aux_l = _layer_apply(
            cfg, p_l, xc, positions, None, None, use_flash)
        return (xc, aux + aux_l), None

    def body_cache(carry, xs):
        xc, aux = carry
        p_l, cache_l = xs
        xc, new_cache_l, aux_l = _layer_apply(
            cfg, p_l, xc, positions, cache_l, cache_index, use_flash)
        return (xc, aux + aux_l), new_cache_l

    if cache is None:
        body_fn = jax.checkpoint(body_nocache) if remat else body_nocache
        (x, aux), _ = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)), p["layers"])
        new_cache = None
    else:
        body_fn = jax.checkpoint(body_cache) if remat else body_cache
        (x, aux), new_cache = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)),
            (p["layers"], cache))
    if last_only:
        # serving prefill wants next-token logits only: slicing BEFORE
        # the unembed avoids materializing (B, S, V) logits.
        x = x[:, -1:]
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = _unembed(p, cfg, x)
    return logits, new_cache, aux


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
