"""Unified Model API over all families — what the launch / train /
serve layers program against.

  model = build_model(cfg)
  params = model.init(key, dtype)
  loss, metrics = model.loss(params, batch)              # training
  logits, cache = model.prefill(params, batch)           # serving
  logits, cache = model.decode_step(params, cache, batch)

Batches are dicts:
  train:   {"tokens": (B,S) | (B,K,S), "targets": same, [patch_embeds]}
  prefill: {"tokens": ...}
  decode:  {"tokens": (B,1)|(B,K,1), "cache_index": ()} + cache pytree
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import stacks, transformer

Params = Dict[str, Any]
Batch = Dict[str, jax.Array]


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy; logits promoted to fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


@dataclass
class Model:
    cfg: ModelConfig
    use_flash: Any = False      # False | True (chunked jnp) | "pallas"
    remat: bool = True
    prefill_last_only: bool = False

    # ---------------- init ----------------
    def init(self, key, dtype=jnp.float32) -> Params:
        c = self.cfg
        if c.family in ("dense", "moe", "vlm", "audio"):
            return transformer.init_params(c, key, dtype)
        if c.family == "ssm" and c.xlstm_pattern:
            return stacks.xlstm_init(c, key, dtype)
        if c.family in ("ssm", "hybrid"):
            return stacks.zamba2_init(c, key, dtype)
        raise ValueError(c.family)

    # ---------------- training ----------------
    def logits(self, params: Params, batch: Batch) -> Tuple[jax.Array, jax.Array]:
        c = self.cfg
        tokens = batch["tokens"]
        if c.family in ("dense", "moe", "vlm", "audio"):
            lg, _, aux = transformer.forward(
                c, params, tokens, patch_embeds=batch.get("patch_embeds"),
                remat=self.remat, use_flash=self.use_flash)
            return lg, aux
        if c.family == "ssm" and c.xlstm_pattern:
            lg, _, aux = stacks.xlstm_forward(c, params, tokens,
                                              remat=self.remat)
            return lg, aux
        lg, _, aux = stacks.zamba2_forward(c, params, tokens,
                                           remat=self.remat,
                                           use_flash=self.use_flash)
        return lg, aux

    def loss(self, params: Params, batch: Batch) -> Tuple[jax.Array, Dict]:
        c = self.cfg
        lg, aux = self.logits(params, batch)
        targets = batch["targets"]
        if c.family == "vlm":
            # patches are prepended: score only the text positions
            n_p = c.n_patches
            lg = lg[:, n_p:]
        if c.family == "audio":
            # lg: (B,S,K,V); targets (B,K,S)
            tgt = jnp.moveaxis(targets, 1, 2)
            ce = cross_entropy(lg, tgt)
        else:
            ce = cross_entropy(lg, targets)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # ---------------- serving ----------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.float32):
        c = self.cfg
        if c.family in ("dense", "moe", "vlm", "audio"):
            return transformer.init_cache(c, batch, max_seq, dtype)
        if c.family == "ssm" and c.xlstm_pattern:
            return stacks.xlstm_state(c, batch, dtype)
        return stacks.zamba2_state(c, batch, max_seq, dtype)

    def prefill(self, params: Params, batch: Batch, cache) -> Tuple[jax.Array, Any]:
        """Full-sequence forward that fills the cache/state."""
        c = self.cfg
        tokens = batch["tokens"]
        if c.family in ("dense", "moe", "vlm", "audio"):
            lg, cache, _ = transformer.forward(
                c, params, tokens, cache=cache,
                patch_embeds=batch.get("patch_embeds"),
                use_flash=self.use_flash,
                last_only=self.prefill_last_only)
            return lg, cache
        if c.family == "ssm" and c.xlstm_pattern:
            lg, st, _ = stacks.xlstm_forward(c, params, tokens, state=cache)
            return lg, st
        lg, st, _ = stacks.zamba2_forward(c, params, tokens, state=cache,
                                          use_flash=self.use_flash)
        return lg, st

    def decode_step(self, params: Params, cache, batch: Batch
                    ) -> Tuple[jax.Array, Any]:
        """One new token against the cache. tokens: (B,1) / (B,K,1)."""
        c = self.cfg
        tokens = batch["tokens"]
        idx = batch["cache_index"]
        if c.family in ("dense", "moe", "vlm", "audio"):
            lg, cache, _ = transformer.forward(
                c, params, tokens, cache=cache, cache_index=idx)
            return lg, cache
        if c.family == "ssm" and c.xlstm_pattern:
            lg, st, _ = stacks.xlstm_forward(c, params, tokens, state=cache,
                                             decode=True)
            return lg, st
        lg, st, _ = stacks.zamba2_forward(c, params, tokens, state=cache,
                                          cache_index=idx, decode=True)
        return lg, st

    # ---------------- shape builders (dry-run / data pipeline) -------
    def batch_shapes(self, kind: str, batch: int, seq: int
                     ) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
        """{name: (shape, dtype)} for every model input of a step."""
        c = self.cfg
        tok = jnp.int32
        if c.family == "audio":
            tok_shape = (batch, c.n_codebooks, seq)
        else:
            tok_shape = (batch, seq)
        if kind == "train":
            d: Dict[str, Tuple[Tuple[int, ...], Any]] = {
                "tokens": (tok_shape, tok),
                "targets": (tok_shape, tok),
            }
            if c.family == "vlm":
                # patches occupy the first n_patches positions of seq
                text = seq - c.n_patches
                d["tokens"] = ((batch, text), tok)
                d["targets"] = ((batch, text), tok)
                d["patch_embeds"] = ((batch, c.n_patches, c.d_model),
                                     jnp.bfloat16)
            return d
        if kind == "prefill":
            d = {"tokens": (tok_shape, tok)}
            if c.family == "vlm":
                text = seq - c.n_patches
                d["tokens"] = ((batch, text), tok)
                d["patch_embeds"] = ((batch, c.n_patches, c.d_model),
                                     jnp.bfloat16)
            return d
        if kind == "decode":
            one = ((batch, c.n_codebooks, 1) if c.family == "audio"
                   else (batch, 1))
            return {"tokens": (one, tok), "cache_index": ((), jnp.int32)}
        raise ValueError(kind)


def build_model(cfg: ModelConfig, use_flash: Any = False,
                remat: bool = True,
                prefill_last_only: bool = False) -> Model:
    return Model(cfg=cfg, use_flash=use_flash, remat=remat,
                 prefill_last_only=prefill_last_only)
