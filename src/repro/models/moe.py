"""Mixture-of-Experts layer: top-k routing with GShard-style capacity
dispatch lowered to ONE batched expert GEMM.

Dispatch = scatter tokens into (E, C, d) slot buffers (dropped tokens
fall through on the residual); experts run as a single
``einsum('ecd,edf->ecf')`` so the compiler sees a dense grouped GEMM —
shardable either on the expert axis (EP: dbrx, 16 experts / 16-way
`model`) or on the ffn axis (TP: qwen2-moe, 60 experts). Combine =
gather + gate-weighted sum. Fully differentiable (gate grads flow
through the top-k values; index grads are zero as usual).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, mlp_apply, mlp_init

Params = Dict[str, Any]


def moe_init(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    d_e = cfg.d_expert or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)

    def experts(k, d_in, d_out):
        return (jax.random.normal(k, (E, d_in, d_out), jnp.float32)
                * (1.0 / math.sqrt(d_in))).astype(dtype)

    p: Params = {
        "router": _dense_init(ks[0], d, E, dtype),
        "w_gate": experts(ks[1], d, d_e),
        "w_up": experts(ks[2], d, d_e),
        "w_down": experts(ks[3], d_e, d),
    }
    if cfg.n_shared_experts:
        shared_cfg = cfg.replace(mlp_gated=True)
        p["shared"] = mlp_init(shared_cfg, ks[4], dtype,
                               d_ff=cfg.n_shared_experts * d_e)
    return p


def capacity(cfg: ModelConfig, n_tokens: int, factor: float = 1.25) -> int:
    if n_tokens <= 128:
        # dropless for tiny groups (decode steps, smoke tests): the
        # worst-case buffer is E x (n_tokens*k) x d — negligible — and
        # decode/prefill logits stay bit-consistent (no token drops).
        c = n_tokens * cfg.n_experts_per_tok
        return max(8, -(-c // 8) * 8)
    c = math.ceil(n_tokens * cfg.n_experts_per_tok / cfg.n_experts * factor)
    return max(8, -(-c // 8) * 8)  # pad to 8 for tiling friendliness


def _n_groups(B: int, cap: int = 64) -> int:
    """Largest power of two <= cap that divides the batch — groups
    align with (and subdivide) the data-parallel batch shards."""
    g = 1
    while g * 2 <= min(cap, B) and B % (g * 2) == 0:
        g *= 2
    return g


def moe_apply(p: Params, cfg: ModelConfig, x: jax.Array,
              capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss). x: (B, S, d).

    GShard-style GROUPED dispatch: tokens are ranked against a
    per-group capacity, so the rank prefix-sum runs along the token
    axis WITHIN a group while the group axis stays batch-sharded — no
    cross-shard prefix scans or global scatters (the baseline
    one-hot-cumsum over all B*S*k assignments made qwen2-moe train_4k
    the most collective-bound dry-run cell; see EXPERIMENTS.md §Perf).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    G = _n_groups(B)
    T = B * S
    Tg = T // G
    C = capacity(cfg, Tg, capacity_factor)
    xg = x.reshape(G, Tg, d)

    logits = (xg @ p["router"]).astype(jnp.float32)         # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style). Scatter-add, not
    # one-hot: a (G,Tg,E) one-hot here is O(T*E) bytes (250GB at
    # train_4k scale).
    density = jnp.zeros((E,), jnp.float32).at[
        expert_idx[..., 0].reshape(-1)].add(1.0) / (G * Tg)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux_loss = E * jnp.sum(density * mean_prob)

    # --- dispatch: rank of each assignment within (group, expert) ---
    # Sort-based ranking. The GShard one-hot-cumsum materializes a
    # (G, A, E) int tensor (~1 TB at train_4k) and drags TBs of
    # all-reduce through the backward pass — the dominant collective
    # term of the baseline dry-run. Two argsorts + a searchsorted on
    # (G, A) int32 (~16 MB) computes the same ranks.
    A = Tg * k
    flat_e = expert_idx.reshape(G, A)                        # (G, A)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    pos = jnp.arange(A, dtype=jnp.int32)[None, :]
    first = jax.vmap(
        lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    ranks_sorted = pos - first
    inv = jnp.argsort(order, axis=1)
    ranks = jnp.take_along_axis(ranks_sorted, inv, axis=1)
    ranks = jax.lax.stop_gradient(ranks)
    keep = ranks < C
    slot = jnp.where(keep, flat_e * C + ranks, E * C)        # E*C = drop

    x_rep = jnp.repeat(xg, k, axis=1)                        # (G, A, d)
    buf = jnp.zeros((G, E * C, d), x.dtype)
    buf = jax.vmap(
        lambda b, s, v: b.at[s].add(v, mode="drop"))(
            buf, slot, jnp.where(keep[..., None], x_rep, 0))
    # GSPMD cannot propagate batch sharding through the scatter — left
    # alone it replicates the dispatch buffers across `data` and
    # all-reduces the expert outputs (TBs/step at train_4k). Pin the
    # group dim to the DP axes explicitly.
    from repro.distributed.sharding import maybe_constrain

    buf = maybe_constrain(buf, ("pod", "data"), None, None)
    h = buf.reshape(G, E, C, d)

    # --- grouped expert GEMMs (one batched einsum each) ---
    g_ = jax.nn.silu(jnp.einsum("gecd,edf->gecf", h, p["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", h, p["w_up"])
    y_e = jnp.einsum("gecf,efd->gecd", g_ * u, p["w_down"])
    y_e = maybe_constrain(y_e, ("pod", "data"), None, None, None)

    # --- combine: gather + gate-weighted sum over the k slots ---
    y_flat = y_e.reshape(G, E * C, d)
    y_tok = jnp.take_along_axis(
        y_flat, jnp.minimum(slot, E * C - 1)[..., None], axis=1)
    y_tok = jnp.where(keep[..., None], y_tok, 0)
    y_tok = y_tok.reshape(G, Tg, k, d)
    gates = gate_vals.astype(x.dtype)[..., None]             # (G, Tg, k, 1)
    y = jnp.sum(y_tok * gates, axis=2)

    y = y.reshape(B, S, d)
    if "shared" in p:
        shared_cfg = cfg.replace(mlp_gated=True)
        y = y + mlp_apply(p["shared"], shared_cfg, x)
    return y, aux_loss
