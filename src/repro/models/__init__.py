"""Model zoo substrate: shared layers + per-family stacks.

All stacks scan over layers (``jax.lax.scan`` with stacked per-layer
params) so compile time and HLO size are O(1) in depth — required for
the 80-program dry-run matrix on this 1-core container, and standard
production practice (MaxText-style).
"""
from repro.models.registry import build_model, Model

__all__ = ["build_model", "Model"]
