"""vNPU -> pNPU mapping (§III-C).

The vNPU manager tracks every physical NPU's free MEs/VEs and
SRAM/HBM segments, and places vNPUs under two schemes:

* ``spatial``  (hardware-isolated): dedicated EUs + segments. A set of
  vNPUs is collocatable iff total EU/memory demand fits the pNPU.
* ``temporal`` (software-isolated): EUs oversubscribed; placement
  load-balances total demand across pNPUs.

The default placement is the paper's greedy rule: balance the
*fraction* of EUs vs memory consumed on each core, so EU-hungry
vNPUs land next to memory-hungry ones.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.vnpu import (KVLedger, KVLedgerError, MemorySegments, VNPU,
                             VNPUConfig, VNPUState)
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig


class ReconfigureError(RuntimeError):
    """A vNPU reconfigure could not be placed; the original mapping
    was restored and is available as ``.restored``."""

    def __init__(self, msg: str, restored: VNPU):
        super().__init__(msg)
        self.restored = restored


@dataclass
class CoreState:
    """Bookkeeping for one physical NPU core."""

    core: NPUCoreConfig
    pnpu_id: int
    core_id: int
    free_mes: List[int] = field(default_factory=list)
    free_ves: List[int] = field(default_factory=list)
    free_sram_segs: List[int] = field(default_factory=list)
    free_hbm_segs: List[int] = field(default_factory=list)
    residents: List[int] = field(default_factory=list)  # vnpu ids
    # temporal mode: cumulative oversubscribed demand
    demand_me: int = 0
    demand_ve: int = 0

    def __post_init__(self):
        c = self.core
        self.free_mes = list(range(c.n_me))
        self.free_ves = list(range(c.n_ve))
        self.free_sram_segs = list(range(c.sram_bytes // c.sram_segment))
        self.free_hbm_segs = list(range(c.hbm_bytes // c.hbm_segment))

    # -- utilization fractions for the greedy balance rule --
    @property
    def eu_used_frac(self) -> float:
        c = self.core
        used = (c.n_me - len(self.free_mes)) + (c.n_ve - len(self.free_ves))
        return used / (c.n_me + c.n_ve)

    @property
    def mem_used_frac(self) -> float:
        c = self.core
        total = c.hbm_bytes // c.hbm_segment
        return (total - len(self.free_hbm_segs)) / max(total, 1)

    def fits_spatial(self, cfg: VNPUConfig) -> bool:
        c = self.core
        n_sram = -(-max(cfg.sram_bytes, c.sram_segment) // c.sram_segment)
        n_hbm = -(-max(cfg.hbm_bytes, c.hbm_segment) // c.hbm_segment)
        return (
            len(self.free_mes) >= cfg.n_me
            and len(self.free_ves) >= cfg.n_ve
            and len(self.free_sram_segs) >= n_sram
            and len(self.free_hbm_segs) >= n_hbm
        )


class VNPUManager:
    """Host-side vNPU manager (the paper's kernel-module control
    plane, minus the KVM plumbing — see DESIGN.md §3)."""

    def __init__(self, n_pnpus: int = 1, cores_per_pnpu: int = 1,
                 core: NPUCoreConfig = DEFAULT_CORE):
        self.core_cfg = core
        self.cores: List[CoreState] = [
            CoreState(core, p, c)
            for p in range(n_pnpus)
            for c in range(cores_per_pnpu)
        ]
        self.vnpus: Dict[int, VNPU] = {}

    # ------------------------------------------------------------------
    def create(self, cfg: VNPUConfig, name: str = "",
               mapping: str = "spatial",
               core_hint: Optional[int] = None) -> VNPU:
        """``core_hint`` pins placement to one core index (the fabric
        control plane's topology-aware choice; a live per-core
        simulation also pins resizes so a tenant cannot silently hop
        cores). ``None`` keeps the unrestricted greedy rule."""
        cfg.validate(self.core_cfg)
        v = VNPU(config=cfg, name=name, mapping=mapping)
        self.vnpus[v.vnpu_id] = v
        self._map(v, core_hint=core_hint)
        return v

    def destroy(self, v: VNPU) -> None:
        """vNPU deallocation: clean the context, release EUs+segments,
        tear down the (modeled) DMA mappings."""
        if v.state == VNPUState.DESTROYED:
            return
        cs = self._core_of(v)
        if cs is not None:
            if v.mapping == "spatial":
                cs.free_mes.extend(v.me_ids)
                cs.free_ves.extend(v.ve_ids)
                cs.free_mes.sort()
                cs.free_ves.sort()
            else:
                cs.demand_me -= v.config.n_me
                cs.demand_ve -= v.config.n_ve
            if v.segments is not None:
                cs.free_sram_segs.extend(
                    s for s in v.segments.sram_segments)
                cs.free_hbm_segs.extend(
                    s for s in v.segments.hbm_segments)
                cs.free_sram_segs.sort()
                cs.free_hbm_segs.sort()
            cs.residents.remove(v.vnpu_id)
        v.destroy()

    def reconfigure(self, v: VNPU, cfg: VNPUConfig,
                    core_hint: Optional[int] = None) -> VNPU:
        """Paper hypercall (2): change an existing vNPU's config.

        All-or-nothing: if the new config cannot be placed, the old
        mapping is restored and :class:`ReconfigureError` is raised
        carrying the restored vNPU (live control planes must keep a
        valid handle — a failed grow must not kill the tenant).

        The vNPU's KV ledger (live per-request HBM occupancy) is
        carried to the new mapping. A resize whose HBM allocation
        cannot hold the live occupancy is REJECTED the same
        all-or-nothing way: callers must evict/swap the excess first —
        shrinking segments out from under resident KV would corrupt
        tenant state."""
        mapping = v.mapping
        old_cfg = v.config
        old_ledger = v.kv_ledger
        self.destroy(v)

        def _restore() -> VNPU:
            restored = self.create(old_cfg, name=v.name, mapping=mapping,
                                   core_hint=core_hint)
            if old_ledger is not None:
                restored.kv_ledger.migrate_from(old_ledger)
            return restored

        try:
            nv = self.create(cfg, name=v.name, mapping=mapping,
                             core_hint=core_hint)
        except RuntimeError as exc:
            raise ReconfigureError(
                f"reconfigure of vNPU {v.name!r} to "
                f"{cfg.n_me}ME/{cfg.n_ve}VE failed ({exc}); "
                f"previous mapping restored", _restore()) from exc
        if old_ledger is not None:
            try:
                nv.kv_ledger.migrate_from(old_ledger)
            except KVLedgerError as exc:
                self.destroy(nv)
                raise ReconfigureError(
                    f"reconfigure of vNPU {v.name!r} rejected: {exc}; "
                    f"previous mapping restored", _restore()) from exc
        return nv

    # ------------------------------------------------------------------
    def _core_of(self, v: VNPU) -> Optional[CoreState]:
        for cs in self.cores:
            if v.vnpu_id in cs.residents:
                return cs
        return None

    def core_index_of(self, v: VNPU) -> int:
        """Fabric-facing: index (into ``self.cores``) of the core a
        vNPU is mapped on. Raises for an unmapped/destroyed vNPU."""
        for i, cs in enumerate(self.cores):
            if v.vnpu_id in cs.residents:
                return i
        raise ValueError(f"vNPU {v.name!r} is not mapped on any core")

    def _alloc_segments(self, cs: CoreState, cfg: VNPUConfig) -> MemorySegments:
        c = cs.core
        n_sram = -(-max(cfg.sram_bytes, c.sram_segment) // c.sram_segment)
        n_hbm = -(-max(cfg.hbm_bytes, c.hbm_segment) // c.hbm_segment)
        if len(cs.free_sram_segs) < n_sram or len(cs.free_hbm_segs) < n_hbm:
            raise RuntimeError("out of memory segments")
        sram = tuple(cs.free_sram_segs[:n_sram])
        hbm = tuple(cs.free_hbm_segs[:n_hbm])
        del cs.free_sram_segs[:n_sram]
        del cs.free_hbm_segs[:n_hbm]
        return MemorySegments(sram, hbm, c.sram_segment, c.hbm_segment)

    def _map(self, v: VNPU, core_hint: Optional[int] = None) -> None:
        cfg = v.config
        pool = self.cores
        if core_hint is not None:
            if not 0 <= core_hint < len(self.cores):
                raise ValueError(
                    f"core_hint {core_hint} out of range for "
                    f"{len(self.cores)} cores")
            pool = [self.cores[core_hint]]
        if v.mapping == "spatial":
            # greedy §III-C: among cores that fit, pick the one where
            # adding this vNPU best balances EU-frac vs mem-frac.
            def imbalance(cs: CoreState) -> float:
                c = cs.core
                eu = cs.eu_used_frac + cfg.n_eus / (c.n_me + c.n_ve)
                total_hbm = c.hbm_bytes // c.hbm_segment
                mem = cs.mem_used_frac + (
                    -(-max(cfg.hbm_bytes, c.hbm_segment) // c.hbm_segment)
                    / max(total_hbm, 1)
                )
                return abs(eu - mem)

            candidates = [cs for cs in pool if cs.fits_spatial(cfg)]
            if not candidates:
                raise RuntimeError(
                    f"no pNPU core fits vNPU {cfg.n_me}ME/{cfg.n_ve}VE "
                    f"(spatial); free up resources or use temporal mapping"
                )
            cs = min(candidates, key=imbalance)
            v.me_ids = tuple(cs.free_mes[: cfg.n_me])
            v.ve_ids = tuple(cs.free_ves[: cfg.n_ve])
            del cs.free_mes[: cfg.n_me]
            del cs.free_ves[: cfg.n_ve]
        else:
            # temporal: least-loaded core by oversubscribed demand
            cs = min(pool, key=lambda c: c.demand_me + c.demand_ve)
            cs.demand_me += cfg.n_me
            cs.demand_ve += cfg.n_ve
            v.me_ids = tuple(range(cfg.n_me))   # logical ids
            v.ve_ids = tuple(range(cfg.n_ve))
        v.segments = self._alloc_segments(cs, cfg)
        # live HBM accounting over exactly the segments this vNPU owns
        v.kv_ledger = KVLedger(v.segments.hbm_bytes, cs.core.hbm_segment)
        cs.residents.append(v.vnpu_id)
        v.pnpu_id, v.core_id = cs.pnpu_id, cs.core_id
        v.state = VNPUState.MAPPED

    # ------------------------------------------------------------------
    def collocated(self, v: VNPU) -> List[VNPU]:
        cs = self._core_of(v)
        if cs is None:
            return []
        return [self.vnpus[i] for i in cs.residents if i != v.vnpu_id]
