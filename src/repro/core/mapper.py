"""vNPU -> pNPU mapping (§III-C).

The vNPU manager tracks every physical NPU's free MEs/VEs and
SRAM/HBM segments, and places vNPUs under two schemes:

* ``spatial``  (hardware-isolated): dedicated EUs + segments. A set of
  vNPUs is collocatable iff total EU/memory demand fits the pNPU.
* ``temporal`` (software-isolated): EUs oversubscribed; placement
  load-balances total demand across pNPUs.

The default placement is the paper's greedy rule: balance the
*fraction* of EUs vs memory consumed on each core, so EU-hungry
vNPUs land next to memory-hungry ones.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.vnpu import (KVLedger, KVLedgerError, MemorySegments, VNPU,
                             VNPUConfig, VNPUState)
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig


class ReconfigureError(RuntimeError):
    """A vNPU reconfigure could not be placed; the original mapping
    was restored and is available as ``.restored``."""

    def __init__(self, msg: str, restored: VNPU):
        super().__init__(msg)
        self.restored = restored


@dataclass
class CoreState:
    """Bookkeeping for one physical NPU core."""

    core: NPUCoreConfig
    pnpu_id: int
    core_id: int
    free_mes: List[int] = field(default_factory=list)
    free_ves: List[int] = field(default_factory=list)
    free_sram_segs: List[int] = field(default_factory=list)
    free_hbm_segs: List[int] = field(default_factory=list)
    residents: List[int] = field(default_factory=list)  # vnpu ids
    # temporal mode: cumulative oversubscribed demand
    demand_me: int = 0
    demand_ve: int = 0
    # fault state: a failed core accepts no placements until restored;
    # faulted HBM segments are permanently out of every free list
    failed: bool = False
    faulted_hbm_segs: List[int] = field(default_factory=list)

    def __post_init__(self):
        c = self.core
        self.free_mes = list(range(c.n_me))
        self.free_ves = list(range(c.n_ve))
        self.free_sram_segs = list(range(c.sram_bytes // c.sram_segment))
        self.free_hbm_segs = list(range(c.hbm_bytes // c.hbm_segment))

    # -- utilization fractions for the greedy balance rule --
    @property
    def eu_used_frac(self) -> float:
        c = self.core
        used = (c.n_me - len(self.free_mes)) + (c.n_ve - len(self.free_ves))
        return used / (c.n_me + c.n_ve)

    @property
    def mem_used_frac(self) -> float:
        c = self.core
        total = c.hbm_bytes // c.hbm_segment
        return (total - len(self.free_hbm_segs)) / max(total, 1)

    def fits_spatial(self, cfg: VNPUConfig) -> bool:
        if self.failed:
            return False
        c = self.core
        n_sram = -(-max(cfg.sram_bytes, c.sram_segment) // c.sram_segment)
        n_hbm = -(-max(cfg.hbm_bytes, c.hbm_segment) // c.hbm_segment)
        return (
            len(self.free_mes) >= cfg.n_me
            and len(self.free_ves) >= cfg.n_ve
            and len(self.free_sram_segs) >= n_sram
            and len(self.free_hbm_segs) >= n_hbm
        )


class VNPUManager:
    """Host-side vNPU manager (the paper's kernel-module control
    plane, minus the KVM plumbing — see DESIGN.md §3)."""

    def __init__(self, n_pnpus: int = 1, cores_per_pnpu: int = 1,
                 core: NPUCoreConfig = DEFAULT_CORE):
        self.core_cfg = core
        self.cores: List[CoreState] = [
            CoreState(core, p, c)
            for p in range(n_pnpus)
            for c in range(cores_per_pnpu)
        ]
        self.vnpus: Dict[int, VNPU] = {}
        # cross-tenant HBM loans: (lender vnpu_id, borrower vnpu_id)
        # -> bytes. The ledgers carry only their lent/borrowed totals;
        # this table is the authority on who owes whom.
        self._loans: Dict[Tuple[int, int], int] = {}

    # ------------------------------------------------------------------
    def create(self, cfg: VNPUConfig, name: str = "",
               mapping: str = "spatial",
               core_hint: Optional[int] = None) -> VNPU:
        """``core_hint`` pins placement to one core index (the fabric
        control plane's topology-aware choice; a live per-core
        simulation also pins resizes so a tenant cannot silently hop
        cores). ``None`` keeps the unrestricted greedy rule."""
        cfg.validate(self.core_cfg)
        v = VNPU(config=cfg, name=name, mapping=mapping)
        self.vnpus[v.vnpu_id] = v
        self._map(v, core_hint=core_hint)
        return v

    def destroy(self, v: VNPU, _settle_loans: bool = True) -> None:
        """vNPU deallocation: clean the context, release EUs+segments,
        tear down the (modeled) DMA mappings. Outstanding HBM loans
        are settled first (``reconfigure`` opts out: the re-placed
        vNPU inherits them via the ledger migration + loan re-key)."""
        if v.state == VNPUState.DESTROYED:
            return
        if _settle_loans:
            self._settle_loans(v)
        cs = self._core_of(v)
        if cs is not None:
            if v.mapping == "spatial":
                cs.free_mes.extend(v.me_ids)
                cs.free_ves.extend(v.ve_ids)
                cs.free_mes.sort()
                cs.free_ves.sort()
            else:
                cs.demand_me -= v.config.n_me
                cs.demand_ve -= v.config.n_ve
            if v.segments is not None:
                cs.free_sram_segs.extend(
                    s for s in v.segments.sram_segments)
                cs.free_hbm_segs.extend(
                    s for s in v.segments.hbm_segments)
                cs.free_sram_segs.sort()
                cs.free_hbm_segs.sort()
            cs.residents.remove(v.vnpu_id)
        v.destroy()

    def reconfigure(self, v: VNPU, cfg: VNPUConfig,
                    core_hint: Optional[int] = None) -> VNPU:
        """Paper hypercall (2): change an existing vNPU's config.

        All-or-nothing: if the new config cannot be placed, the old
        mapping is restored and :class:`ReconfigureError` is raised
        carrying the restored vNPU (live control planes must keep a
        valid handle — a failed grow must not kill the tenant).

        The vNPU's KV ledger (live per-request HBM occupancy) is
        carried to the new mapping. A resize whose HBM allocation
        cannot hold the live occupancy is REJECTED the same
        all-or-nothing way: callers must evict/swap the excess first —
        shrinking segments out from under resident KV would corrupt
        tenant state."""
        mapping = v.mapping
        old_cfg = v.config
        old_ledger = v.kv_ledger
        old_id = v.vnpu_id
        self.destroy(v, _settle_loans=False)

        def _restore() -> VNPU:
            restored = self.create(old_cfg, name=v.name, mapping=mapping,
                                   core_hint=core_hint)
            if old_ledger is not None:
                restored.kv_ledger.migrate_from(old_ledger)
            self._rekey_loans(old_id, restored.vnpu_id)
            return restored

        try:
            nv = self.create(cfg, name=v.name, mapping=mapping,
                             core_hint=core_hint)
        except RuntimeError as exc:
            raise ReconfigureError(
                f"reconfigure of vNPU {v.name!r} to "
                f"{cfg.n_me}ME/{cfg.n_ve}VE failed ({exc}); "
                f"previous mapping restored", _restore()) from exc
        if old_ledger is not None:
            try:
                nv.kv_ledger.migrate_from(old_ledger)
            except KVLedgerError as exc:
                self.destroy(nv, _settle_loans=False)
                raise ReconfigureError(
                    f"reconfigure of vNPU {v.name!r} rejected: {exc}; "
                    f"previous mapping restored", _restore()) from exc
        self._rekey_loans(old_id, nv.vnpu_id)
        return nv

    # ------------------------------------------------------------------
    def _core_of(self, v: VNPU) -> Optional[CoreState]:
        for cs in self.cores:
            if v.vnpu_id in cs.residents:
                return cs
        return None

    def core_index_of(self, v: VNPU) -> int:
        """Fabric-facing: index (into ``self.cores``) of the core a
        vNPU is mapped on. Raises for an unmapped/destroyed vNPU."""
        for i, cs in enumerate(self.cores):
            if v.vnpu_id in cs.residents:
                return i
        raise ValueError(f"vNPU {v.name!r} is not mapped on any core")

    def _alloc_segments(self, cs: CoreState, cfg: VNPUConfig) -> MemorySegments:
        c = cs.core
        n_sram = -(-max(cfg.sram_bytes, c.sram_segment) // c.sram_segment)
        n_hbm = -(-max(cfg.hbm_bytes, c.hbm_segment) // c.hbm_segment)
        if len(cs.free_sram_segs) < n_sram or len(cs.free_hbm_segs) < n_hbm:
            raise RuntimeError("out of memory segments")
        sram = tuple(cs.free_sram_segs[:n_sram])
        hbm = tuple(cs.free_hbm_segs[:n_hbm])
        del cs.free_sram_segs[:n_sram]
        del cs.free_hbm_segs[:n_hbm]
        return MemorySegments(sram, hbm, c.sram_segment, c.hbm_segment)

    def _map(self, v: VNPU, core_hint: Optional[int] = None) -> None:
        cfg = v.config
        pool = self.cores
        if core_hint is not None:
            if not 0 <= core_hint < len(self.cores):
                raise ValueError(
                    f"core_hint {core_hint} out of range for "
                    f"{len(self.cores)} cores")
            if self.cores[core_hint].failed:
                raise RuntimeError(
                    f"core {core_hint} has failed; place elsewhere")
            pool = [self.cores[core_hint]]
        elif any(cs.failed for cs in pool):
            pool = [cs for cs in pool if not cs.failed]
            if not pool:
                raise RuntimeError("every core in the pool has failed")
        if v.mapping == "spatial":
            # greedy §III-C: among cores that fit, pick the one where
            # adding this vNPU best balances EU-frac vs mem-frac.
            def imbalance(cs: CoreState) -> float:
                c = cs.core
                eu = cs.eu_used_frac + cfg.n_eus / (c.n_me + c.n_ve)
                total_hbm = c.hbm_bytes // c.hbm_segment
                mem = cs.mem_used_frac + (
                    -(-max(cfg.hbm_bytes, c.hbm_segment) // c.hbm_segment)
                    / max(total_hbm, 1)
                )
                return abs(eu - mem)

            candidates = [cs for cs in pool if cs.fits_spatial(cfg)]
            if not candidates:
                raise RuntimeError(
                    f"no pNPU core fits vNPU {cfg.n_me}ME/{cfg.n_ve}VE "
                    f"(spatial); free up resources or use temporal mapping"
                )
            cs = min(candidates, key=imbalance)
            v.me_ids = tuple(cs.free_mes[: cfg.n_me])
            v.ve_ids = tuple(cs.free_ves[: cfg.n_ve])
            del cs.free_mes[: cfg.n_me]
            del cs.free_ves[: cfg.n_ve]
        else:
            # temporal: least-loaded core by oversubscribed demand
            cs = min(pool, key=lambda c: c.demand_me + c.demand_ve)
            cs.demand_me += cfg.n_me
            cs.demand_ve += cfg.n_ve
            v.me_ids = tuple(range(cfg.n_me))   # logical ids
            v.ve_ids = tuple(range(cfg.n_ve))
        v.segments = self._alloc_segments(cs, cfg)
        # live HBM accounting over exactly the segments this vNPU owns
        v.kv_ledger = KVLedger(v.segments.hbm_bytes, cs.core.hbm_segment)
        cs.residents.append(v.vnpu_id)
        v.pnpu_id, v.core_id = cs.pnpu_id, cs.core_id
        v.state = VNPUState.MAPPED

    # ------------------------------------------------------------------
    # fault injection
    # ------------------------------------------------------------------
    def fail_core(self, idx: int) -> List[int]:
        """Mark core ``idx`` failed: no new placements land on it
        until :meth:`restore_core`. Residents stay mapped (the control
        plane evacuates or suspends them). Returns the resident vNPU
        ids at fault time."""
        cs = self.cores[idx]
        cs.failed = True
        return list(cs.residents)

    def restore_core(self, idx: int) -> None:
        """A transient core fault healed: accept placements again."""
        self.cores[idx].failed = False

    def healthy_cores(self) -> List[int]:
        """Indices of cores currently accepting placements."""
        return [i for i, cs in enumerate(self.cores) if not cs.failed]

    def fault_hbm_segments(self, v: VNPU, n: int = 1) -> int:
        """``n`` of vNPU ``v``'s HBM isolation segments fault away
        (a bad HBM row inside its allocation). All-or-nothing: the
        ledger capacity shrinks FIRST — raising
        :class:`~repro.core.vnpu.KVLedgerError` untouched when the
        live occupancy would no longer fit, so the caller evicts down
        (or escalates to evacuation) and retries — and only then do
        the physical segments leave the vNPU, parked on the core's
        ``faulted_hbm_segs`` list (never returned to a free list).
        Returns the bytes removed."""
        if v.segments is None or v.kv_ledger is None:
            raise ValueError(f"vNPU {v.name!r} is not mapped")
        cs = self._core_of(v)
        if cs is None:
            raise ValueError(f"vNPU {v.name!r} is not resident on any core")
        n = min(n, len(v.segments.hbm_segments))
        if n <= 0:
            return 0
        seg = v.kv_ledger.segment_bytes
        v.kv_ledger.shrink_capacity(n * seg)   # raises before any mutation
        hbm = v.segments.hbm_segments
        lost, kept = hbm[-n:], hbm[:-n]
        cs.faulted_hbm_segs.extend(lost)
        cs.faulted_hbm_segs.sort()
        v.segments = MemorySegments(
            v.segments.sram_segments, kept,
            v.segments.sram_segment_size, v.segments.hbm_segment_size)
        return n * seg

    def fault_free_hbm_segments(self, idx: int, n: int = 1) -> int:
        """``n`` HBM segments fault out of core ``idx``'s FREE pool —
        a bad row outside any allocation, or the segments a vNPU
        vacated when its HBM fault escalated to whole-vNPU failover.
        Clamped to the free list; returns the segments faulted."""
        cs = self.cores[idx]
        n = min(n, len(cs.free_hbm_segs))
        if n <= 0:
            return 0
        lost = cs.free_hbm_segs[-n:]
        del cs.free_hbm_segs[-n:]
        cs.faulted_hbm_segs.extend(lost)
        cs.faulted_hbm_segs.sort()
        return n

    def hbm_census(self) -> List[Tuple[int, int, int, int]]:
        """Per-core ``(free, resident, faulted, total)`` HBM segment
        counts — conservation holds iff ``free + resident + faulted ==
        total`` on every core at all times."""
        out = []
        for cs in self.cores:
            total = cs.core.hbm_bytes // cs.core.hbm_segment
            resident = sum(
                len(self.vnpus[i].segments.hbm_segments)
                for i in cs.residents
                if self.vnpus[i].segments is not None)
            out.append((len(cs.free_hbm_segs), resident,
                        len(cs.faulted_hbm_segs), total))
        return out

    # ------------------------------------------------------------------
    def collocated(self, v: VNPU) -> List[VNPU]:
        cs = self._core_of(v)
        if cs is None:
            return []
        return [self.vnpus[i] for i in cs.residents if i != v.vnpu_id]

    # ------------------------------------------------------------------
    # cross-tenant HBM borrowing (reclaim-on-pressure protocol)
    # ------------------------------------------------------------------
    def borrow_hbm(self, v: VNPU, nbytes: int) -> int:
        """Borrow up to ``nbytes`` (rounded up to whole isolation
        segments) of IDLE HBM from co-resident vNPUs' ledgers for
        ``v``. Deterministic: lenders are scanned in vnpu_id order,
        each granting as many idle segments as it can spare. Returns
        the bytes actually granted (0 when no co-resident has an idle
        segment)."""
        led = v.kv_ledger
        if led is None or nbytes <= 0:
            return 0
        seg = led.segment_bytes
        need_segs = -(-int(nbytes) // seg)
        got = 0
        for peer in sorted(self.collocated(v), key=lambda p: p.vnpu_id):
            if need_segs <= 0:
                break
            plend = peer.kv_ledger
            if plend is None:
                continue
            idle_segs = max(plend.available, 0) // seg
            take_segs = min(need_segs, idle_segs)
            if take_segs <= 0:
                continue
            take = take_segs * seg
            if not plend.lend(take):      # pragma: no cover (idle-checked)
                continue
            led.grant(take)
            key = (peer.vnpu_id, v.vnpu_id)
            self._loans[key] = self._loans.get(key, 0) + take
            got += take
            need_segs -= take_segs
        return got

    def reclaim_hbm(self, v: VNPU, nbytes: int) -> int:
        """Reclaim-on-pressure: pull back up to ``nbytes`` that ``v``
        lent out, BEFORE its own admission blocks. Only the idle share
        of each loan can return (live KV on a borrowed segment stays
        until the borrower frees it). Returns the bytes reclaimed."""
        led = v.kv_ledger
        if led is None or nbytes <= 0 or led.lent <= 0:
            return 0
        got = 0
        for key in sorted(k for k in self._loans if k[0] == v.vnpu_id):
            if got >= nbytes:
                break
            borrower = self.vnpus.get(key[1])
            bled = None if borrower is None else borrower.kv_ledger
            if bled is None:
                continue
            back = bled.revoke(min(self._loans[key], int(nbytes) - got))
            if back <= 0:
                continue
            led.reclaim_lent(back)
            self._loans[key] -= back
            if self._loans[key] <= 0:
                del self._loans[key]
            got += back
        return got

    def return_borrowed(self, v: VNPU) -> int:
        """Give back every byte ``v`` borrowed from co-residents (a
        loan cannot follow a vNPU off its core, so evacuation unwinds
        the borrower side first). Only IDLE borrowed capacity can
        leave — the caller evicts ``v``'s KV down to its own segments
        before calling; live KV still riding borrowed capacity raises,
        loans untouched past the ones already returned. Returns the
        bytes returned."""
        led = v.kv_ledger
        got = 0
        for key in sorted(k for k in self._loans if k[1] == v.vnpu_id):
            n = self._loans[key]
            back = 0 if led is None else led.revoke(n)
            if back < n:
                if led is not None:
                    led.grant(back)     # undo the partial revoke
                raise KVLedgerError(
                    f"vNPU {v.name!r} still holds live KV on {n - back} B "
                    f"of borrowed capacity; evict before evacuating")
            lender = self.vnpus.get(key[0])
            if lender is not None and lender.kv_ledger is not None:
                lender.kv_ledger.reclaim_lent(back)
            del self._loans[key]
            got += back
        return got

    def borrowers_of(self, v: VNPU) -> List[int]:
        """vnpu_ids currently borrowing capacity from ``v`` (sorted;
        failover force-drains them before evacuating the lender)."""
        return sorted(b for (l, b) in self._loans if l == v.vnpu_id)

    def loans_of(self, v: VNPU) -> Tuple[int, int]:
        """(bytes lent out, bytes borrowed) per the loan table."""
        lent = sum(n for (l, _), n in self._loans.items()
                   if l == v.vnpu_id)
        borrowed = sum(n for (_, b), n in self._loans.items()
                       if b == v.vnpu_id)
        return lent, borrowed

    def _settle_loans(self, v: VNPU) -> None:
        """Unwind every loan involving ``v`` (destroy path). As a
        borrower, its grant vanishes with it — the lender gets its
        bytes back in full. As a lender, the borrower must be able to
        give the idle bytes back; live KV stranded on a dying lender's
        segments is a control-plane bug and raises."""
        for key in sorted(k for k in self._loans if v.vnpu_id in k):
            n = self._loans.pop(key)
            lender = self.vnpus.get(key[0])
            borrower = self.vnpus.get(key[1])
            if key[1] == v.vnpu_id:       # v borrowed: return in full
                if v.kv_ledger is not None:
                    v.kv_ledger.borrowed = max(v.kv_ledger.borrowed - n, 0)
                if lender is not None and lender.kv_ledger is not None:
                    lender.kv_ledger.reclaim_lent(n)
                continue
            # v lent: the borrower must release the idle capacity now
            bled = None if borrower is None else borrower.kv_ledger
            back = 0 if bled is None else bled.revoke(n)
            if bled is not None and back < n:
                raise KVLedgerError(
                    f"vNPU {v.name!r} destroyed with {n - back} B of its "
                    f"segments still holding live borrowed KV; drain the "
                    f"borrower first")
            if v.kv_ledger is not None:
                v.kv_ledger.reclaim_lent(n if bled is None else back)

    def _rekey_loans(self, old_id: int, new_id: int) -> None:
        """Reconfigure carried a ledger (and its loan counters) to a
        new vNPU id: move the loan-table keys with it. Session resizes
        are core-pinned, so co-residency — the physical premise of a
        loan — survives the re-placement."""
        for key in [k for k in self._loans if old_id in k]:
            n = self._loans.pop(key)
            nk = (new_id if key[0] == old_id else key[0],
                  new_id if key[1] == old_id else key[1])
            self._loans[nk] = self._loans.get(nk, 0) + n
