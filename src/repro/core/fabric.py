"""Multi-pNPU cluster fabric: the inter-core link topology and the
cross-core phase-migration protocol (cluster-scale vNPU serving).

The paper virtualizes one pNPU; its pay-as-you-go model only pays off
at cloud scale when vNPUs map onto a *fleet* of cores connected by an
inter-core fabric ("Topology-Aware Virtualization over Inter-Core
Connected NPUs"; DistServe-style prefill/decode disaggregation). This
module provides the pieces the control plane composes:

* :class:`FabricTopology` — a link graph over the cluster's cores
  (ring / 2-D mesh / fully-connected builders, or any custom edge
  set), each link carrying a bandwidth (bytes/cycle) and a latency
  (cycles). A transfer is priced store-and-forward over the shortest
  path: ``sum over hops of (link latency + bytes / link bandwidth)``
  — for uniform links exactly ``hops x (latency + bytes/bw)``, the
  hop-count x KV-bytes cost model.
* :class:`Placement` — the ``register_generative(placement=...)``
  request: where a tenant's prefill pool and decode pool land
  (explicit cores, or ``"topo"`` / ``"random"`` auto strategies) and
  how the EU budget splits between them.
* :func:`random_phase_pair` — the seeded random-placement baseline
  the fabric benchmark compares the topology-aware allocator
  (:func:`repro.core.allocator.place_phase_pair`) against.

The migration protocol itself (destination KV ledger charged before
the source frees, all-or-nothing, reject-to-local-decode under
destination pressure) is :meth:`repro.core.vnpu.KVLedger.
migrate_entry_to` driven by the serving session's migration hook; one
:class:`~repro.core.simulator.Simulator` per core is advanced in
lockstep by :class:`repro.serve.session.ServingSession`.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.npu.hw_config import DEFAULT_CORE, TPUv5eRoofline

# Default link model: one inter-core interconnect lane per link at the
# TPU-v5e ICI bandwidth, expressed in the simulator's cycle domain,
# plus a per-hop launch latency (DMA descriptor + switch traversal).
DEFAULT_LINK_BW = TPUv5eRoofline.ici_bw / DEFAULT_CORE.freq_hz  # B/cycle
DEFAULT_LINK_LATENCY = 2_000.0                                  # cycles


@dataclass(frozen=True)
class FabricLink:
    """One bidirectional inter-core link. ``bandwidth`` is bytes per
    cycle of the core clock; ``latency`` is cycles per traversal."""

    bandwidth: float = DEFAULT_LINK_BW
    latency: float = DEFAULT_LINK_LATENCY

    def __post_init__(self):
        if self.bandwidth <= 0 or self.latency < 0:
            raise ValueError(
                f"link needs bandwidth > 0 B/cycle and latency >= 0 "
                f"cycles, got {self.bandwidth}/{self.latency}")


class FabricTopology:
    """Link graph over ``n_cores`` pNPU cores.

    Edges are undirected and keyed on the sorted core pair; cores with
    no path between them are unreachable (``hops`` returns ``inf`` and
    transfers are unpriceable — placement never pairs them). Shortest
    paths are hop-count BFS, cached per source."""

    def __init__(self, n_cores: int,
                 links: Dict[Tuple[int, int], FabricLink],
                 kind: str = "custom"):
        if n_cores < 1:
            raise ValueError(f"topology needs >= 1 core, got {n_cores}")
        self.n_cores = n_cores
        self.kind = kind
        self.links: Dict[Tuple[int, int], FabricLink] = {}
        self._adj: List[List[int]] = [[] for _ in range(n_cores)]
        for (a, b), link in links.items():
            a, b = int(a), int(b)
            if not (0 <= a < n_cores and 0 <= b < n_cores) or a == b:
                raise ValueError(f"bad link endpoints ({a}, {b}) for "
                                 f"{n_cores} cores")
            key = (min(a, b), max(a, b))
            if key in self.links:
                continue
            self.links[key] = link
            self._adj[a].append(b)
            self._adj[b].append(a)
        for nbrs in self._adj:
            nbrs.sort()
        self._paths: Dict[int, Dict[int, Tuple[int, ...]]] = {}
        # pristine link set, kept so fault injection can restore a
        # degraded/failed link to its exact base parameters
        self._base_links: Dict[Tuple[int, int], FabricLink] = dict(self.links)

    # ---------------- builders ----------------
    @classmethod
    def single(cls) -> "FabricTopology":
        """Degenerate one-core fabric (no links; the single-pNPU
        engine path — every transfer cost is zero)."""
        return cls(1, {}, kind="single")

    @classmethod
    def ring(cls, n: int, link: FabricLink = FabricLink()
             ) -> "FabricTopology":
        """Bidirectional ring: core i links to (i+1) mod n."""
        if n == 1:
            return cls.single()
        links = {(i, (i + 1) % n): link for i in range(n)}
        return cls(n, links, kind="ring")

    @classmethod
    def mesh(cls, n: int, link: FabricLink = FabricLink()
             ) -> "FabricTopology":
        """2-D mesh on the most-square r x c grid with r*c == n
        (degenerates to a line for prime n)."""
        if n == 1:
            return cls.single()
        r = int(math.isqrt(n))
        while n % r:
            r -= 1
        c = n // r
        links = {}
        for i in range(n):
            y, x = divmod(i, c)
            if x + 1 < c:
                links[(i, i + 1)] = link
            if y + 1 < r:
                links[(i, i + c)] = link
        return cls(n, links, kind="mesh")

    @classmethod
    def fully_connected(cls, n: int, link: FabricLink = FabricLink()
                        ) -> "FabricTopology":
        """Every core one hop from every other (switched fabric)."""
        if n == 1:
            return cls.single()
        links = {(a, b): link
                 for a in range(n) for b in range(a + 1, n)}
        return cls(n, links, kind="fully_connected")

    # ---------------- fault injection ----------------
    def _rebuild_adj(self) -> None:
        self._adj = [[] for _ in range(self.n_cores)]
        for a, b in self.links:
            self._adj[a].append(b)
            self._adj[b].append(a)
        for nbrs in self._adj:
            nbrs.sort()
        self._paths.clear()

    def _base_key(self, a: int, b: int) -> Tuple[int, int]:
        key = (min(a, b), max(a, b))
        if key not in self._base_links:
            raise ValueError(f"no link ({a}, {b}) in this topology")
        return key

    def degrade_link(self, a: int, b: int, bw_scale: float) -> None:
        """Scale link (a, b)'s bandwidth to ``bw_scale`` x its BASE
        bandwidth. ``bw_scale == 0`` is a full outage: the link drops
        out of the graph and routes re-plan around it (a pair left
        with no path prices transfers at ``inf`` — migration hooks
        reject and decode locally). Idempotent per absolute scale."""
        key = self._base_key(a, b)
        base = self._base_links[key]
        if bw_scale < 0:
            raise ValueError(f"bw_scale must be >= 0, got {bw_scale}")
        if bw_scale == 0:
            self.links.pop(key, None)
        else:
            self.links[key] = FabricLink(bandwidth=base.bandwidth * bw_scale,
                                         latency=base.latency)
        self._rebuild_adj()

    def fail_link(self, a: int, b: int) -> None:
        """Full link outage — shorthand for ``degrade_link(a, b, 0)``."""
        self.degrade_link(a, b, 0.0)

    def restore_link(self, a: int, b: int) -> None:
        """Return link (a, b) to its pristine base parameters."""
        key = self._base_key(a, b)
        self.links[key] = self._base_links[key]
        self._rebuild_adj()

    # ---------------- queries ----------------
    def neighbors(self, core: int) -> Tuple[int, ...]:
        return tuple(self._adj[core])

    def link(self, a: int, b: int) -> FabricLink:
        return self.links[(min(a, b), max(a, b))]

    def _bfs(self, src: int) -> Dict[int, Tuple[int, ...]]:
        """Shortest path (as the visited-core sequence src..dst) to
        every reachable core; deterministic (lowest-id tie-break)."""
        paths = self._paths.get(src)
        if paths is not None:
            return paths
        paths = {src: (src,)}
        q = deque([src])
        while q:
            u = q.popleft()
            for v in self._adj[u]:
                if v not in paths:
                    paths[v] = paths[u] + (v,)
                    q.append(v)
        self._paths[src] = paths
        return paths

    def hops(self, src: int, dst: int) -> float:
        """Shortest-path hop count (0 for src==dst, inf when
        unreachable)."""
        path = self._bfs(src).get(dst)
        return math.inf if path is None else float(len(path) - 1)

    def path_links(self, src: int, dst: int) -> List[Tuple[int, int]]:
        """The (a, b) link sequence of the shortest src->dst path."""
        path = self._bfs(src).get(dst)
        if path is None:
            raise ValueError(f"cores {src} and {dst} are not connected")
        return [(path[i], path[i + 1]) for i in range(len(path) - 1)]

    def transfer_cycles(self, src: int, dst: int, nbytes: float) -> float:
        """Cycles to move ``nbytes`` from core ``src`` to ``dst``:
        store-and-forward over the shortest path, each hop paying its
        link latency plus the serialization time ``nbytes /
        bandwidth`` — hop count x KV bytes over the link model.
        ``inf`` for unreachable pairs (placement skips them)."""
        if src == dst:
            return 0.0
        if self._bfs(src).get(dst) is None:
            return math.inf
        total = 0.0
        for a, b in self.path_links(src, dst):
            link = self.link(a, b)
            total += link.latency + float(nbytes) / link.bandwidth
        return total

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"FabricTopology(kind={self.kind!r}, "
                f"n_cores={self.n_cores}, links={len(self.links)})")


# ----------------------------------------------------------------------
@dataclass
class Placement:
    """Where a generative tenant's phase pools land on the fabric
    (``ServingSession.register_generative(placement=...)``).

    ``prefill_core`` / ``decode_core`` pin cores explicitly; left
    ``None``, ``strategy`` picks them: ``"topo"`` routes through the
    topology-aware allocator (chatty phase pairs on neighboring
    cores, load-balanced — :func:`repro.core.allocator.
    place_phase_pair`), ``"random"`` through the seeded baseline
    (:func:`random_phase_pair`). ``prefill_eus`` / ``decode_eus``
    split the tenant's EU budget between the pools (0 = half each);
    the ``*_hbm_bytes`` pins override the per-side HBM allocation
    (bytes), e.g. to squeeze the decode pool for reject testing."""

    prefill_core: Optional[int] = None
    decode_core: Optional[int] = None
    strategy: str = "topo"       # "topo" | "random"
    seed: int = 0                # random-strategy draw
    prefill_eus: int = 0         # 0 -> eu_budget // 2
    decode_eus: int = 0          # 0 -> eu_budget - eu_budget // 2
    prefill_hbm_bytes: Optional[int] = None
    decode_hbm_bytes: Optional[int] = None

    def __post_init__(self):
        if self.strategy not in ("topo", "random"):
            raise ValueError(
                f"unknown placement strategy {self.strategy!r}; "
                f"use 'topo' or 'random'")


def random_phase_pair(topology: FabricTopology, seed: int = 0
                      ) -> Tuple[int, int]:
    """Seeded random-placement baseline: a uniform draw of two
    DISTINCT cores (distinct like the topology-aware rule, so the
    comparison isolates *which* cores, not whether the pools share
    one) with no regard for link distance."""
    n = topology.n_cores
    rng = np.random.default_rng(seed)
    a = int(rng.integers(n))
    if n == 1:
        return a, a
    b = int(rng.integers(n))
    while b == a:
        b = int(rng.integers(n))
    return a, b
