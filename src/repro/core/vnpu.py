"""vNPU: the paper's new system abstraction (§III-A).

A vNPU is a virtual NPU device exposed to a tenant: a number of MEs
and VEs, SRAM/HBM allocations, and a lifecycle
(CREATE -> MAP -> ACTIVE -> DESTROYED). The guest-visible hierarchy
(chips/cores) mirrors a physical board; the control-plane calls here
correspond to the paper's hypercalls (create / reconfigure /
deallocate) routed to the vNPU manager.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig


class VNPUState(enum.Enum):
    CREATED = "created"
    MAPPED = "mapped"
    ACTIVE = "active"
    DESTROYED = "destroyed"


@dataclass
class VNPUConfig:
    """User-facing pay-as-you-go spec (Fig. 10)."""

    n_me: int
    n_ve: int
    sram_bytes: int = 0        # 0 -> proportional to MEs (§III-B)
    hbm_bytes: int = 0
    n_cores: int = 1
    n_chips: int = 1
    priority: float = 1.0      # temporal-sharing fair-share weight

    @property
    def n_eus(self) -> int:
        return self.n_me + self.n_ve

    def validate(self, core: NPUCoreConfig = DEFAULT_CORE) -> None:
        if self.n_me < 1 or self.n_ve < 1:
            raise ValueError("each vNPU gets at least 1 ME and 1 VE (§III-B)")
        if self.n_me > core.n_me or self.n_ve > core.n_ve:
            raise ValueError(
                f"vNPU ({self.n_me}ME/{self.n_ve}VE) exceeds pNPU core "
                f"({core.n_me}ME/{core.n_ve}VE); allocate more vNPU cores instead"
            )


# preset sizes the paper suggests cloud providers expose (§III-A)
PRESETS = {
    "small": VNPUConfig(n_me=1, n_ve=1),
    "medium": VNPUConfig(n_me=4, n_ve=4),
    "large": VNPUConfig(n_me=8, n_ve=8, n_cores=2),
}


@dataclass
class MemorySegments:
    """Fixed-size-segment address-space isolation (§III-C)."""

    sram_segments: Tuple[int, ...] = ()
    hbm_segments: Tuple[int, ...] = ()
    sram_segment_size: int = DEFAULT_CORE.sram_segment
    hbm_segment_size: int = DEFAULT_CORE.hbm_segment

    def translate(self, space: str, vaddr: int) -> int:
        """Virtual -> physical: base-plus-offset within the segment
        list. Raises (page fault) on out-of-range access."""
        segs, size = (
            (self.sram_segments, self.sram_segment_size)
            if space == "sram"
            else (self.hbm_segments, self.hbm_segment_size)
        )
        idx, off = divmod(vaddr, size)
        if vaddr < 0 or idx >= len(segs):
            raise MemoryError(
                f"vNPU page fault: {space} vaddr {vaddr:#x} outside the "
                f"{len(segs)}-segment allocation"
            )
        return segs[idx] * size + off

    @property
    def sram_bytes(self) -> int:
        return len(self.sram_segments) * self.sram_segment_size

    @property
    def hbm_bytes(self) -> int:
        return len(self.hbm_segments) * self.hbm_segment_size


class KVLedgerError(RuntimeError):
    """A ledger operation violated its bookkeeping contract
    (double-free, unknown allocation, over-reservation)."""


class KVLedger:
    """Live HBM accounting for one vNPU's segment allocation.

    Tracks per-request KV-cache bytes against the vNPU's HBM capacity
    so decode context growth consumes memory *live* instead of hiding
    behind a static ``hbm_footprint`` max. ``reserved`` bytes model
    the resident working set that is not per-request (weights); every
    other byte is owned by exactly one request id.

    Beyond per-request entries the ledger carries two optional pools,
    both empty (and cost-free) unless a control plane turns them on:

    * SHARED entries — refcounted segments keyed by prefix hash
      (cross-request KV prefix caching). The FIRST ``acquire_shared``
      of a key charges its bytes all-or-nothing; later acquires only
      bump the refcount; ``release_shared`` frees the bytes exactly
      when the last holder lets go.
    * BORROWED/LENT bytes — cross-tenant segment borrowing
      (:meth:`VNPUManager.borrow_hbm`): ``lend`` parks idle bytes of
      THIS ledger for a co-resident borrower (they stop being
      allocatable here), ``grant`` extends the borrower's effective
      capacity by the same amount. The manager keeps the loan table;
      the ledger only carries the two counters.

    Invariants (proven by the property tests):

    * ``reserved + in_use + shared_in_use + lent <= capacity +
      borrowed`` at all times — an ``alloc``/``acquire_shared``/
      ``lend`` that would exceed returns False and changes nothing;
    * frees are exact: ``free(rid)`` returns precisely the bytes
      ``rid`` holds and removes the entry; freeing an unknown rid
      raises :class:`KVLedgerError` (no silent double-free);
    * refcounts never go negative: ``release_shared`` of an unknown
      key raises; a key's entry disappears exactly when its refcount
      reaches zero;
    * conservation: ``sum(entries) == in_use`` and
      ``sum(shared bytes) == shared_in_use`` across any sequence of
      alloc/grow/free/acquire/release/clear/migrate.

    Units: every quantity is BYTES except ``used_segments`` /
    ``peak_segments`` (counts of ``segment_bytes``-sized isolation
    segments, §III-C)."""

    def __init__(self, capacity_bytes: int, segment_bytes: int,
                 reserved_bytes: int = 0):
        if capacity_bytes < 0 or segment_bytes <= 0:
            raise ValueError("ledger needs capacity >= 0 and segment > 0")
        self.capacity = int(capacity_bytes)
        self.segment_bytes = int(segment_bytes)
        self.reserved = 0
        self.in_use = 0
        self.entries: Dict[int, int] = {}
        # refcounted cross-request prefix segments: key -> [bytes, refs]
        self.shared: Dict[int, List[int]] = {}
        self.shared_in_use = 0
        # LRU retention: a shared entry whose last holder left is
        # parked here (key -> [bytes, expires_at]) for up to
        # ``retention_window`` cycles before its bytes free; retired
        # entries are evicted FIRST under pressure. Window 0 (the
        # default) disables retention entirely — last release frees
        # immediately, exactly the pre-retention behaviour.
        self.retention_window = 0.0
        self.retired: Dict[int, List[float]] = {}
        # cross-tenant segment borrowing (manager-mediated)
        self.borrowed = 0      # extra capacity granted BY co-residents
        self.lent = 0          # own bytes parked FOR co-residents
        self.peak_bytes = 0
        self.peak_segments = 0
        if reserved_bytes:
            self.reserve(reserved_bytes)

    # ------------------------------------------------------------------
    @property
    def available(self) -> int:
        """Bytes still allocatable: effective capacity (own segments
        plus borrowed ones) minus everything resident or lent away."""
        return (self.capacity + self.borrowed - self.reserved
                - self.in_use - self.shared_in_use - self.lent)

    @property
    def occupancy(self) -> int:
        """Bytes a resize must keep: weights + per-request KV + shared
        prefix segments + bytes lent to co-residents (lent segments
        host ANOTHER tenant's live KV — they cannot be shrunk away
        until the loan is reclaimed)."""
        return self.reserved + self.in_use + self.shared_in_use + self.lent

    @property
    def used_segments(self) -> int:
        """HBM isolation segments the live occupancy covers."""
        return -(-self.occupancy // self.segment_bytes)

    def fits(self, nbytes: float) -> bool:
        return nbytes <= self.available

    def reserve(self, nbytes: int) -> None:
        """Set the non-per-request resident share (weights) to
        ``nbytes`` absolute; raises if it cannot fit next to the live
        allocations."""
        nbytes = int(nbytes)
        live = self.in_use + self.shared_in_use + self.lent
        if nbytes < 0 or nbytes + live > self.capacity + self.borrowed:
            raise KVLedgerError(
                f"cannot reserve {nbytes} B: {live} B live KV in a "
                f"{self.capacity} B ledger")
        self.reserved = nbytes
        self._mark()

    def alloc(self, rid: int, nbytes: float) -> bool:
        """Allocate (or grow by) ``nbytes`` for request ``rid``.
        All-or-nothing: returns False — and changes nothing — when the
        ledger would exceed its capacity."""
        n = int(nbytes)
        if n < 0:
            raise KVLedgerError(f"negative allocation ({n} B) for rid {rid}")
        if n > self.available:
            return False
        self.entries[rid] = self.entries.get(rid, 0) + n
        self.in_use += n
        self._mark()
        return True

    def bytes_of(self, rid: int) -> int:
        return self.entries.get(rid, 0)

    def free(self, rid: int) -> int:
        """Release ``rid``'s allocation exactly; raises
        :class:`KVLedgerError` on an unknown rid (double-free)."""
        if rid not in self.entries:
            raise KVLedgerError(f"free of unknown/already-freed rid {rid}")
        n = self.entries.pop(rid)
        self.in_use -= n
        return n

    def release(self, rid: int) -> int:
        """Lenient free: 0 for an unknown rid (used on teardown paths
        where a request may legitimately hold nothing)."""
        if rid not in self.entries:
            return 0
        return self.free(rid)

    # ------------------------------------------------------------------
    # refcounted shared prefix entries
    # ------------------------------------------------------------------
    def acquire_shared(self, key: int, nbytes: float) -> bool:
        """Attach one holder to the shared entry ``key``.

        A resident key only bumps its refcount — a prefix HIT costs no
        bytes. An absent key is FIRST-FILLED all-or-nothing: ``nbytes``
        are charged (False — and nothing changes — when they don't
        fit), then the entry starts at refcount 1. ``nbytes`` of later
        acquires must match the resident size (the prefix hash keys
        the exact token range, so a size mismatch is a caller bug)."""
        n = int(nbytes)
        if n <= 0:
            raise KVLedgerError(
                f"shared entry {key} needs positive bytes, got {n}")
        ent = self.shared.get(key)
        if ent is not None:
            if ent[0] != n:
                raise KVLedgerError(
                    f"shared entry {key} holds {ent[0]} B; acquire asked "
                    f"for {n} B (prefix-hash collision?)")
            ent[1] += 1
            return True
        parked = self.retired.get(key)
        if parked is not None:
            # retention HIT: the bytes never left — revive the entry
            # at refcount 1 with zero fill cost.
            if parked[0] != n:
                raise KVLedgerError(
                    f"retired entry {key} holds {int(parked[0])} B; acquire "
                    f"asked for {n} B (prefix-hash collision?)")
            del self.retired[key]
            self.shared[key] = [n, 1]
            return True
        if n > self.available:
            return False
        self.shared[key] = [n, 1]
        self.shared_in_use += n
        self._mark()
        return True

    def release_shared(self, key: int, now: Optional[float] = None) -> int:
        """Drop one holder of ``key``. The last release frees the
        entry's bytes exactly and returns them; earlier releases
        return 0. Raises on an unknown key (refcount underflow).

        With a positive ``retention_window`` and a ``now`` timestamp,
        the last release instead PARKS the entry in the retired table
        until ``now + retention_window`` — its bytes stay charged (a
        re-``acquire_shared`` before expiry revives it for free) and
        the release returns 0; the bytes free later via
        :meth:`expire_retired` / :meth:`evict_retired`."""
        ent = self.shared.get(key)
        if ent is None:
            raise KVLedgerError(
                f"release of unknown/already-freed shared key {key}")
        ent[1] -= 1
        if ent[1] > 0:
            return 0
        n = ent[0]
        del self.shared[key]
        if self.retention_window > 0 and now is not None:
            self.retired[key] = [n, now + self.retention_window]
            return 0
        self.shared_in_use -= n
        return n

    def shared_refs(self, key: int) -> int:
        """Holders of shared entry ``key`` (0 when absent)."""
        ent = self.shared.get(key)
        return 0 if ent is None else ent[1]

    def shared_bytes_of(self, key: int) -> int:
        ent = self.shared.get(key)
        return 0 if ent is None else ent[0]

    @property
    def retired_bytes(self) -> int:
        """Bytes held by retired (zero-holder, retained) entries."""
        return sum(int(v[0]) for v in self.retired.values())

    def expire_retired(self, now: float) -> int:
        """Free every retired entry whose retention window has lapsed
        (``expires_at <= now``); returns the bytes freed."""
        freed = 0
        for key in [k for k, v in self.retired.items() if v[1] <= now]:
            n = int(self.retired.pop(key)[0])
            self.shared_in_use -= n
            freed += n
        return freed

    def evict_retired(self, nbytes: float, now: Optional[float] = None) -> int:
        """Free retired entries under pressure — expired ones first,
        then oldest-expiry-first — until at least ``nbytes`` are freed
        or the table is empty. Returns the bytes freed. Retired
        entries are strictly cheaper victims than live KV (no holder
        loses state), so callers try this before PREMA eviction."""
        freed = 0
        if now is not None:
            freed = self.expire_retired(now)
        if freed >= nbytes:
            return freed
        for key in sorted(self.retired, key=lambda k: (self.retired[k][1], k)):
            if freed >= nbytes:
                break
            n = int(self.retired.pop(key)[0])
            self.shared_in_use -= n
            freed += n
        return freed

    def flush_retired(self) -> int:
        """Free ALL retired entries regardless of expiry (drain /
        teardown); returns the bytes freed."""
        n = self.retired_bytes
        self.retired.clear()
        self.shared_in_use -= n
        return n

    # ------------------------------------------------------------------
    # cross-tenant borrowing (counters only; the VNPUManager owns the
    # loan table and pairs every lend() with a grant() on the borrower)
    # ------------------------------------------------------------------
    def lend(self, nbytes: int) -> bool:
        """Park ``nbytes`` of THIS ledger's idle capacity for a
        co-resident borrower. All-or-nothing against ``available``."""
        n = int(nbytes)
        if n < 0:
            raise KVLedgerError(f"negative lend ({n} B)")
        if n > self.available:
            return False
        self.lent += n
        self._mark()
        return True

    def reclaim_lent(self, nbytes: int) -> None:
        """Take back ``nbytes`` previously lent (the borrower's grant
        was revoked first — manager-ordered, so the segments are idle
        again by the time they return)."""
        n = int(nbytes)
        if n < 0 or n > self.lent:
            raise KVLedgerError(
                f"reclaim of {n} B exceeds the {self.lent} B lent out")
        self.lent -= n

    def grant(self, nbytes: int) -> None:
        """Extend effective capacity by ``nbytes`` borrowed from a
        co-resident ledger (paired with that ledger's ``lend``)."""
        n = int(nbytes)
        if n < 0:
            raise KVLedgerError(f"negative grant ({n} B)")
        self.borrowed += n

    def revoke(self, nbytes: int) -> int:
        """Give back up to ``nbytes`` of borrowed capacity — only what
        is IDLE (not holding live KV) can leave. Returns the bytes
        actually revoked; the manager reclaims exactly that much on
        the lender side."""
        n = int(nbytes)
        if n < 0:
            raise KVLedgerError(f"negative revoke ({n} B)")
        take = min(n, self.borrowed, max(self.available, 0))
        self.borrowed -= take
        return take

    def clear(self) -> int:
        """Release every per-request allocation AND every shared
        prefix entry (tenant teardown); ``reserved`` stays until the
        vNPU itself is destroyed, and any loan counters stay until the
        manager settles them."""
        n = self.in_use + self.shared_in_use
        self.entries.clear()
        self.in_use = 0
        self.shared.clear()
        self.retired.clear()
        self.shared_in_use = 0
        return n

    def migrate_from(self, other: "KVLedger") -> None:
        """Adopt ``other``'s live state (vNPU reconfigure carries the
        ledger to the re-placed vNPU). Raises when the live occupancy
        — per-request KV, shared prefix segments, AND bytes lent to
        co-residents — does not fit the new capacity: shrinking a
        refcounted or lent segment out from under its holders would
        corrupt state, so the caller must drain/reclaim or reject the
        resize first. Borrowed capacity carries over (the manager
        re-keys its loan table to the new vNPU)."""
        need = (other.reserved + other.in_use + other.shared_in_use
                + other.lent)
        if need > self.capacity + other.borrowed:
            raise KVLedgerError(
                f"live occupancy {need} B exceeds the resized capacity "
                f"{self.capacity} B; evict or reject the resize")
        self.reserved = other.reserved
        self.in_use = other.in_use
        self.entries = dict(other.entries)
        self.shared = {k: list(v) for k, v in other.shared.items()}
        self.shared_in_use = other.shared_in_use
        self.retention_window = other.retention_window
        self.retired = {k: list(v) for k, v in other.retired.items()}
        self.borrowed = other.borrowed
        self.lent = other.lent
        self.peak_bytes = max(self.peak_bytes, other.peak_bytes)
        self.peak_segments = max(self.peak_segments, other.peak_segments)
        self._mark()

    def migrate_entry_to(self, dst: "KVLedger", rid: int,
                         dst_rid: Optional[int] = None) -> int:
        """Cross-ledger transfer of ONE request's allocation (the
        fabric's prefill->decode hand-off between cores): the
        DESTINATION ledger is charged first, all-or-nothing, and only
        then does the source free — a crash or reject mid-protocol
        can never leak or double-count segments.

        Returns the bytes moved, or -1 when the destination cannot
        hold them (destination pressure: nothing changed on either
        side — the caller falls back to local decode). Raises
        :class:`KVLedgerError` for an unknown source rid, exactly
        like :meth:`free`."""
        if rid not in self.entries:
            raise KVLedgerError(
                f"migrate of unknown/already-freed rid {rid}")
        n = self.entries[rid]
        if dst is self:
            return n                   # same ledger: nothing to move
        if dst_rid is None:
            dst_rid = rid
        if dst_rid in dst.entries:
            # a silent alloc here would MERGE into the resident
            # entry's bytes and the later free would release both
            # requests' KV at once — refuse before touching anything.
            raise KVLedgerError(
                f"migrate target rid {dst_rid} already live in the "
                f"destination ledger; both ledgers untouched")
        if not dst.alloc(dst_rid, n):
            return -1                  # reject: both ledgers untouched
        self.free(rid)
        return n

    def shrink_capacity(self, nbytes: int) -> None:
        """Permanently remove ``nbytes`` of capacity (an HBM segment
        fault). Raises when the live occupancy would no longer fit —
        the caller must evict/flush down to the new size first, or
        escalate to evacuation."""
        n = int(nbytes)
        if n < 0 or n > self.capacity:
            raise KVLedgerError(
                f"cannot shrink {self.capacity} B ledger by {n} B")
        if self.occupancy > self.capacity - n + self.borrowed:
            raise KVLedgerError(
                f"occupancy {self.occupancy} B exceeds the faulted "
                f"capacity {self.capacity - n} B; evict first")
        self.capacity -= n

    def _mark(self) -> None:
        used = self.occupancy
        if used > self.peak_bytes:
            self.peak_bytes = used
        segs = self.used_segments
        if segs > self.peak_segments:
            self.peak_segments = segs


_ids = itertools.count()


@dataclass
class VNPU:
    """A vNPU instance tracked by the vNPU manager."""

    config: VNPUConfig
    name: str = ""
    vnpu_id: int = field(default_factory=lambda: next(_ids))
    state: VNPUState = VNPUState.CREATED
    # filled in by the mapper
    pnpu_id: Optional[int] = None
    core_id: Optional[int] = None
    me_ids: Tuple[int, ...] = ()
    ve_ids: Tuple[int, ...] = ()
    segments: Optional[MemorySegments] = None
    mapping: str = "spatial"  # "spatial" (hw-isolated) | "temporal"
    # live HBM accounting against this vNPU's segment allocation
    # (created by the mapper; carried across reconfigures)
    kv_ledger: Optional[KVLedger] = None

    def __post_init__(self):
        if not self.name:
            self.name = f"vnpu{self.vnpu_id}"

    def destroy(self) -> None:
        self.state = VNPUState.DESTROYED
        self.me_ids = ()
        self.ve_ids = ()
        self.segments = None
        self.kv_ledger = None
