"""vNPU: the paper's new system abstraction (§III-A).

A vNPU is a virtual NPU device exposed to a tenant: a number of MEs
and VEs, SRAM/HBM allocations, and a lifecycle
(CREATE -> MAP -> ACTIVE -> DESTROYED). The guest-visible hierarchy
(chips/cores) mirrors a physical board; the control-plane calls here
correspond to the paper's hypercalls (create / reconfigure /
deallocate) routed to the vNPU manager.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig


class VNPUState(enum.Enum):
    CREATED = "created"
    MAPPED = "mapped"
    ACTIVE = "active"
    DESTROYED = "destroyed"


@dataclass
class VNPUConfig:
    """User-facing pay-as-you-go spec (Fig. 10)."""

    n_me: int
    n_ve: int
    sram_bytes: int = 0        # 0 -> proportional to MEs (§III-B)
    hbm_bytes: int = 0
    n_cores: int = 1
    n_chips: int = 1
    priority: float = 1.0      # temporal-sharing fair-share weight

    @property
    def n_eus(self) -> int:
        return self.n_me + self.n_ve

    def validate(self, core: NPUCoreConfig = DEFAULT_CORE) -> None:
        if self.n_me < 1 or self.n_ve < 1:
            raise ValueError("each vNPU gets at least 1 ME and 1 VE (§III-B)")
        if self.n_me > core.n_me or self.n_ve > core.n_ve:
            raise ValueError(
                f"vNPU ({self.n_me}ME/{self.n_ve}VE) exceeds pNPU core "
                f"({core.n_me}ME/{core.n_ve}VE); allocate more vNPU cores instead"
            )


# preset sizes the paper suggests cloud providers expose (§III-A)
PRESETS = {
    "small": VNPUConfig(n_me=1, n_ve=1),
    "medium": VNPUConfig(n_me=4, n_ve=4),
    "large": VNPUConfig(n_me=8, n_ve=8, n_cores=2),
}


@dataclass
class MemorySegments:
    """Fixed-size-segment address-space isolation (§III-C)."""

    sram_segments: Tuple[int, ...] = ()
    hbm_segments: Tuple[int, ...] = ()
    sram_segment_size: int = DEFAULT_CORE.sram_segment
    hbm_segment_size: int = DEFAULT_CORE.hbm_segment

    def translate(self, space: str, vaddr: int) -> int:
        """Virtual -> physical: base-plus-offset within the segment
        list. Raises (page fault) on out-of-range access."""
        segs, size = (
            (self.sram_segments, self.sram_segment_size)
            if space == "sram"
            else (self.hbm_segments, self.hbm_segment_size)
        )
        idx, off = divmod(vaddr, size)
        if vaddr < 0 or idx >= len(segs):
            raise MemoryError(
                f"vNPU page fault: {space} vaddr {vaddr:#x} outside the "
                f"{len(segs)}-segment allocation"
            )
        return segs[idx] * size + off

    @property
    def sram_bytes(self) -> int:
        return len(self.sram_segments) * self.sram_segment_size

    @property
    def hbm_bytes(self) -> int:
        return len(self.hbm_segments) * self.hbm_segment_size


_ids = itertools.count()


@dataclass
class VNPU:
    """A vNPU instance tracked by the vNPU manager."""

    config: VNPUConfig
    name: str = ""
    vnpu_id: int = field(default_factory=lambda: next(_ids))
    state: VNPUState = VNPUState.CREATED
    # filled in by the mapper
    pnpu_id: Optional[int] = None
    core_id: Optional[int] = None
    me_ids: Tuple[int, ...] = ()
    ve_ids: Tuple[int, ...] = ()
    segments: Optional[MemorySegments] = None
    mapping: str = "spatial"  # "spatial" (hw-isolated) | "temporal"

    def __post_init__(self):
        if not self.name:
            self.name = f"vnpu{self.vnpu_id}"

    def destroy(self) -> None:
        self.state = VNPUState.DESTROYED
        self.me_ids = ()
        self.ve_ids = ()
        self.segments = None
