"""Credit-based SLO admission control (fleet scale).

Registration today always succeeds when segments fit; under bursty
multi-tenant load that melts the well-behaved tenants' tails. This
module adds the cluster-level gate in front of
:meth:`repro.serve.session.ServingSession.register`: every tenant
holds a :class:`CreditAccount` whose balance follows PREMA's
token scheme —

* **accrual**: credit accrues continuously at a rate set by the
  tenant's DECLARED SLOs (``base_rate`` plus ``slo_rate`` times the
  summed strictness ``1/slo_ms`` of each declared TTFT / TBT / e2e
  target). A tenant that promises tight service pays for — and is
  owed — tight admission.
* **decay**: the opening balance decays exponentially toward zero
  with half-life ``decay_halflife_s`` (PREMA's aging, continuous
  form), so hoarded credit is bounded by ``rate * tau`` and a
  long-idle tenant cannot bank unbounded priority.
* **debits**: every observed violation — a TTFT / TBT sample over
  its declared SLO, or an admission-deadline miss — debits
  ``violation_debit``; admissions and approved scale-ups debit their
  price. A tenant that keeps missing its own SLOs stops outbidding
  its neighbors.

Every account conserves exactly::

    credit == initial + accrued - decayed - debited

(the hypothesis property pinned in ``tests/test_admission.py``).

Admission itself is a credit-weighted DRF/knapsack over the fleet's
two scarce resources — execution units and HBM isolation segments
(:func:`repro.core.allocator.credit_weighted_fill` ranks competing
asks). An ask's price is its *dominant share* (the DRF scalar:
``max(eus/total_eus, segs/total_segs)``) scaled by fleet pressure:
below ``free_level`` utilization admission is free (an idle fleet
admits everyone — the off-state a bit-identical cluster expects);
above it the price rises linearly to ``price_scale * dominant`` at
saturation. A low-credit ask is first *down-sized* (fewer EUs at a
cheaper price; the HBM ask is never shrunk — resident weights must
fit) and only then *deferred*, to be retried from the serving
session's re-admission queue as credit recovers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.allocator import credit_weighted_fill

__all__ = ["AdmissionAsk", "AdmissionDecision", "CreditAccount",
           "FleetState", "AdmissionController"]


# ----------------------------------------------------------------------
@dataclass(frozen=True)
class AdmissionAsk:
    """One tenant's resource ask at the admission gate. ``eus`` is
    execution units (ME+VE engines); ``hbm_segments`` is HBM isolation
    segments (0 when the ask carries no explicit HBM pin — the gate
    then prices EUs alone); ``slo_*`` are the tenant's DECLARED
    targets in milliseconds (they set the accrual rate, not a
    verdict); ``min_eus`` floors how far a down-size may shrink."""

    name: str
    eus: int
    hbm_segments: int = 0
    slo_ttft_ms: Optional[float] = None
    slo_tbt_ms: Optional[float] = None
    slo_p95_ms: Optional[float] = None
    min_eus: int = 2


@dataclass
class AdmissionDecision:
    """Gate verdict: ``status`` is ``"admit"`` (full ask),
    ``"downsize"`` (admitted at ``eus < ask.eus``) or ``"defer"``
    (queue and retry as credit recovers). ``price`` is the credit
    debited (0 for a deferral); ``reason`` says why a deferral
    happened (``"credit"`` vs ``"capacity"``)."""

    status: str
    eus: int = 0
    price: float = 0.0
    reason: str = ""


@dataclass
class FleetState:
    """Cluster-wide resource snapshot the gate prices against: free
    counts over HEALTHY cores, totals over the full fleet (a faulted
    core's capacity still counts toward the denominator — pressure
    rises when cores fail, exactly when admission should tighten)."""

    free_eus: int
    total_eus: int
    free_hbm_segments: int
    total_hbm_segments: int

    @property
    def pressure(self) -> float:
        """Fleet utilization, dominant-resource form: the max of the
        EU and HBM used fractions."""
        eu = 1.0 - self.free_eus / self.total_eus if self.total_eus else 0.0
        hbm = (1.0 - self.free_hbm_segments / self.total_hbm_segments
               if self.total_hbm_segments else 0.0)
        return max(eu, hbm)

    def dominant_share(self, eus: int, hbm_segments: int) -> float:
        """DRF's scalar: the ask's largest fraction of any one fleet
        resource."""
        eu = eus / self.total_eus if self.total_eus else 0.0
        hbm = (hbm_segments / self.total_hbm_segments
               if self.total_hbm_segments else 0.0)
        return max(eu, hbm)

    def fits(self, eus: int, hbm_segments: int) -> bool:
        return eus <= self.free_eus and hbm_segments <= self.free_hbm_segments


# ----------------------------------------------------------------------
@dataclass
class CreditAccount:
    """Per-tenant PREMA-style credit balance plus its conservation
    ledger (``accrued`` / ``decayed`` / ``debited`` are lifetime
    totals; ``decayed`` is signed — a negative balance decays toward
    zero too, so debt is forgiven at the same half-life credit ages).
    The ``*_seen`` cursors mark how much of the tenant's live
    :class:`~repro.core.simulator.TenantStats` series the controller
    has already converted into debits."""

    name: str
    rate: float                  # accrual, credit per simulated second
    tau_s: float                 # decay time constant (halflife/ln 2)
    credit: float = 0.0
    initial: float = 0.0
    accrued: float = 0.0
    decayed: float = 0.0
    debited: float = 0.0
    last_s: float = 0.0
    violations: int = 0          # SLO-sample + deadline-miss debits
    deferrals: int = 0           # times the gate said "defer"
    scaleups_denied: int = 0     # autoscale grows the gate refused
    ttft_seen: int = 0
    tbt_seen: int = 0
    misses_seen: int = 0

    def advance(self, now_s: float) -> None:
        """Roll the balance forward to ``now_s``: decay the opening
        balance, then add the interval's accrual (discrete PREMA
        update; order documented, deterministic)."""
        dt = now_s - self.last_s
        if dt <= 0.0:
            return
        d = self.credit * (1.0 - math.exp(-dt / self.tau_s))
        self.credit -= d
        self.decayed += d
        a = self.rate * dt
        self.credit += a
        self.accrued += a
        self.last_s = now_s

    def spend(self, amount: float) -> None:
        self.credit -= amount
        self.debited += amount

    def conserved(self, tol: float = 1e-6) -> bool:
        """The invariant every mutation preserves."""
        return abs(self.credit - (self.initial + self.accrued
                                  - self.decayed - self.debited)) <= tol


# ----------------------------------------------------------------------
class AdmissionController:
    """The fleet-scale credit gate. Stateless about the cluster — the
    serving session snapshots a :class:`FleetState` per decision — so
    it is directly unit-testable. All times are simulated SECONDS (the
    session's API domain); all SLO inputs are milliseconds."""

    def __init__(self, initial_credit: float = 1.0,
                 decay_halflife_s: float = 1.0,
                 base_rate: float = 0.1, slo_rate: float = 1.0,
                 violation_debit: float = 0.25,
                 price_scale: float = 4.0, free_level: float = 0.5,
                 min_eus: int = 2, charge_admission: bool = True):
        if decay_halflife_s <= 0:
            raise ValueError(
                f"decay_halflife_s must be > 0, got {decay_halflife_s}")
        if not 0.0 <= free_level < 1.0:
            raise ValueError(
                f"free_level must be in [0, 1), got {free_level}")
        self.initial_credit = float(initial_credit)
        self.tau_s = decay_halflife_s / math.log(2.0)
        self.base_rate = float(base_rate)
        self.slo_rate = float(slo_rate)
        self.violation_debit = float(violation_debit)
        self.price_scale = float(price_scale)
        self.free_level = float(free_level)
        self.min_eus = int(min_eus)
        self.charge_admission = bool(charge_admission)
        self.accounts: Dict[str, CreditAccount] = {}

    # ------------------------------------------------------------------
    def accrual_rate(self, ask: AdmissionAsk) -> float:
        """Credit/second from declared strictness: ``base_rate`` plus
        ``slo_rate`` per unit of summed ``1/slo_ms`` over the declared
        targets. No SLOs -> the base rate alone (a best-effort tenant
        accrues slowly and queues behind everyone under pressure)."""
        strict = sum(1.0 / s for s in (ask.slo_ttft_ms, ask.slo_tbt_ms,
                                       ask.slo_p95_ms)
                     if s is not None and s > 0)
        return self.base_rate + self.slo_rate * strict

    def touch(self, ask: AdmissionAsk, now_s: float) -> CreditAccount:
        """Get-or-create the tenant's account (idempotent; re-attach
        after failover must not reset a balance)."""
        acct = self.accounts.get(ask.name)
        if acct is None:
            acct = CreditAccount(name=ask.name, rate=self.accrual_rate(ask),
                                 tau_s=self.tau_s,
                                 credit=self.initial_credit,
                                 initial=self.initial_credit,
                                 last_s=now_s)
            self.accounts[ask.name] = acct
        return acct

    def balance(self, name: str, now_s: float) -> float:
        """The tenant's rolled-forward balance (0 for unknown names)."""
        acct = self.accounts.get(name)
        if acct is None:
            return 0.0
        acct.advance(now_s)
        return acct.credit

    def observe(self, name: str, now_s: float, violations: int) -> None:
        """Feed live violation signals (SLO-violating TTFT/TBT samples
        and deadline misses, counted by
        :func:`repro.core.policies.slo_violation_signal`) into the
        account as debits."""
        acct = self.accounts.get(name)
        if acct is None or violations <= 0:
            return
        acct.advance(now_s)
        acct.violations += violations
        acct.spend(self.violation_debit * violations)

    # ------------------------------------------------------------------
    def price(self, eus: int, hbm_segments: int,
              fleet: FleetState) -> float:
        """Credit price of an ask: dominant share x pressure slack.
        Free below ``free_level`` fleet utilization; rises linearly to
        ``price_scale * dominant_share`` at saturation."""
        slack = (fleet.pressure - self.free_level) / (1.0 - self.free_level)
        slack = min(max(slack, 0.0), 1.0)
        return self.price_scale * fleet.dominant_share(
            eus, hbm_segments) * slack

    def decide(self, ask: AdmissionAsk, now_s: float,
               fleet: FleetState) -> AdmissionDecision:
        """Gate one ask. Admit at full size when the balance covers
        the price and the fleet has the capacity; otherwise walk the
        EU ask down toward ``ask.min_eus`` (the HBM ask never shrinks
        — resident weights must fit) looking for a size that is both
        affordable and placeable; otherwise defer. Admission debits
        its price (``charge_admission=False`` turns the gate into a
        pure ranking, for A/B rows)."""
        acct = self.touch(ask, now_s)
        acct.advance(now_s)
        floor = max(min(ask.min_eus, ask.eus), self.min_eus)
        for eus in range(ask.eus, floor - 1, -1):
            if not fleet.fits(eus, ask.hbm_segments):
                continue
            p = self.price(eus, ask.hbm_segments, fleet)
            if acct.credit + 1e-12 < p:
                continue
            if self.charge_admission:
                acct.spend(p)
            status = "admit" if eus == ask.eus else "downsize"
            return AdmissionDecision(status=status, eus=eus, price=p)
        acct.deferrals += 1
        reason = ("capacity"
                  if not fleet.fits(floor, ask.hbm_segments) else "credit")
        return AdmissionDecision(status="defer", reason=reason)

    def approve_scaleup(self, name: str, extra_eus: int, now_s: float,
                        fleet: FleetState) -> bool:
        """Autoscale grows pass the same gate: the incremental EUs are
        priced like a fresh ask (no HBM delta — resizes keep the HBM
        pin) and debited from the tenant's balance. Unknown tenants
        are approved (the session opens an account for every attached
        tenant; this is only a guard)."""
        acct = self.accounts.get(name)
        if acct is None or extra_eus <= 0:
            return True
        acct.advance(now_s)
        p = self.price(extra_eus, 0, fleet)
        if acct.credit + 1e-12 < p:
            acct.scaleups_denied += 1
            return False
        if self.charge_admission:
            acct.spend(p)
        return True

    def rank(self, asks: Sequence[AdmissionAsk], now_s: float,
             fleet: FleetState) -> List[str]:
        """Credit-weighted drain order for the re-admission queue:
        delegate to the allocator's knapsack
        (:func:`~repro.core.allocator.credit_weighted_fill`) over the
        fleet's free EUs/segments, using each account's rolled-forward
        balance as the weight."""
        rows = [(a.name, self.balance(a.name, now_s)
                 if a.name in self.accounts else self.touch(a, now_s).credit,
                 a.eus, a.hbm_segments) for a in asks]
        return credit_weighted_fill(rows, fleet.free_eus,
                                    fleet.free_hbm_segments,
                                    fleet.total_eus,
                                    fleet.total_hbm_segments)
