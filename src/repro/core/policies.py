"""Pluggable vNPU scheduler policies (§III-E, §V-A).

The simulator in :mod:`repro.core.simulator` is a policy-agnostic
event loop; everything that decides *which* ready chunk runs on
*which* engine lives here. A policy is a class implementing
:class:`SchedulerPolicy` and registered under a name:

    from repro.core.policies import SchedulerPolicy, register_policy

    @register_policy("my_policy")
    class MyPolicy(SchedulerPolicy):
        spatial = False           # engines shared, not owned
        isa = "vliw"              # compile whole operators

        def schedule(self, sim, t):
            for rt in sim.active_tenants():
                ...sim.dispatch(chunk, engines, t)...

``Simulator(..., policy="my_policy")`` then resolves it through the
registry — no changes to ``repro.core`` required. The four paper
policies (``pmt`` / ``v10`` / ``neu10_nh`` / ``neu10``) are themselves
registered this way.

Policy API surface on the simulator (stable for third parties):

* ``sim.active_tenants()`` — live tenant runtimes, each with
  ``ready_me`` / ``ready_ve`` chunk queues, ``active_cycles`` fair-
  share counters, and ``spec.weight`` priorities.
* ``sim.mes`` / ``sim.ves`` — engine pools; an engine has ``.free``,
  ``.owner`` (tenant idx under spatial policies), ``.chunk``,
  ``.tenant``.
* ``sim.dispatch(chunk, engines, t, harvested=False)`` — start a
  chunk on one or more free engines.
* ``sim.preempt(engine, t, blocked_owner=None)`` — preempt the chunk
  on an engine (and VLIW siblings); remaining work returns to its
  tenant's ready queue with the context-switch penalty.
* ``sim.core`` / ``sim.fair_slice`` — hardware config and the
  fair-share imbalance threshold.
"""
from __future__ import annotations

import heapq
from abc import ABC, abstractmethod
from collections import deque
from typing import (TYPE_CHECKING, Callable, Deque, Dict, Optional, Tuple,
                    Type, Union)

from repro.core.compiler import (PIGGYBACK, PREFILL, ProgramCache,
                                 compile_neuisa, compile_request_plan,
                                 compile_vliw)
from repro.core.neuisa import ME, VE, FusedIssueGroup, form_fused_group

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.simulator import Simulator


class UnknownPolicyError(KeyError):
    """Raised when a policy name is not in the registry."""

    def __init__(self, name: str):
        self.name = name
        super().__init__(
            f"unknown scheduler policy {name!r}; "
            f"registered: {', '.join(available_policies())}")

    def __str__(self) -> str:  # KeyError quotes its arg; keep it readable
        return self.args[0]


_REGISTRY: Dict[str, Type["SchedulerPolicy"]] = {}


def register_policy(name: str) -> Callable[[Type["SchedulerPolicy"]],
                                           Type["SchedulerPolicy"]]:
    """Class decorator: make a :class:`SchedulerPolicy` resolvable by
    name from ``Simulator`` / ``NPUCluster`` / benchmarks."""

    def deco(cls: Type["SchedulerPolicy"]) -> Type["SchedulerPolicy"]:
        if not (isinstance(cls, type) and issubclass(cls, SchedulerPolicy)):
            raise TypeError(f"{cls!r} is not a SchedulerPolicy subclass")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_policy(name: str) -> Type["SchedulerPolicy"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownPolicyError(name) from None


def available_policies() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


PolicyLike = Union[str, "SchedulerPolicy", Type["SchedulerPolicy"]]


def resolve_policy(policy: PolicyLike) -> "SchedulerPolicy":
    """Accept a registry name, a policy class, or an instance; return
    a fresh (or the given) instance ready to bind to one simulator."""
    if isinstance(policy, str):
        return get_policy(policy)()
    if isinstance(policy, type) and issubclass(policy, SchedulerPolicy):
        return policy()
    if isinstance(policy, SchedulerPolicy):
        return policy
    raise TypeError(f"cannot resolve scheduler policy from {policy!r}")


# ----------------------------------------------------------------------
class SchedulerPolicy(ABC):
    """One scheduling discipline for collocated vNPU tenants.

    Class attributes declare the contract the rest of the stack reads:

    * ``spatial`` — engines carry per-tenant ownership (hardware
      isolation); also selects the ``"spatial"`` vNPU mapping in the
      control plane (``"temporal"`` otherwise).
    * ``isa`` — ``"neuisa"`` (μTOp groups, per-engine chunks) or
      ``"vliw"`` (whole operators); picks the compiler front-end.

    Instances are stateful and bound to ONE simulator at a time
    (``Simulator`` resolves names to fresh instances).
    """

    name: str = ""
    spatial: bool = False
    isa: str = "vliw"

    @property
    def mapping(self) -> str:
        """vNPU mapping scheme this policy implies (``"spatial"`` =
        engines owned per tenant, ``"temporal"`` = shared)."""
        return "spatial" if self.spatial else "temporal"

    @classmethod
    def compile_program(cls, trace, core):
        """Compile a :class:`WorkloadTrace` into the program form this
        policy schedules."""
        if cls.isa == "neuisa":
            return compile_neuisa(trace, core)
        return compile_vliw(trace, core)

    @classmethod
    def compile_plan(cls, plan, core, cache: Optional[ProgramCache] = None):
        """Compile a phase-structured
        :class:`~repro.npu.cost_model.RequestPlan` into per-phase
        programs, sharing ``cache`` so decode programs at each context
        bucket compile once per (model shape, policy ISA)."""
        return compile_request_plan(plan, core, isa=cls.isa, cache=cache)

    # ---------------- lifecycle hooks ----------------
    def on_attach(self, sim: "Simulator") -> None:
        """Called once when the simulator binds this policy."""

    def on_tenant_added(self, sim: "Simulator", rt) -> None:
        """Called after a tenant runtime joins (possibly mid-run)."""

    def on_tenant_removed(self, sim: "Simulator", rt) -> None:
        """Called after a tenant runtime is deregistered mid-run."""

    def on_request_migrated(self, sim: "Simulator", rt, req) -> None:
        """Called after a request migrated in from ANOTHER core's
        simulator (cross-core prefill->decode hand-off over the
        cluster fabric) and joined ``rt``'s continuous decode batch.
        Policies with per-tenant warm state can prime it here; the
        default is a no-op — the request is already queued and the
        dispatch pass that follows the hand-off event schedules it
        like any decode work."""

    # ---------------- the actual scheduler ----------------
    @abstractmethod
    def schedule(self, sim: "Simulator", t: float) -> None:
        """Dispatch ready chunks onto free engines at time ``t``.

        Policies may ALSO define the opt-in hook::

            def schedule_incremental(self, sim, t, dirty) -> None

        When present (and the simulator runs with
        ``fast_path=True, incremental=True``), the simulator calls it
        instead of ``schedule`` and ONLY when the dirty set is
        non-empty — ``dirty`` is the frozen set of tenant indices
        whose scheduling inputs changed since the last pass (-1 marks
        a global change). The hook must reach the same fixpoint a full
        ``schedule`` pass would; a policy that keeps enabling state
        outside the ready queues / engine pools must call
        ``sim.mark_dirty`` when that state changes, or its work will
        sit unscheduled until an unrelated event lands. Policies
        without the hook (``pmt``/``v10``) transparently fall back to
        a full ``schedule`` pass per event. See ``docs/architecture.md``
        ("Event engine") for the marking contract."""


# ----------------------------------------------------------------------
# Built-in policies — the paper's baselines and Neu10 itself, extracted
# verbatim from the former Simulator._schedule_* branches.
# ----------------------------------------------------------------------
def _ve_drain_first(c) -> bool:
    """VE ready-queue sort key: drains of ME groups run first (the
    operation scheduler's rule) — hoisted to module level so the hot
    schedule pass doesn't rebuild a closure per call."""
    return not c.from_me_group


def _harvest_order_me(r) -> tuple:
    """Harvest priority (ME pool): decode-phase tenants first, then
    least-served — hoisted like :func:`_ve_drain_first`."""
    return (not any(c.phase == "decode" for c in r.ready_me),
            r.active_cycles)


def _harvest_order_ve(r) -> tuple:
    """Harvest priority (VE pool) — see :func:`_harvest_order_me`."""
    return (not any(c.phase == "decode" for c in r.ready_ve),
            r.active_cycles)




class _SpatialPolicy(SchedulerPolicy):
    """Spatially-isolated vNPUs (dedicated engines per tenant).

    ``harvest``: idle engines may run co-tenant μTOps (reclaimed by
    preemption when the owner needs them back). ``fuse``: harvested
    decode VE μTOps landing under a co-tenant's in-flight prefill ME
    μTOps form a :class:`~repro.core.neuisa.FusedIssueGroup` — the
    paper's Fig. 6 ISA-level co-scheduling — and run to completion
    (the reclaim pass skips fused members, so neither side pays a
    preemption drain for the shared window). Piggybacked iterations
    interact naturally with both mechanisms: their ME μTOps (phase
    ``"piggyback"``) anchor fused groups exactly like prefill MEs —
    the fused program's own window is prefill-dominated — while
    reclaim treats non-fused piggyback μTOps like any other harvested
    work (preemptible with the standard drain).

    ``schedule`` has two result-identical implementations selected by
    ``Simulator.fast_path``: the reference pass (kept for the A/B
    equality + speedup proof in ``fig25_scaling``) and a tightened
    pass that buckets free engines per owner once per pool and skips
    empty ready queues."""

    spatial = True
    isa = "neuisa"
    harvest = False
    fuse = False

    def __init__(self) -> None:
        # the most recent fused issue groups formed, newest last —
        # observability for tests/operators (who anchored, who rode):
        # inspect via ``sim.policy_obj.recent_fused``
        self.recent_fused: Deque[FusedIssueGroup] = deque(maxlen=64)

    def schedule(self, sim: "Simulator", t: float) -> None:
        if getattr(sim, "fast_path", False):
            self._schedule_fast(sim, t)
        else:
            self._schedule_ref(sim, t)

    def _schedule_ref(self, sim: "Simulator", t: float) -> None:
        tenants = sim.active_tenants()
        # 1) owners dispatch on their own engines (MEs then VEs)
        for pool, ready_attr in ((sim.mes, "ready_me"), (sim.ves, "ready_ve")):
            for rt in tenants:
                ready = getattr(rt, ready_attr)
                if ready_attr == "ready_ve":
                    # operation scheduler: prioritize drains of ME groups
                    ready.sort(key=lambda c: not c.from_me_group)
                own_free = [e for e in pool
                            if e.owner == rt.idx and e.free]
                while own_free and ready:
                    sim.dispatch(ready.pop(0), [own_free.pop(0)], t)
                # 2) reclaim: preempt harvested μTOps on my engines.
                # Engines drain in PARALLEL, so the owner is wall-
                # blocked for ONE ctx window per reclaim pass (what
                # Table III measures), however many engines it takes
                # back. Fused issue-group members are exempt: they
                # were co-issued INTO the owner's prefill window and
                # complete with it (Fig. 6).
                if self.harvest and ready:
                    reclaimed = 0
                    for e in pool:
                        if reclaimed >= len(ready):
                            break
                        if (e.owner == rt.idx and not e.free
                                and e.chunk is not None
                                and e.tenant != rt.idx):
                            if e.chunk.fused:
                                continue
                            sim.preempt(e, t)
                            reclaimed += 1
                    if reclaimed:
                        ctx = float(sim.core.ctx_switch_cycles
                                    if pool is sim.mes else 32)
                        rt.stats.reclaim_blocked += ctx
        if not self.harvest:
            return
        # 3) harvest: leftover ready chunks take others' idle engines.
        for pool, ready_attr in ((sim.mes, "ready_me"), (sim.ves, "ready_ve")):
            # only engines whose owner has no pending demand are up for
            # harvest (§III-E scheduling policy). Phase-aware ordering:
            # decode μTOps (tiny, latency-critical) harvest first, so a
            # decoding tenant interleaves into the VE-idle window under
            # a co-located tenant's prefill (Fig. 2/6); ties fall back
            # to the fair-share counter. Single-phase tenants all rank
            # equal on the first key — seed ordering is unchanged.
            def _order(r):
                has_decode = any(c.phase == "decode"
                                 for c in getattr(r, ready_attr))
                return (not has_decode, r.active_cycles)

            for rt in sorted(tenants, key=_order):
                ready = getattr(rt, ready_attr)
                if not ready:
                    continue
                for e in pool:
                    if not ready:
                        break
                    if not e.free or e.owner == rt.idx:
                        continue
                    owner = (sim.tenants[e.owner]
                             if e.owner is not None else None)
                    owner_ready = getattr(owner, ready_attr) if owner else []
                    if owner_ready:
                        continue  # owner will use it this round
                    chunk = ready.pop(0)
                    if (self.fuse and pool is sim.ves and owner is not None
                            and chunk.phase == "decode"):
                        self._try_fuse(sim, chunk, e.owner, rt)
                    sim.dispatch(chunk, [e], t, harvested=True)

    def _schedule_fast(self, sim: "Simulator", t: float) -> None:
        """Tightened schedule pass — decision-for-decision identical
        to :meth:`_schedule_ref` (dispatch order, reclaim victims,
        harvest order), with the per-tenant engine scans replaced by
        one free-engine bucketing per pool (ownership is disjoint, so
        dispatches never consume another owner's bucket) and empty
        ready queues skipped outright."""
        tenants = sim.active_tenants()
        harvest = self.harvest
        # `_dispatch` is aliased to `dispatch` at class-definition
        # time, so comparing the two detects overrides made any way —
        # instance patch (spies), subclass method, or class-level
        # monkeypatch — without caching state that a patch could
        # poison. Overridden dispatches route through the documented
        # API so the observation point keeps seeing every chunk.
        if ("dispatch" in sim.__dict__
                or type(sim).dispatch is not type(sim)._dispatch):
            def dispatch(c, e, t_, harvested=False):
                sim.dispatch(c, [e], t_, harvested=harvested)
        else:
            dispatch = sim._dispatch1
        # 1) owners dispatch on their own engines (MEs then VEs);
        # 2) reclaim harvested μTOps squatting on needed engines.
        # A pool sub-pass only runs when some tenant has ready work of
        # that kind — with none, no dispatch NOR reclaim can happen,
        # and (since only reclaim preemption refills ready queues
        # mid-pass) none can appear either. Ready queues are stable
        # list objects (mutated in place, never reassigned), so the
        # (tenant, queue) pairing is taken once per sub-pass.
        for is_ve, pool in ((False, sim.mes), (True, sim.ves)):
            work = [(rt, rt.ready_ve if is_ve else rt.ready_me)
                    for rt in tenants]
            if not any(ready for _, ready in work):
                continue
            free_by_owner: Dict = {}
            for e in pool:
                if e.token < 0:
                    free_by_owner.setdefault(e.owner, []).append(e)
            for rt, ready in work:
                if not ready:
                    continue
                if is_ve and len(ready) > 1:
                    ready.sort(key=_ve_drain_first)
                own_free = free_by_owner.get(rt.idx)
                while own_free and ready:
                    dispatch(ready.pop(0), own_free.pop(0), t)
                # reclaim scan only when this owner actually has
                # foreign chunks squatting on its engines (the
                # simulator counts them incrementally) — with none the
                # scan is a provable no-op
                if harvest and ready and sim._squat.get(rt.idx):
                    reclaimed = 0
                    for e in pool:
                        if reclaimed >= len(ready):
                            break
                        if (e.owner == rt.idx and e.token >= 0
                                and e.chunk is not None
                                and e.tenant != rt.idx):
                            if e.chunk.fused:
                                continue
                            sim.preempt(e, t)
                            reclaimed += 1
                    if reclaimed:
                        ctx = float(sim.core.ctx_switch_cycles
                                    if pool is sim.mes else 32)
                        rt.stats.reclaim_blocked += ctx
        if not harvest:
            return
        # 3) harvest: leftover ready chunks take others' idle engines
        # (same phase-aware decode-first order as the reference pass).
        # Harvest only dispatches — no queue refills — so the src and
        # free-engine snapshots taken here stay exhaustive; the token
        # re-check covers engines consumed within this section.
        for is_ve, pool in ((False, sim.mes), (True, sim.ves)):
            src = [rt for rt in tenants
                   if (rt.ready_ve if is_ve else rt.ready_me)]
            if not src:
                continue
            free_list = [e for e in pool if e.token < 0]
            if not free_list:
                continue

            def _order(r):
                has_decode = any(c.phase == "decode" for c in
                                 (r.ready_ve if is_ve else r.ready_me))
                return (not has_decode, r.active_cycles)

            for rt in sorted(src, key=_order):
                ready = rt.ready_ve if is_ve else rt.ready_me
                for e in free_list:
                    if not ready:
                        break
                    if e.token >= 0 or e.owner == rt.idx:
                        continue
                    owner = (sim.tenants[e.owner]
                             if e.owner is not None else None)
                    if owner is not None and (owner.ready_ve if is_ve
                                              else owner.ready_me):
                        continue  # owner will use it this round
                    chunk = ready.pop(0)
                    if (self.fuse and is_ve and owner is not None
                            and chunk.phase == "decode"):
                        self._try_fuse(sim, chunk, e.owner, rt)
                    dispatch(chunk, e, t, harvested=True)

    def schedule_incremental(self, sim: "Simulator", t: float,
                             dirty) -> None:
        """Dirty-set schedule pass (the incremental core's opt-in
        hook): decision-for-decision identical to
        :meth:`_schedule_fast` — the simulator already guarantees it
        only runs when ``dirty`` is non-empty, i.e. something changed
        a scheduling input since the last pass. On top of that gate it
        (a) consumes the simulator's maintained per-owner free-engine
        index instead of re-bucketing each pool, (b) inlines the
        single-engine dispatch body (token issue, duration, heap
        push), and (c) dispatches runs of identical compute-only
        sibling chunks (a VE μTOp's slot chunks) as one *cohort*
        sharing a single completion event — same end time, one heap
        entry, per-chunk accounting replayed in engine order at
        completion, and heap tie-order unchanged because the merged
        entries' sequence numbers were consecutive. Cohort members run
        on the owner's OWN engines, which this policy never preempts,
        so the shared token is never partially invalidated.
        ``dirty``'s content is not needed for correctness here — every
        enabling transition for this policy lives in the ready queues
        and engine pools, which the pass re-reads — but one re-mark
        is: reclaim is bounded per pass (one ctx window per owner), so
        an owner still squatted-on with leftover ready work re-marks
        itself to keep the next pass coming. When ``dispatch`` is
        overridden (spies/subclasses), the whole pass routes through
        :meth:`_schedule_fast` so the documented API keeps seeing
        every chunk."""
        if ("dispatch" in sim.__dict__
                or type(sim).dispatch is not type(sim)._dispatch):
            self._schedule_fast(sim, t)
            return
        act = sim._act
        harvest = self.harvest
        heap = sim._heap
        push = heapq.heappush
        tok = sim._tok.__next__
        seq = sim._seq.__next__
        squat = sim._squat
        duration = sim._duration
        bw_register = sim._bw_register
        bpc = sim._bpc
        mes, ves = sim.mes, sim.ves
        left_me = left_ve = False   # pool has leftover ready work
                                    # after owner dispatch -> harvest
        # 1) owner dispatch + 2) reclaim, MEs then VEs (the fast
        # pass's structure; ready queues are stable list objects read
        # live, exactly like the fast pass's work-list snapshot)
        for is_ve in (False, True):
            if is_ve:
                free_own, kind, nd = sim._free_ve_own, VE, 0
            else:
                free_own, kind, nd = sim._free_me_own, ME, 0
            for rt in act:
                ready = rt.ready_ve if is_ve else rt.ready_me
                if not ready:
                    continue
                if is_ve and len(ready) > 1:
                    # operation scheduler: drains of ME groups first
                    ready.sort(key=_ve_drain_first)
                own = free_own.get(rt.idx)
                if own:
                    is_neu = rt.is_neuisa
                    while own and ready:
                        c = ready.pop(0)
                        e = own[0]
                        del own[0]
                        nd += 1
                        c.n_dispatched = 1
                        token = tok()
                        hbm = c.hbm_bytes
                        # a non-contender μTOp (compute-bound: would
                        # not pass _bw_register's test, same float
                        # expression) has a pressure-stable duration —
                        # identical consecutive siblings can form a
                        # cohort around one completion event
                        if is_neu and (hbm <= 0.0
                                       or hbm / bpc < c.cycles):
                            if hbm <= 0.0:
                                end = t + c.cycles + c.penalty
                            else:
                                end = t + duration(c, 1)
                            e.token = token
                            e.chunk = c
                            e.tenant = c.tenant
                            e.start = t
                            e.end = end
                            e.harvested = False
                            n = 1
                            while own and ready:
                                c2 = ready[0]
                                if (c2.cycles != c.cycles
                                        or c2.penalty != c.penalty
                                        or c2.hbm_bytes != hbm):
                                    break
                                del ready[0]
                                e2 = own[0]
                                del own[0]
                                nd += 1
                                c2.n_dispatched = 1
                                c2.cohort = 1   # member marker
                                e2.token = token
                                e2.chunk = c2
                                e2.tenant = c2.tenant
                                e2.start = t
                                e2.end = end
                                e2.harvested = False
                                n += 1
                            if n > 1:
                                c.cohort = n
                            push(heap, (end, seq(), kind, e.eid, token))
                        else:
                            dur = duration(c, 1)
                            bw_register(c)
                            e.token = token
                            e.chunk = c
                            e.tenant = c.tenant
                            e.start = t
                            end = t + dur
                            e.end = end
                            e.harvested = False
                            push(heap, (end, seq(), kind, e.eid, token))
                if harvest and ready and squat.get(rt.idx):
                    pool = ves if is_ve else mes
                    reclaimed = 0
                    for e in pool:
                        if reclaimed >= len(ready):
                            break
                        if (e.owner == rt.idx and e.token >= 0
                                and e.chunk is not None
                                and e.tenant != rt.idx):
                            if e.chunk.fused:
                                continue
                            sim.preempt(e, t)
                            reclaimed += 1
                    if reclaimed:
                        ctx = float(sim.core.ctx_switch_cycles
                                    if not is_ve else 32)
                        rt.stats.reclaim_blocked += ctx
                        # the preempted remainder went back on the
                        # VICTIM's ready queue (this pool): harvest
                        # must still look at it
                        if is_ve:
                            left_ve = True
                        else:
                            left_me = True
                    # reclaim is bounded per pass: if this owner is
                    # still squatted-on with work left, the full pass
                    # would reclaim again next event — keep that event
                    # coming even if nothing else marks
                    if ready and squat.get(rt.idx):
                        sim._dirty.add(rt.idx)
                if ready:
                    # leftover ready work (or a reclaim preemption put
                    # work back on a queue of this pool): the harvest
                    # section below must look at this pool
                    if is_ve:
                        left_ve = True
                    else:
                        left_me = True
            if is_ve:
                sim._nfree_ve -= nd
            else:
                sim._nfree_me -= nd
        if not harvest:
            return
        # 3) harvest — identical structure and order to _schedule_fast
        # (the leftover flags + maintained free counters make the
        # common nothing-to-harvest case two boolean checks per pool)
        for is_ve in (False, True):
            if not (left_ve if is_ve else left_me):
                continue
            if not (sim._nfree_ve if is_ve else sim._nfree_me):
                continue
            src = [rt for rt in act
                   if (rt.ready_ve if is_ve else rt.ready_me)]
            if not src:
                continue
            pool = ves if is_ve else mes
            free_list = [e for e in pool if e.token < 0]
            kind = VE if is_ve else ME
            if len(src) > 1:
                src.sort(key=_harvest_order_ve if is_ve
                         else _harvest_order_me)
            for rt in src:
                ready = rt.ready_ve if is_ve else rt.ready_me
                for e in free_list:
                    if not ready:
                        break
                    if e.token >= 0 or e.owner == rt.idx:
                        continue
                    ow = e.owner
                    owner = sim.tenants[ow] if ow is not None else None
                    if owner is not None and (owner.ready_ve if is_ve
                                              else owner.ready_me):
                        continue  # owner will use it this round
                    chunk = ready.pop(0)
                    if (self.fuse and is_ve and owner is not None
                            and chunk.phase == "decode"):
                        self._try_fuse(sim, chunk, ow, rt)
                    sim._free_idx_remove(e)
                    if chunk.hbm_bytes <= 0.0 and rt.is_neuisa:
                        dur = chunk.cycles + chunk.penalty
                    else:
                        dur = duration(chunk, 1)
                        bw_register(chunk)
                    chunk.n_dispatched = 1
                    token = tok()
                    e.token = token
                    e.chunk = chunk
                    e.tenant = chunk.tenant
                    e.start = t
                    end = t + dur
                    e.end = end
                    e.harvested = True
                    if ow is not None:
                        squat[ow] = squat.get(ow, 0) + 1
                    push(heap, (end, seq(), kind, e.eid, token))

    def _try_fuse(self, sim: "Simulator", chunk, owner_idx: int, rt) -> None:
        """Fuse a harvested decode VE μTOp into the engine owner's
        in-flight prefill ME group, if it has one (Fig. 6): the μTOp
        becomes a fused issue-group member and is exempt from reclaim
        until it completes. Piggybacked ME μTOps anchor too — a
        budgeted iteration's ME window is its prefill slice, so a
        co-tenant's decode VE μTOp rides it exactly like a plain
        prefill window."""
        anchor = next(
            (m.chunk for m in sim.mes
             if m.chunk is not None and m.tenant == owner_idx
             and m.chunk.kind == ME
             and m.chunk.phase in (PREFILL, PIGGYBACK)),
            None)
        if anchor is None:
            return
        group = form_fused_group(
            owner_idx, anchor.op_name,
            [(chunk.tenant, chunk.op_name, chunk.phase)], max_ve=1)
        if group.fused:
            chunk.fused = True
            rt.stats.fused_groups += 1
            self.recent_fused.append(group)


@register_policy("neu10_nh")
class Neu10NoHarvestPolicy(_SpatialPolicy):
    """Spatial-isolated vNPUs, no harvesting (MIG-like static
    partition)."""

    harvest = False


@register_policy("neu10")
class Neu10Policy(_SpatialPolicy):
    """Spatial-isolated + dynamic μTOp scheduling with ME/VE
    harvesting, reclaim preemption, and fused prefill+decode issue
    groups (the paper's system; fusion is the Fig. 6 co-scheduling of
    a prefill chunk's MU-heavy μTOps with co-tenant decode VE
    μTOps)."""

    harvest = True
    fuse = True


@register_policy("v10")
class V10Policy(SchedulerPolicy):
    """V10: operator-granular temporal sharing; an ME operator
    occupies ALL MEs (VLIW control-flow coupling); VE-only operators
    from other vNPUs may run concurrently; priority-based
    preemption."""

    spatial = False
    isa = "vliw"

    def schedule(self, sim: "Simulator", t: float) -> None:
        order = sorted(sim.active_tenants(),
                       key=lambda r: r.active_cycles / r.spec.weight)
        free_mes = [e for e in sim.mes if e.free]
        all_mes_free = len(free_mes) == len(sim.mes)
        for rt in order:
            # ME op: needs the WHOLE ME array (VLIW coupling)
            if rt.ready_me:
                if all_mes_free:
                    chunk = rt.ready_me.pop(0)
                    sim.dispatch(chunk, list(sim.mes), t)
                    all_mes_free = False
                else:
                    # priority-based preemption of the running op
                    running = next((e for e in sim.mes if not e.free
                                    and e.chunk is not None), None)
                    if running is not None and running.tenant >= 0:
                        holder = sim.tenants[running.tenant]
                        deficit = (holder.active_cycles / holder.spec.weight
                                   - rt.active_cycles / rt.spec.weight)
                        if deficit > sim.fair_slice:
                            sim.preempt(running, t)
            # VE-only ops run on the free VE pool concurrently
            if rt.ready_ve:
                free_ves = [e for e in sim.ves if e.free]
                if free_ves:
                    chunk = rt.ready_ve.pop(0)
                    sim.dispatch(chunk, free_ves, t)
        # note: dispatching a VE op across k free VEs divides its span
        # (VLIW VE ops address all VE slots).


@register_policy("pmt")
class PMTPolicy(SchedulerPolicy):
    """PREMA-style whole-core temporal sharing; preemptive fair
    scheduling at operator boundaries."""

    spatial = False
    isa = "vliw"

    def __init__(self) -> None:
        self._last: int = -1  # tenant currently holding the core

    def schedule(self, sim: "Simulator", t: float) -> None:
        # whole core belongs to one tenant at a time (PREMA-style
        # task-level sharing): the core changes hands at operator
        # boundaries only when the fair-share deficit is large —
        # switches are coarse and expensive.
        busy = any(not e.free for e in sim.mes + sim.ves)
        if busy:
            return
        order = sorted(
            (rt for rt in sim.active_tenants()
             if rt.ready_me or rt.ready_ve),
            key=lambda r: r.active_cycles / r.spec.weight)
        if not order:
            return
        rt = order[0]
        if self._last >= 0 and self._last != rt.idx:
            holder = sim.tenants[self._last]
            if holder.ready_me or holder.ready_ve:
                deficit = (holder.active_cycles / holder.spec.weight
                           - rt.active_cycles / rt.spec.weight)
                if deficit < 4 * sim.fair_slice:
                    rt = holder  # keep the core; not worth a switch yet
        # whole-core context switch cost when the core changes hands
        penalty = 0.0
        if self._last not in (-1, rt.idx):
            penalty = float(sim.core.ctx_switch_cycles * sim.core.n_me)
        self._last = rt.idx
        if rt.ready_me:
            chunk = rt.ready_me.pop(0)
            chunk.penalty += penalty
            sim.dispatch(chunk, list(sim.mes), t)
        elif rt.ready_ve:
            chunk = rt.ready_ve.pop(0)
            chunk.penalty += penalty
            sim.dispatch(chunk, list(sim.ves), t)

    def on_tenant_removed(self, sim: "Simulator", rt) -> None:
        if self._last == rt.idx:
            self._last = -1


# ----------------------------------------------------------------------
# KV-pressure eviction victim selection (PREMA-style, arXiv 1909.04548)
# ----------------------------------------------------------------------
def estimated_remaining_cycles(plan, req, context: int) -> float:
    """PREMA-style per-request service estimate: decode tokens
    remaining x the per-step cost of the request's CURRENT context
    bucket (``CompiledPhase.est_cycles``, the ideal-parallel lower
    bound of the bucket's decode trace). Requests still mid-prefill
    count their full generation. Units: cycles."""
    steps = max(req.gen_len - max(req.tokens_done, 1), 0) + 1
    if not plan.has_decode:
        return float(steps)
    return steps * max(plan.decode_phase_for(context).est_cycles, 1.0)


def pick_eviction_victim(requests, plan, context_of, shared_refs_of=None):
    """Choose which in-flight decoding request loses its KV segments
    when a tenant's continuous batch outgrows its HBM budget: the one
    with the LARGEST estimated remaining service (it would occupy the
    segments longest, so parking it frees the most byte-time for the
    short requests whose TBT the SLO watches — PREMA's
    estimate-driven preemption applied to memory instead of compute).
    Deterministic: ties break toward the latest arrival, then the
    candidate list order.

    ``shared_refs_of`` (``req -> int``, optional) marks requests
    holding a shared KV prefix entry: a holder whose entry is still
    referenced by OTHER live requests (refs > 1) is picked LAST —
    evicting it cannot free the shared segments (the refcount keeps
    them resident), so it frees the least bytes per eviction. With the
    callback omitted the ordering is identical to the pre-sharing
    picker."""
    best, best_key = None, None
    for i, req in enumerate(requests):
        key = (estimated_remaining_cycles(plan, req, context_of(req)),
               req.arrival, i)
        if shared_refs_of is not None:
            key = (0 if shared_refs_of(req) > 1 else 1,) + key
        if best_key is None or key > best_key:
            best, best_key = req, key
    return best


# ----------------------------------------------------------------------
# live SLO violation signals (credit-based admission, PREMA tokens)
# ----------------------------------------------------------------------
def slo_violation_signal(stats, slo_ttft_cycles=None, slo_tbt_cycles=None,
                         ttft_seen: int = 0, tbt_seen: int = 0,
                         ) -> Tuple[int, int, int]:
    """Count NEW latency samples violating the tenant's declared SLOs
    — the live signal the credit admission controller debits accounts
    with (:mod:`repro.core.admission`). Scans the tenant's
    ``TenantStats`` TTFT / TBT series past the caller's cursors (the
    controller stores them per account, so every sample is converted
    into at most one debit) against the SLOs in CYCLES (the stats
    domain; the serving layer converts its ms SLOs once). Returns
    ``(violations, new_ttft_cursor, new_tbt_cursor)``; a None SLO
    skips its series entirely."""
    v = 0
    if slo_ttft_cycles is not None:
        v += sum(1 for x in stats.ttft[ttft_seen:] if x > slo_ttft_cycles)
        ttft_seen = len(stats.ttft)
    if slo_tbt_cycles is not None:
        v += sum(1 for x in stats.tbt[tbt_seen:] if x > slo_tbt_cycles)
        tbt_seen = len(stats.tbt)
    return v, ttft_seen, tbt_seen
