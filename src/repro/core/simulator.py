"""Event-driven NPU simulator (§III-G) with the μTOp / operation
schedulers (§III-E) and the paper's baselines (§V-A).

Granularity: μTOp events with cycle-accurate durations. An ME μTOp
occupies one ME; a VE μTOp is split into n_y slot-chunks served by
the operation scheduler. HBM bandwidth is shared between tenants with
in-flight memory-demanding μTOps (fair sharing, §III-B). The ME
preemption penalty is the paper's 256 cycles (drain partial sums +
weights of a 128x128 array).

Policies
--------
* ``pmt``      — PREMA-style whole-core temporal sharing; preemptive
                 fair scheduling at operator boundaries.
* ``v10``      — V10: operator-granular temporal sharing; an ME
                 operator occupies ALL MEs (VLIW control-flow
                 coupling); VE-only operators from other vNPUs may run
                 concurrently; priority-based preemption.
* ``neu10_nh`` — spatial-isolated vNPUs, no harvesting (MIG-like).
* ``neu10``    — spatial-isolated + dynamic μTOp scheduling with
                 ME/VE harvesting and reclaim preemption.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.neuisa import ME, VE, MuTOpGroup, NeuISAProgram, VLIWProgram
from repro.core.vnpu import VNPU
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig

EPS = 1e-9


# ----------------------------------------------------------------------
@dataclass
class Chunk:
    """A schedulable unit: one ME μTOp, one VE μTOp slot-chunk, or a
    whole VLIW operator (multi-engine)."""

    tenant: int
    kind: str                    # "me" | "ve"
    cycles: float                # per-engine work
    hbm_bytes: float
    op_name: str = ""
    n_engines: int = 1           # VLIW ops seize several engines
    penalty: float = 0.0         # context-switch cycles to add (resume)
    group_key: int = -1          # group (NeuISA) or op (VLIW) index
    from_me_group: bool = False  # VE chunk draining an ME group


@dataclass
class TenantSpec:
    program: Union[NeuISAProgram, VLIWProgram]
    vnpu: VNPU
    n_requests: int = 8
    weight: float = 1.0          # fair-share priority


@dataclass
class TenantStats:
    name: str
    latencies: List[float] = field(default_factory=list)
    requests_done: int = 0
    me_work: float = 0.0
    ve_work: float = 0.0
    harvested_me_work: float = 0.0   # work done on non-owned MEs
    harvested_ve_work: float = 0.0
    reclaim_blocked: float = 0.0     # Table III: stall due to being
                                     # harvested (reclaim ctx windows)
    preemptions: int = 0

    def p95(self) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        i = min(len(xs) - 1, max(0, math.ceil(0.95 * len(xs)) - 1))
        return xs[i]

    def mean(self) -> float:
        return sum(self.latencies) / len(self.latencies) if self.latencies else 0.0


@dataclass
class SimResult:
    policy: str
    makespan: float              # cycles until every tenant hit N reqs
    tenants: List[TenantStats]
    n_me: int
    n_ve: int
    freq_hz: float

    def me_utilization(self) -> float:
        return sum(t.me_work for t in self.tenants) / (self.n_me * self.makespan)

    def ve_utilization(self) -> float:
        return sum(t.ve_work for t in self.tenants) / (self.n_ve * self.makespan)

    def throughput(self, idx: int) -> float:
        """requests/sec for tenant idx over the makespan."""
        t = self.tenants[idx]
        return t.requests_done / (self.makespan / self.freq_hz)

    def total_throughput(self) -> float:
        return sum(self.throughput(i) for i in range(len(self.tenants)))


# ----------------------------------------------------------------------
class _Engine:
    __slots__ = ("kind", "eid", "owner", "token", "chunk", "tenant",
                 "start", "end", "harvested")

    def __init__(self, kind: str, eid: int, owner: Optional[int]):
        self.kind = kind
        self.eid = eid
        self.owner = owner       # tenant idx (spatial) or None
        self.token: int = -1     # generation counter; -1 = free
        self.chunk: Optional[Chunk] = None
        self.tenant: int = -1
        self.start = 0.0
        self.end = 0.0
        self.harvested = False

    @property
    def free(self) -> bool:
        return self.token < 0


class _TenantRT:
    """Runtime cursor over a tenant's program (closed-loop requests)."""

    def __init__(self, idx: int, spec: TenantSpec, core: NPUCoreConfig):
        self.idx = idx
        self.spec = spec
        self.core = core
        self.is_neuisa = isinstance(spec.program, NeuISAProgram)
        self.me_ids = set(spec.vnpu.me_ids)
        self.ve_ids = set(spec.vnpu.ve_ids)
        self.stats = TenantStats(name=spec.program.name)
        self.active_cycles = 0.0          # fair-share bookkeeping
        self.req_start = 0.0
        self.cursor = -1                  # group / op index
        self.outstanding = 0              # chunks of current step in flight
        self.ready_me: List[Chunk] = []
        self.ready_ve: List[Chunk] = []
        self.loop_remaining: Dict[int, int] = {}
        self.done = False                 # reached n_requests (keeps running)
        self.finished_at = math.inf

    # ---------------- program stepping ----------------
    def start_request(self, t: float) -> None:
        self.req_start = t
        self.cursor = -1
        self.loop_remaining = {}
        self._advance(t)

    def _advance(self, t: float) -> None:
        """Move to the next non-empty group/op; refill ready queues."""
        prog = self.spec.program
        while True:
            nxt = self._next_cursor()
            if nxt is None:
                # request complete
                self.stats.latencies.append(t - self.req_start)
                self.stats.requests_done += 1
                if (self.stats.requests_done >= self.spec.n_requests
                        and not self.done):
                    self.done = True
                    self.finished_at = t
                self.start_request(t)
                return
            self.cursor = nxt
            if self._fill_ready():
                return

    def _next_cursor(self) -> Optional[int]:
        prog = self.spec.program
        if self.is_neuisa:
            n = len(prog.groups)
            if self.cursor < 0:
                return 0 if n else None
            # loop control (uTop.nextGroup)
            g = prog.groups[self.cursor]
            tgt = next((u.next_group for u in g.all_utops()
                        if u.next_group is not None), None)
            if tgt is not None:
                trips = self.loop_remaining.get(
                    self.cursor, prog.loop_trips.get(self.cursor, 1))
                if trips > 1:
                    self.loop_remaining[self.cursor] = trips - 1
                    return tgt
            nxt = self.cursor + 1
            return nxt if nxt < n else None
        n = len(prog.ops)
        nxt = self.cursor + 1
        return nxt if nxt < n else None

    def _fill_ready(self) -> bool:
        """Expand current group/op into ready chunks. False if empty."""
        prog = self.spec.program
        made = 0
        if self.is_neuisa:
            g: MuTOpGroup = prog.groups[self.cursor]
            for u in g.me_utops:
                if u.cycles > EPS or u.hbm_bytes > EPS:
                    self.ready_me.append(Chunk(
                        self.idx, ME, u.cycles, u.hbm_bytes, u.op_name,
                        group_key=self.cursor))
                    made += 1
            if g.ve_utop is not None and (
                    g.ve_utop.cycles > EPS or g.ve_utop.hbm_bytes > EPS):
                n_y = prog.n_y
                for _ in range(n_y):
                    self.ready_ve.append(Chunk(
                        self.idx, VE, g.ve_utop.cycles / n_y,
                        g.ve_utop.hbm_bytes / n_y, g.ve_utop.op_name,
                        group_key=self.cursor,
                        from_me_group=bool(g.me_utops)))
                    made += 1
        else:
            op = prog.ops[self.cursor]
            if op.n_me_static > 0 and (op.me_cycles > EPS or op.hbm_bytes > EPS):
                self.ready_me.append(Chunk(
                    self.idx, ME, op.me_cycles, op.hbm_bytes, op.op_name,
                    n_engines=op.n_me_static, group_key=self.cursor))
                made += 1
                # drain VE work is folded into the op span (pipelined)
            elif op.ve_cycles > EPS or op.hbm_bytes > EPS:
                self.ready_ve.append(Chunk(
                    self.idx, VE, op.ve_cycles, op.hbm_bytes, op.op_name,
                    group_key=self.cursor))
                made += 1
        self.outstanding = made
        return made > 0

    def chunk_done(self, t: float) -> None:
        self.outstanding -= 1
        if self.outstanding <= 0 and not self.ready_me and not self.ready_ve:
            self._advance(t)


# ----------------------------------------------------------------------
class Simulator:
    """Deterministic event-driven simulator for one physical NPU core
    shared by collocated vNPU tenants."""

    def __init__(
        self,
        tenants: Sequence[TenantSpec],
        policy: str = "neu10",
        core: NPUCoreConfig = DEFAULT_CORE,
        hbm_scale: float = 1.0,
        fair_slice: float = 50_000.0,   # cycles of service imbalance
        max_events: int = 20_000_000,
    ):
        assert policy in ("pmt", "v10", "neu10_nh", "neu10"), policy
        self.policy = policy
        self.core = core
        self.hbm_scale = hbm_scale
        self.fair_slice = fair_slice
        self.max_events = max_events
        self.tenants = [_TenantRT(i, s, core) for i, s in enumerate(tenants)]
        spatial = policy in ("neu10", "neu10_nh")
        self.mes = [
            _Engine(ME, i, self._owner_of(ME, i) if spatial else None)
            for i in range(core.n_me)
        ]
        self.ves = [
            _Engine(VE, i, self._owner_of(VE, i) if spatial else None)
            for i in range(core.n_ve)
        ]
        self._heap: List[Tuple[float, int, str, int, int]] = []
        self._seq = itertools.count()
        self._tok = itertools.count()

    def _owner_of(self, kind: str, eid: int) -> Optional[int]:
        for t in self.tenants:
            ids = t.me_ids if kind == ME else t.ve_ids
            if eid in ids:
                return t.idx
        return None

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        t = 0.0
        for rt in self.tenants:
            rt.start_request(0.0)
        self._schedule(0.0)
        events = 0
        while self._heap:
            events += 1
            if events > self.max_events:
                raise RuntimeError("simulator exceeded max_events")
            t, _, kind, eid, token = heapq.heappop(self._heap)
            eng = (self.mes if kind == ME else self.ves)[eid]
            if eng.token != token:
                continue  # stale (preempted)
            self._complete(eng, t)
            # batch any same-time completions before rescheduling
            while self._heap and self._heap[0][0] <= t + EPS:
                t2, _, k2, e2, tok2 = heapq.heappop(self._heap)
                eng2 = (self.mes if k2 == ME else self.ves)[e2]
                if eng2.token == tok2:
                    self._complete(eng2, t2)
            if all(rt.done for rt in self.tenants):
                break
            self._schedule(t)
            if not self._heap:
                pending = [rt.idx for rt in self.tenants
                           if rt.ready_me or rt.ready_ve]
                raise RuntimeError(
                    f"scheduler deadlock at t={t}: tenants {pending} have "
                    f"ready work but nothing is in flight")
        makespan = max((rt.finished_at for rt in self.tenants), default=t)
        if not all(rt.done for rt in self.tenants):
            makespan = t
        return SimResult(
            policy=self.policy,
            makespan=max(makespan, EPS),
            tenants=[rt.stats for rt in self.tenants],
            n_me=self.core.n_me,
            n_ve=self.core.n_ve,
            freq_hz=self.core.freq_hz,
        )

    # ------------------------------------------------------------------
    def _complete(self, eng: _Engine, t: float) -> None:
        chunk, tenant = eng.chunk, eng.tenant
        if chunk is None:
            # context-switch drain window finished
            token = eng.token
            for e in self.mes + self.ves:
                if e.token == token:
                    e.token = -1
            return
        engines = self._engines_of(chunk)
        for e in engines:
            e.token = -1
            e.chunk = None
        rt = self.tenants[tenant]
        if chunk.kind == ME:
            rt.stats.me_work += chunk.cycles
            # note: a VLIW ME op's fused VE-drain work rides inside the
            # op span without occupying modeled VE engines, so it is
            # NOT counted as VE work — utilization stats are physical
            # occupancy for every policy (conservation-exact).
            if eng.harvested:
                rt.stats.harvested_me_work += chunk.cycles
        else:
            rt.stats.ve_work += chunk.cycles
            if eng.harvested:
                rt.stats.harvested_ve_work += chunk.cycles
        # fairness bookkeeping counts ACTIVE (compute) cycles, like the
        # paper's per-vNPU performance counters (§III-E) — an
        # HBM-stalled tenant accrues little and keeps its priority,
        # which is precisely V10's Fig. 27 pathology.
        rt.active_cycles += chunk.cycles / max(chunk.n_engines, 1)
        rt.chunk_done(t)

    def _engines_of(self, chunk: Chunk) -> List[_Engine]:
        pool = self.mes if chunk.kind == ME else self.ves
        return [e for e in pool if e.chunk is chunk]

    # ------------------------------------------------------------------
    def _duration(self, chunk: Chunk, n_dispatched: int) -> float:
        rt = self.tenants[chunk.tenant]
        if rt.is_neuisa:
            # μTOps are single-engine units (a VE μTOp was pre-split
            # into n_y slot chunks)
            span = chunk.cycles
        elif chunk.kind == ME:
            # VLIW op: rate fixed by its compiled-in ME count (Fig. 9 —
            # extra engines seized but unusable); drain VE work is
            # pipelined inside the op span.
            span = chunk.cycles / max(chunk.n_engines, 1)
            op = rt.spec.program.ops[chunk.group_key]
            frac = min(chunk.cycles / max(op.me_cycles, EPS), 1.0)
            span = max(span, op.ve_cycles * frac / max(rt.spec.program.n_y, 1))
        else:
            # VLIW VE op addresses every VE slot granted at dispatch
            span = chunk.cycles / max(n_dispatched, 1)
        if chunk.hbm_bytes > 0:
            # two-level fair sharing (computed at dispatch): HBM BW is
            # split across tenants with in-flight DMA (§III-B "fair
            # sharing of HBM bandwidth"), then across THIS tenant's
            # own in-flight memory chunks — so partitioning one
            # operator into μTOps never manufactures bandwidth.
            n_ten, n_mine = self._mem_pressure(chunk.tenant)
            bw = (self.core.hbm_bytes_per_cycle * self.hbm_scale
                  / n_ten / n_mine)
            span = max(span, chunk.hbm_bytes / bw)
        return span + chunk.penalty

    def _mem_pressure(self, tenant: int) -> Tuple[int, int]:
        """Max-min fair HBM sharing: only BANDWIDTH-BOUND in-flight
        chunks contend (a compute-bound neighbor's trickle of weight
        streaming doesn't halve a decode tenant's BW — §V-F: the
        collocated LLM 'suffers negligible overhead')."""
        bpc = self.core.hbm_bytes_per_cycle * self.hbm_scale
        tenants = {tenant}
        mine = 1  # the chunk being dispatched
        seen = set()
        for e in self.mes + self.ves:
            c = e.chunk
            if c is None or c.hbm_bytes <= 0 or id(c) in seen:
                continue
            seen.add(id(c))
            # compute-bound chunks are not HBM contenders. Ties DO
            # count: memory-paced μTOp chunks sit exactly at the
            # boundary, and exempting them would let k sibling chunks
            # stream at k x BW.
            if c.hbm_bytes / bpc < c.cycles:
                continue
            tenants.add(e.tenant)
            if e.tenant == tenant:
                mine += 1
        return len(tenants), mine

    def _dispatch(self, chunk: Chunk, engines: List[_Engine], t: float,
                  harvested: bool = False) -> None:
        token = next(self._tok)
        dur = self._duration(chunk, len(engines))
        for e in engines:
            e.token = token
            e.chunk = chunk
            e.tenant = chunk.tenant
            e.start = t
            e.end = t + dur
            e.harvested = harvested
        lead = engines[0]
        heapq.heappush(
            self._heap, (t + dur, next(self._seq), lead.kind, lead.eid, token))

    def _preempt(self, eng: _Engine, t: float,
                 blocked_owner: Optional[int] = None) -> None:
        """Preempt the chunk on `eng` (and sibling engines for VLIW
        ops): remaining work returns to its tenant's ready queue with
        the context-switch penalty; engines drain for ctx cycles.
        ``blocked_owner``: tenant reclaiming its engine — it eats the
        drain window (Table III 'blocked because harvested')."""
        chunk = eng.chunk
        engines = self._engines_of(chunk)
        # VE state is tiny vs the 256-cycle systolic drain (§III-G)
        ctx = float(self.core.ctx_switch_cycles if chunk.kind == ME else 32)
        # VLIW ops span every ME: their contexts drain serially through
        # the shared SRAM port — V10 "needs to preempt the entire
        # operator from ALL MEs" (§V-C), Neu10 only the harvested one.
        ctx *= len(engines)
        frac_done = (t - eng.start) / max(eng.end - eng.start, EPS)
        frac_done = min(max(frac_done, 0.0), 1.0)
        rt = self.tenants[eng.tenant]
        remaining = Chunk(
            chunk.tenant, chunk.kind, chunk.cycles * (1 - frac_done),
            chunk.hbm_bytes * (1 - frac_done), chunk.op_name,
            n_engines=chunk.n_engines, penalty=ctx,
            group_key=chunk.group_key, from_me_group=chunk.from_me_group)
        (rt.ready_me if chunk.kind == ME else rt.ready_ve).insert(0, remaining)
        rt.stats.preemptions += 1
        if blocked_owner is not None:
            self.tenants[blocked_owner].stats.reclaim_blocked += ctx
        # account the completed fraction as useful work
        if chunk.kind == ME:
            rt.stats.me_work += chunk.cycles * frac_done
            if eng.harvested:
                rt.stats.harvested_me_work += chunk.cycles * frac_done
        else:
            rt.stats.ve_work += chunk.cycles * frac_done
        rt.active_cycles += chunk.cycles * frac_done / max(chunk.n_engines, 1)
        # engines drain their state for ctx cycles
        token = next(self._tok)
        for e in engines:
            e.token = token
            e.chunk = None
            e.tenant = -1
            e.start = t
            e.end = t + ctx
            e.harvested = False
        heapq.heappush(
            self._heap,
            (t + ctx, next(self._seq), engines[0].kind, engines[0].eid,
             token))

    # ------------------------------------------------------------------
    def _schedule(self, t: float) -> None:
        if self.policy in ("neu10", "neu10_nh"):
            self._schedule_spatial(t, harvest=self.policy == "neu10")
        elif self.policy == "v10":
            self._schedule_v10(t)
        else:
            self._schedule_pmt(t)

    # ---------------- Neu10 / Neu10-NH ----------------
    def _schedule_spatial(self, t: float, harvest: bool) -> None:
        # 1) owners dispatch on their own engines (MEs then VEs)
        for pool, ready_attr in ((self.mes, "ready_me"), (self.ves, "ready_ve")):
            for rt in self.tenants:
                ready: List[Chunk] = getattr(rt, ready_attr)
                if ready_attr == "ready_ve":
                    # operation scheduler: prioritize drains of ME groups
                    ready.sort(key=lambda c: not c.from_me_group)
                own_free = [e for e in pool
                            if e.owner == rt.idx and e.free]
                while own_free and ready:
                    self._dispatch(ready.pop(0), [own_free.pop(0)], t)
                # 2) reclaim: preempt harvested μTOps on my engines.
                # Engines drain in PARALLEL, so the owner is wall-
                # blocked for ONE ctx window per reclaim pass (what
                # Table III measures), however many engines it takes
                # back.
                if harvest and ready:
                    reclaimed = 0
                    for e in pool:
                        if reclaimed >= len(ready):
                            break
                        if (e.owner == rt.idx and not e.free
                                and e.chunk is not None
                                and e.tenant != rt.idx):
                            self._preempt(e, t)
                            reclaimed += 1
                    if reclaimed:
                        ctx = float(self.core.ctx_switch_cycles
                                    if pool is self.mes else 32)
                        rt.stats.reclaim_blocked += ctx
        if not harvest:
            return
        # 3) harvest: leftover ready chunks take others' idle engines.
        for pool, ready_attr in ((self.mes, "ready_me"), (self.ves, "ready_ve")):
            # only engines whose owner has no pending demand are up for
            # harvest (§III-E scheduling policy)
            for rt in sorted(self.tenants, key=lambda r: r.active_cycles):
                ready = getattr(rt, ready_attr)
                if not ready:
                    continue
                for e in pool:
                    if not ready:
                        break
                    if not e.free or e.owner == rt.idx:
                        continue
                    owner = self.tenants[e.owner] if e.owner is not None else None
                    owner_ready = getattr(owner, ready_attr) if owner else []
                    if owner_ready:
                        continue  # owner will use it this round
                    self._dispatch(ready.pop(0), [e], t, harvested=True)

    # ---------------- V10 ----------------
    def _schedule_v10(self, t: float) -> None:
        order = sorted(self.tenants,
                       key=lambda r: r.active_cycles / r.spec.weight)
        free_mes = [e for e in self.mes if e.free]
        all_mes_free = len(free_mes) == len(self.mes)
        for rt in order:
            # ME op: needs the WHOLE ME array (VLIW coupling)
            if rt.ready_me:
                if all_mes_free:
                    chunk = rt.ready_me.pop(0)
                    self._dispatch(chunk, list(self.mes), t)
                    all_mes_free = False
                else:
                    # priority-based preemption of the running op
                    running = next((e for e in self.mes if not e.free
                                    and e.chunk is not None), None)
                    if running is not None and running.tenant >= 0:
                        holder = self.tenants[running.tenant]
                        deficit = (holder.active_cycles / holder.spec.weight
                                   - rt.active_cycles / rt.spec.weight)
                        if deficit > self.fair_slice:
                            self._preempt(running, t)
            # VE-only ops run on the free VE pool concurrently
            if rt.ready_ve:
                free_ves = [e for e in self.ves if e.free]
                if free_ves:
                    chunk = rt.ready_ve.pop(0)
                    self._dispatch(chunk, free_ves, t)
        # note: dispatching a VE op across k free VEs divides its span
        # (VLIW VE ops address all VE slots).

    # ---------------- PMT ----------------
    def _schedule_pmt(self, t: float) -> None:
        # whole core belongs to one tenant at a time (PREMA-style
        # task-level sharing): the core changes hands at operator
        # boundaries only when the fair-share deficit is large —
        # switches are coarse and expensive.
        busy = any(not e.free for e in self.mes + self.ves)
        if busy:
            return
        order = sorted(
            (rt for rt in self.tenants if rt.ready_me or rt.ready_ve),
            key=lambda r: r.active_cycles / r.spec.weight)
        if not order:
            return
        rt = order[0]
        last = getattr(self, "_pmt_last", None)
        if last is not None and last != rt.idx:
            holder = self.tenants[last]
            if holder.ready_me or holder.ready_ve:
                deficit = (holder.active_cycles / holder.spec.weight
                           - rt.active_cycles / rt.spec.weight)
                if deficit < 4 * self.fair_slice:
                    rt = holder  # keep the core; not worth a switch yet
        # whole-core context switch cost when the core changes hands
        penalty = 0.0
        if getattr(self, "_pmt_last", None) not in (None, rt.idx):
            penalty = float(self.core.ctx_switch_cycles * self.core.n_me)
        self._pmt_last = rt.idx
        if rt.ready_me:
            chunk = rt.ready_me.pop(0)
            chunk.penalty += penalty
            self._dispatch(chunk, list(self.mes), t)
        elif rt.ready_ve:
            chunk = rt.ready_ve.pop(0)
            chunk.penalty += penalty
            self._dispatch(chunk, list(self.ves), t)


# ----------------------------------------------------------------------
def run_collocation(
    specs: Sequence[TenantSpec],
    policy: str,
    core: NPUCoreConfig = DEFAULT_CORE,
    hbm_scale: float = 1.0,
) -> SimResult:
    return Simulator(specs, policy=policy, core=core,
                     hbm_scale=hbm_scale).run()
