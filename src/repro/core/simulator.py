"""Event-driven NPU simulator (§III-G): a policy-agnostic event loop
over μTOp / operator chunks.

Granularity: μTOp events with cycle-accurate durations. An ME μTOp
occupies one ME; a VE μTOp is split into n_y slot-chunks served by
the operation scheduler. HBM bandwidth is shared between tenants with
in-flight memory-demanding μTOps (fair sharing, §III-B). The ME
preemption penalty is the paper's 256 cycles (drain partial sums +
weights of a 128x128 array).

Scheduling disciplines live in :mod:`repro.core.policies` — the
simulator resolves a policy name (or class/instance) through the
registry and delegates every dispatch decision to it. The paper's
four disciplines (``pmt`` / ``v10`` / ``neu10_nh`` / ``neu10``) ship
as built-in registry entries; third parties add more with
``@register_policy``.

Two driving modes:

* **closed loop** (legacy): each tenant re-issues its request the
  moment the previous one completes, until ``n_requests`` — call
  :meth:`Simulator.run`.
* **open loop** (online serving): requests arrive at externally
  injected timestamps (:meth:`Simulator.inject_request`), queue per
  tenant, and latency is measured from *arrival*; tenants can be
  added, removed, and re-sized mid-run — drive with
  :meth:`Simulator.run_until`. The `repro.serve.session` layer builds
  the operator-facing API on top of this.

Requests are *phase chains*: a :class:`TenantSpec` may carry a
:class:`~repro.core.compiler.CompiledRequestPlan` (prefill program +
context-bucketed decode programs). Finishing a phase enqueues the
request's next phase instead of completing it; decode steps from a
tenant's in-flight requests coalesce into shared decode iterations
(continuous batching), and :class:`TenantStats` tracks TTFT / TBT /
end-to-end latency series. A plain single-program spec is the
degenerate one-phase plan — its event sequence is bit-identical to the
pre-phase simulator.

With ``CompiledRequestPlan.iteration_token_budget`` set, iterations
are *budgeted* (SARATHI-SF piggybacking): each one fuses an
adaptively-sized prefill slice with the tenant's live decode batch
into a single program compiled on demand on a quantized grid — see
:meth:`_TenantRT._pick_budgeted` / :meth:`_complete_piggyback` for
the slice sizing and token-accounting rules. Unset, the PR-3 phase
chain engine runs verbatim.

``Simulator(fast_path=...)`` selects between the reference event-loop
implementations and result-identical optimized ones (memoized
dispatch spans, incremental HBM-contention and harvest-squatter
bookkeeping, precomputed μTOp expansion, the tightened neu10 schedule
pass); ``benchmarks/fig25_scaling.py`` pins both the equality and the
speedup.

With ``TenantSpec.kv_policy`` set, KV-cache occupancy is a live
simulator resource: every prefill chunk/slice and decode token
charges its KV bytes against the tenant vNPU's
:class:`~repro.core.vnpu.KVLedger` at the phase boundary, and when
the continuous batch outgrows the tenant's HBM segments a PREMA-style
victim (largest tokens-remaining x bucket-cost estimate) is evicted:
swapped out and later resumed through an HBM re-read program
(``"evict"``), or aborted back to admission (``"reject"``).
``kv_policy=""`` (the default) keeps every path bit-identical to the
static-``hbm_footprint`` engine — the KV goldens pin it.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import deque
from dataclasses import dataclass, field
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Tuple,
                    Union)

from repro.core.compiler import (DECODE, PIGGYBACK, PREFIX, SWAPIN,
                                 CompiledPhase, CompiledRequestPlan)
from repro.core.neuisa import ME, VE, MuTOpGroup, NeuISAProgram, VLIWProgram
from repro.core.policies import (PolicyLike, pick_eviction_victim,
                                 resolve_policy)
from repro.core.stats import mean as _mean
from repro.core.stats import percentile
from repro.core.vnpu import VNPU
from repro.npu.cost_model import (PIGGYBACK_CHUNK_FLOOR, PIGGYBACK_POS_QUANT,
                                  PIGGYBACK_TOKEN_QUANT, batch_bucket)
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig

EPS = 1e-9

_ARRIVAL = "arr"  # heap event kind for open-loop request arrivals
_ARRIVAL_K = "arrk"  # arrival carrying a shared-prefix key (payload
                  # side-table, like _MIGRATE — the plain _ARRIVAL
                  # path stays byte-identical when sharing is unused)
_MIGRATE = "mig"  # heap event kind for cross-core decode hand-offs
_ARRIVAL_R = "arrr"  # retried arrival (deadline/fault re-admission):
                  # payload side-table carries (gen_len, prefix_key,
                  # retries, original arrival) — plain arrivals never
                  # touch this path
_WAKE = "wake"    # kick an idle tenant's iteration picker (evacuation
                  # landing on the destination core)
_MIXED = object()  # sentinel: cohort engines span several owners
                  # landing after their fabric transfer delay


def _build_chunk_specs(prog, is_neuisa: bool):
    """Precompute, per group (NeuISA) or op (VLIW), the already-
    filtered (cycles, hbm, name, ...) values `_fill_ready` expands
    into Chunks — pure derived data, identical for every replay of the
    program, cached on the program object by the fast path."""
    specs = []
    if is_neuisa:
        n_y = prog.n_y
        for g in prog.groups:
            mes = [(u.cycles, u.hbm_bytes, u.op_name, 1)
                   for u in g.me_utops
                   if u.cycles > EPS or u.hbm_bytes > EPS]
            ve = None
            u = g.ve_utop
            if u is not None and (u.cycles > EPS or u.hbm_bytes > EPS):
                ve = (u.cycles / n_y, u.hbm_bytes / n_y, u.op_name, n_y,
                      bool(g.me_utops))
            specs.append((mes, ve))
    else:
        for op in prog.ops:
            mes = []
            ve = None
            if op.n_me_static > 0 and (op.me_cycles > EPS
                                       or op.hbm_bytes > EPS):
                mes = [(op.me_cycles, op.hbm_bytes, op.op_name,
                        op.n_me_static)]
            elif op.ve_cycles > EPS or op.hbm_bytes > EPS:
                ve = (op.ve_cycles, op.hbm_bytes, op.op_name, 1, False)
            specs.append((mes, ve))
    return specs


# ----------------------------------------------------------------------
@dataclass(slots=True)
class Chunk:
    """A schedulable unit: one ME μTOp, one VE μTOp slot-chunk, or a
    whole VLIW operator (multi-engine). ``cycles`` is engine cycles of
    work; ``hbm_bytes`` is bytes of HBM traffic; ``penalty`` is
    context-switch cycles added on resume."""

    tenant: int
    kind: str                    # "me" | "ve"
    cycles: float                # per-engine work
    hbm_bytes: float
    op_name: str = ""
    n_engines: int = 1           # VLIW ops seize several engines
    penalty: float = 0.0         # context-switch cycles to add (resume)
    group_key: int = -1          # group (NeuISA) or op (VLIW) index
    from_me_group: bool = False  # VE chunk draining an ME group
    phase: str = ""              # "prefill" | "decode" | "piggyback" | ""
                                 # — visible to SchedulerPolicy dispatch
                                 # decisions
    fused: bool = False          # member of a cross-tenant fused issue
                                 # group (Fig. 6): exempt from reclaim
                                 # preemption while it completes
    n_dispatched: int = 1        # engines the chunk landed on (set by
                                 # dispatch; lets completion skip the
                                 # engine-pool scan for 1-engine μTOps)
    cohort: int = 0              # >1 on the LEAD chunk of a cohort: n
                                 # identical compute-only siblings
                                 # dispatched together under one token
                                 # and one completion event (each
                                 # engine still carries its own chunk;
                                 # set only by the incremental pass)


@dataclass
class TenantSpec:
    """One collocated tenant handed to the :class:`Simulator`: a
    compiled program (or a phase-structured ``plan``), the vNPU whose
    engines it owns/targets, the closed-loop request count, and its
    fair-share ``weight`` (dimensionless priority)."""

    program: Union[NeuISAProgram, VLIWProgram, None] = None
    vnpu: Optional[VNPU] = None
    n_requests: int = 8          # closed-loop target (ignored open loop)
    weight: float = 1.0          # fair-share priority
    # phase-structured requests (prefill -> decode chain); when None,
    # ``program`` runs as a degenerate single-phase plan
    plan: Optional[CompiledRequestPlan] = None
    # live KV-cache accounting policy: "" (off — static hbm_footprint,
    # bit-identical to the ledger-less engine), "evict" (PREMA victim
    # swap-out + HBM re-read resume), or "reject" (victims abort back
    # to admission and restart from scratch)
    kv_policy: str = ""

    def __post_init__(self) -> None:
        if self.program is None and self.plan is None:
            raise ValueError("TenantSpec needs a program or a plan")
        if self.vnpu is None:
            raise ValueError("TenantSpec needs a vnpu")
        if self.kv_policy not in ("", "evict", "reject"):
            raise ValueError(
                f"unknown kv_policy {self.kv_policy!r}; "
                f"use '' (off), 'evict' or 'reject'")


class _Request:
    """One in-flight generation request: its arrival time (cycles),
    target token count, token-emission cursor, and — under chunked
    prefill — how many prefill chunk phases have completed.
    ``prefill_done`` is the token-level ingestion cursor budgeted
    (piggybacked) iterations advance; static chunking counts whole
    phases in ``chunks_done`` instead."""

    __slots__ = ("arrival", "gen_len", "tokens_done", "last_token_t",
                 "chunks_done", "prefill_done", "rid", "ttft_seen",
                 "kv_swapped", "prefix_key", "prefix_ref", "prefix_cached",
                 "deadline", "retries")

    def __init__(self, arrival: float, gen_len: int = 1, rid: int = 0,
                 prefix_key: int = 0):
        self.arrival = arrival
        self.gen_len = max(int(gen_len), 1)
        self.tokens_done = 0
        self.last_token_t = arrival
        self.chunks_done = 0
        self.prefill_done = 0
        self.rid = rid               # KV-ledger allocation key
        self.ttft_seen = False       # first token already sampled (a
                                     # reject-mode restart must not
                                     # re-sample TTFT)
        self.kv_swapped = 0          # bytes to restore on swap-in resume
        self.prefix_key = prefix_key  # shared-prefix group id (0 = none)
        self.prefix_ref = None       # ledger key of the held shared
                                     # entry (None while not admitted)
        self.prefix_cached = 0       # prefix tokens skipped on a hit
                                     # (0 on first-fill: full prefill)
        self.deadline = math.inf     # absolute admission deadline for
                                     # THIS attempt (inf = none)
        self.retries = 0             # re-admissions this request took


@dataclass
class TenantStats:
    """Per-tenant simulation counters.

    Units: every time-valued field (``latencies``, ``completions``,
    ``ttft``, ``tbt``, ``*_work``, ``reclaim_blocked``) is in CYCLES
    of the simulated core clock — divide by ``NPUCoreConfig.freq_hz``
    for seconds (the serve layer's :class:`TenantReport` does this
    once, reporting milliseconds). Token/iteration fields are plain
    counts."""

    name: str
    latencies: List[float] = field(default_factory=list)  # e2e, from arrival
    completions: List[float] = field(default_factory=list)  # finish times
    ttft: List[float] = field(default_factory=list)  # time to first token
    tbt: List[float] = field(default_factory=list)   # time between tokens
    requests_done: int = 0
    tokens: int = 0                  # tokens emitted (1/req + decode steps)
    decode_iterations: int = 0       # shared decode steps executed
    max_decode_batch: int = 0        # peak requests coalesced per step
    prefill_chunks: int = 0          # prefill chunk phases executed
                                     # (0 under monolithic prefill)
    chunk_interleaved_decodes: int = 0  # decode iterations run while a
                                     # same-tenant request sat between
                                     # prefill chunks (SARATHI interleave)
    piggyback_iterations: int = 0    # budgeted iterations that carried a
                                     # prefill slice (0 when the budget
                                     # knob is unset)
    piggyback_decode_tokens: int = 0  # decode tokens emitted riding a
                                     # prefill slice (same iteration)
    max_piggyback_batch: int = 0     # peak decode tokens co-batched with
                                     # one prefill slice
    fused_groups: int = 0            # decode μTOps this tenant co-issued
                                     # into a neighbor's prefill group
    # ---- live KV-cache ledger (all zero with kv_policy unset) ----
    kv_evictions: int = 0            # requests whose KV lost its segments
                                     # under pressure (either kv policy)
    kv_swapins: int = 0              # evicted requests resumed after the
                                     # swap-in HBM re-read ("evict")
    kv_restarts: int = 0             # "reject": victims aborted back to
                                     # admission (re-prefill from token 0)
    kv_rejected: int = 0             # requests dropped outright: their KV
                                     # can never fit the tenant's segments
    kv_truncated: int = 0            # requests force-finished early: no
                                     # co-tenant victim left to evict
    kv_swapped_bytes: float = 0.0    # cumulative bytes swapped out
    # ---- cross-request shared KV prefix (zero with sharing off) ----
    kv_prefix_hits: int = 0          # admissions that found their prefix
                                     # resident (suffix-only prefill)
    kv_shared_bytes: float = 0.0     # cumulative prefix bytes those hits
                                     # did NOT re-charge (re-use volume)
    # ---- cross-tenant HBM borrowing (zero with borrowing off) ----
    kv_borrowed_bytes: float = 0.0   # cumulative bytes granted to this
                                     # tenant from idle peer segments
    kv_reclaimed_bytes: float = 0.0  # cumulative lent bytes pulled back
                                     # when this tenant hit pressure
    # ---- cross-core fabric migration (zero off-fabric) ----
    kv_migrations: int = 0           # prefill->decode hand-offs this
                                     # tenant's requests took to another
                                     # core's decode pool
    kv_migrated_bytes: float = 0.0   # KV bytes those hand-offs moved
                                     # over the inter-core links
    cross_core_hops: int = 0         # fabric hops those hand-offs
                                     # traversed (cumulative)
    kv_migration_rejects: int = 0    # hand-offs refused on DESTINATION
                                     # ledger pressure (request decoded
                                     # locally instead)
    kv_peak_bytes: float = 0.0       # peak ledger occupancy (bytes,
                                     # weights + live KV)
    kv_peak_segments: int = 0        # peak HBM isolation segments occupied
    me_work: float = 0.0
    ve_work: float = 0.0
    harvested_me_work: float = 0.0   # work done on non-owned MEs
    harvested_ve_work: float = 0.0
    reclaim_blocked: float = 0.0     # Table III: stall due to being
                                     # harvested (reclaim ctx windows)
    preemptions: int = 0
    # ---- fault injection / failover (all zero with faults off) ----
    faults_survived: int = 0         # injected faults this tenant rode
                                     # out without losing completed work
    evacuations: int = 0             # whole-vNPU migrations to another
                                     # core after a core fault
    evacuated_bytes: float = 0.0     # live KV bytes those evacuations
                                     # carried over the fabric
    hbm_fault_segments: int = 0      # HBM isolation segments lost to
                                     # segment faults (vNPU shrunk)
    deadline_misses: int = 0         # requests that timed out in the
                                     # admission queue
    retries: int = 0                 # re-admissions scheduled (deadline
                                     # misses + fault aborts with retry
                                     # budget left) — distinct from
                                     # kv_restarts
    retry_successes: int = 0         # retried requests that completed
    retries_exhausted: int = 0       # requests dropped after their
                                     # last retry also failed
    downtime_cycles: float = 0.0     # cycles this tenant spent frozen
                                     # by faults (evacuation transfers,
                                     # suspend-until-recovery gaps)

    def p95(self) -> float:
        """p95 of end-to-end request latency, in cycles."""
        return percentile(self.latencies, 0.95)

    def mean(self) -> float:
        """Mean end-to-end request latency, in cycles."""
        return _mean(self.latencies)

    def ttft_p95(self) -> float:
        """p95 time-to-first-token, in cycles."""
        return percentile(self.ttft, 0.95)

    def tbt_p95(self) -> float:
        """p95 time-between-tokens, in cycles."""
        return percentile(self.tbt, 0.95)


@dataclass
class SimResult:
    """Simulation outcome. ``makespan`` is in CYCLES (like every
    TenantStats series); ``freq_hz`` is the core clock, carried so
    reporting layers convert to wall time exactly once
    (:func:`throughput` already returns requests/SECOND)."""

    policy: str
    makespan: float              # cycles until every tenant hit N reqs
    tenants: List[TenantStats]
    n_me: int
    n_ve: int
    freq_hz: float

    def me_utilization(self) -> float:
        denom = self.n_me * self.makespan
        if denom <= 0:
            return 0.0           # empty open-loop run: no work, no span
        return sum(t.me_work for t in self.tenants) / denom

    def ve_utilization(self) -> float:
        denom = self.n_ve * self.makespan
        if denom <= 0:
            return 0.0
        return sum(t.ve_work for t in self.tenants) / denom

    def throughput(self, idx: int) -> float:
        """requests/sec for tenant idx over the makespan."""
        if self.makespan <= 0 or self.freq_hz <= 0:
            return 0.0
        t = self.tenants[idx]
        return t.requests_done / (self.makespan / self.freq_hz)

    def total_throughput(self) -> float:
        return sum(self.throughput(i) for i in range(len(self.tenants)))


# ----------------------------------------------------------------------
class _Engine:
    __slots__ = ("kind", "eid", "owner", "token", "chunk", "tenant",
                 "start", "end", "harvested")

    def __init__(self, kind: str, eid: int, owner: Optional[int]):
        self.kind = kind
        self.eid = eid
        self.owner = owner       # tenant idx (spatial) or None
        self.token: int = -1     # generation counter; -1 = free
        self.chunk: Optional[Chunk] = None
        self.tenant: int = -1
        self.start = 0.0
        self.end = 0.0
        self.harvested = False

    @property
    def free(self) -> bool:
        return self.token < 0


class _TenantRT:
    """Runtime over a tenant's request plan.

    Requests move waiting -> (prefill iteration(s)) -> decoding ->
    (shared decode iterations) -> done. One *iteration* (a phase
    program execution) is in flight at a time per tenant; decode
    iterations coalesce every in-flight decoding request (continuous
    batching). Under chunked prefill a request's prefill is a CHAIN of
    chunk iterations: after each non-final chunk the request parks in
    ``prefilling`` and, if decodes are live, the tenant yields one
    decode iteration before the next chunk — so a long prompt no
    longer head-of-line blocks the tenant's own token cadence. Closed
    loop: a new request arrives the instant the previous one
    completes. Open loop: requests arrive via :meth:`arrive` and the
    tenant idles between iterations (``in_request`` False)."""

    def __init__(self, idx: int, spec: TenantSpec, core: NPUCoreConfig,
                 open_loop: bool = False, fast_path: bool = True):
        self.idx = idx
        self.spec = spec
        self.core = core
        self.open_loop = open_loop
        self.fast_path = fast_path
        self.removed = False
        if spec.plan is not None:
            self.plan = spec.plan
        else:  # degenerate one-phase plan: seed-identical behavior
            self.plan = CompiledRequestPlan(
                name=spec.program.name,
                prefill=CompiledPhase("", spec.program), gen_len=1)
        self.cur_program = self.plan.prefill.program
        self.is_neuisa = isinstance(self.cur_program, NeuISAProgram)
        self.me_ids = set(spec.vnpu.me_ids)
        self.ve_ids = set(spec.vnpu.ve_ids)
        self.stats = TenantStats(name=self.plan.name)
        self.active_cycles = 0.0          # fair-share bookkeeping
        self.cursor = -1                  # group / op index
        self.outstanding = 0              # chunks of current step in flight
        self.in_request = False           # an iteration is in flight
        self.waiting: Deque[_Request] = deque()   # arrived, not prefilled
        self.prefilling: List[_Request] = []      # between prefill chunks
        self.decoding: List[_Request] = []        # mid-generation
        self.active: List[_Request] = []          # served by the iteration
        self.active_kind = ""                     # phase of the iteration
        self.yield_to_decode = False      # chunk boundary: run one decode
                                          # iteration before the next chunk
        # budgeted (piggybacked) iterations
        self.piggy_req: Optional[_Request] = None  # slice owner this iter
        self.piggy_slice = 0              # prompt tokens this slice ingests
        self.force_prefill = False        # a decode-only iteration just ran
                                          # because the batch ate the whole
                                          # budget: floor the next slice
        # live KV accounting (inert unless the spec sets a kv_policy
        # AND the plan carries per-token KV bytes)
        self.kv_policy = spec.kv_policy
        self.kv_enabled = bool(spec.kv_policy) \
            and self.plan.kv_token_bytes > 0
        if (self.kv_enabled and self.kv_policy == "evict"
                and not self.plan.can_swapin):
            raise ValueError(
                f"tenant plan {self.plan.name!r} has no swap-in builder; "
                f"kv_policy='evict' needs one (compile the plan from a "
                f"trace-layer request_plan)")
        self.swapped: List[_Request] = []  # evicted, awaiting swap-in
        # cross-request shared KV prefix: on when the plan carries a
        # prefix builder AND KV accounting is live (sharing is a
        # ledger feature); requests opt in per-arrival via prefix_key
        self.prefix_enabled = (self.kv_enabled and self.plan.prefix_len > 0
                               and self.plan.can_prefix)
        # cross-tenant HBM borrowing: the serving layer installs a
        # relief callback (needed_bytes -> bytes freed); a failed
        # ledger charge retries ONCE after the hook reclaims lent
        # segments and/or borrows idle peer segments. None (default)
        # keeps every charge path bit-identical.
        self.kv_pressure_hook: Optional[Callable[[float], float]] = None
        # cluster fabric: called when a request finishes prefill and
        # decode steps remain — returning True means the hand-off was
        # taken (the request continues on another core's decode pool);
        # False / None keeps the PR-3 local-decode path bit-identical
        self.migrate_hook: Optional[Callable[["_TenantRT", _Request,
                                              float], bool]] = None
        self._rid = itertools.count()      # per-request ledger keys
        self._t = 0.0                      # time of the current pick
        # deadline/retry admission (inert at the 0 defaults: no sweep
        # runs, no deadline is stamped — bit-identical to the
        # pre-fault engine)
        self.deadline_cycles = 0.0         # per-attempt admission
                                           # deadline (0 = none)
        self.max_retries = 0               # re-admission budget
        # serving-layer callback that schedules the re-admission of a
        # timed-out / fault-aborted request (backoff + re-injection);
        # None drops the request after counting the miss
        self.retry_hook: Optional[Callable[[_Request, float],
                                           None]] = None
        # failover: an evacuated tenant stays parked until its bulk
        # state transfer lands (the session's inject_wake un-parks it);
        # 0.0 never freezes — bit-identical to the pre-fault engine
        self.frozen_until = 0.0
        self.ready_me: List[Chunk] = []
        self.ready_ve: List[Chunk] = []
        # incremental scheduling: the simulator swaps in its shared
        # dirty set at add_tenant so ready-queue growth marks the
        # tenant without a callback (standalone _TenantRT construction
        # in tests keeps the private default)
        self._dirty_sink: set = set()
        self.loop_remaining: Dict[int, int] = {}
        self.done = False                 # reached n_requests (keeps running)
        self.finished_at = math.inf

    # ---------------- request / phase lifecycle ----------------
    @property
    def in_flight(self) -> int:
        """Requests admitted but not completed."""
        n = (len(self.waiting) + len(self.prefilling)
             + len(self.decoding) + len(self.swapped))
        if self.in_request:
            if self.active_kind == PIGGYBACK:
                n += 1   # the slice owner; co-riders stay in `decoding`
            elif self.active_kind not in (DECODE, SWAPIN):
                n += len(self.active)
            elif self.active_kind == SWAPIN:
                n += 1   # the resuming request left every queue
        return n

    def _context_of(self, req: _Request) -> int:
        """KV context of the request's NEXT decode step."""
        return self.plan.prompt_len + req.tokens_done + 1

    def _new_request(self, arrival: float, gen_len: int,
                     prefix_key: int = 0) -> _Request:
        req = _Request(arrival, gen_len, rid=next(self._rid),
                       prefix_key=prefix_key)
        if self.deadline_cycles > 0:
            req.deadline = arrival + self.deadline_cycles
        return req

    def start_request(self, t: float, arrival: Optional[float] = None,
                      gen_len: Optional[int] = None,
                      prefix_key: int = 0) -> None:
        """Admit one request (closed-loop kick / legacy entry point)."""
        self.waiting.append(self._new_request(
            t if arrival is None else arrival,
            self.plan.gen_len if gen_len is None else gen_len,
            prefix_key=prefix_key))
        if not self.in_request:
            self._start_iteration(t)

    def _start_iteration(self, t: float) -> None:
        """Pick the tenant's next unit of work. With an
        ``iteration_token_budget`` set, every iteration is *budgeted*:
        a prefill slice sized to the live decode load piggybacks the
        tenant's decode tokens in one fused program
        (:meth:`_pick_budgeted`). Otherwise the PR-3 phase chain
        rules apply verbatim (:meth:`_pick_phase`): a decode iteration
        if a prefill chunk just yielded, else the next prefill chunk
        of the request mid-prefill, else a waiting request's (first)
        prefill, else one shared decode step over every in-flight
        decoding request. KV-accounted tenants route through the
        ledger-aware variants (:meth:`_pick_phase_kv`, or the gated
        checks inside :meth:`_pick_budgeted`)."""
        self._t = t
        if t < self.frozen_until:
            return   # evacuation transfer in flight: stay parked
        if self.deadline_cycles > 0 and self.waiting:
            self._sweep_deadlines(t)
        budgeted = (self.plan.iteration_token_budget > 0
                    and self.plan.can_piggyback)
        if budgeted:
            picked = self._pick_budgeted()
        elif self.kv_enabled:
            picked = self._pick_phase_kv()
        else:
            picked = self._pick_phase()
        if not picked:
            return
        self.in_request = True
        self.cursor = -1
        self.loop_remaining = {}
        self._advance(t)

    def _sweep_deadlines(self, t: float) -> None:
        """Drop admission-queue requests whose per-attempt deadline
        lapsed (deadline/retry admission — only requests still WAITING
        time out; work in flight always runs to completion). Each miss
        either re-enters admission through the serving layer's retry
        hook (bounded budget, exponential backoff) or is dropped."""
        expired = [r for r in self.waiting if r.deadline <= t]
        if not expired:
            return
        led = self._kv_led()
        st = self.stats
        for req in expired:
            self.waiting.remove(req)
            if led is not None:
                # waiting requests hold no KV by invariant; lenient
                # release keeps that true even across future churn
                led.release(req.rid)
                self._kv_prefix_release(led, req)
            st.deadline_misses += 1
            self.retry_or_drop(req, t)

    def retry_or_drop(self, req: _Request, t: float) -> None:
        """Route a timed-out or fault-aborted request back to
        admission when retry budget remains (the serving layer's hook
        schedules the backoff + re-injection); count the exhaustion
        otherwise. With no hook installed the request just drops."""
        hook = self.retry_hook
        if hook is None:
            return
        if req.retries < self.max_retries:
            hook(req, t)
        else:
            self.stats.retries_exhausted += 1

    def arrive_retry(self, t: float, gen_len: Optional[int] = None,
                     prefix_key: int = 0, retries: int = 1,
                     orig_arrival: float = 0.0,
                     ttft_seen: bool = False) -> None:
        """A re-admission lands after its backoff: end-to-end latency
        still spans the ORIGINAL arrival, the per-attempt deadline
        restarts from now, and a first token already emitted by a
        fault-aborted attempt is never re-sampled into TTFT."""
        if self.removed:
            return
        req = self._new_request(orig_arrival,
                                self.plan.gen_len if gen_len is None
                                else gen_len,
                                prefix_key=prefix_key)
        req.retries = retries
        req.ttft_seen = ttft_seen
        if self.deadline_cycles > 0:
            req.deadline = t + self.deadline_cycles
        if retries > 0:
            # retries == 0 is a re-sequenced arrival (a pending arrival
            # replayed after a suspend gap keeps its original timestamp
            # this way) — not a re-admission, so it doesn't count
            self.stats.retries += 1
        self.waiting.append(req)
        if not self.in_request:
            self._start_iteration(t)

    def abort_iteration(self, t: float) -> List[_Request]:
        """Cancel the in-flight iteration (core fault): every served
        request lands back in a queue with its ledger charges
        consistent. Decode riders keep their KV and stay in
        ``decoding`` — only the step's un-emitted token is lost; a
        swap-in resumer returns to the FRONT of ``swapped`` with its
        bytes released again; a prefill / prefix / piggyback owner
        loses the attempt's charges, resets its cursors, and parks at
        the front of ``waiting``. Returns the requests whose attempt
        was lost (fault-abort retry candidates)."""
        if not self.in_request:
            return []
        led = self._kv_led()
        lost: List[_Request] = []
        kind = self.active_kind
        if kind == DECODE:
            pass
        elif kind == SWAPIN:
            req = self.active[0]
            if led is not None:
                req.kv_swapped = led.release(req.rid)
            self.swapped.insert(0, req)
        else:
            req = self.piggy_req if kind == PIGGYBACK else self.active[0]
            if led is not None:
                led.release(req.rid)
                self._kv_prefix_release(led, req)
            req.tokens_done = 0
            req.prefill_done = 0
            req.chunks_done = 0
            self.waiting.appendleft(req)
            lost.append(req)
        self.piggy_req = None
        self.piggy_slice = 0
        self.active = []
        self.active_kind = ""
        self.in_request = False
        self.outstanding = 0
        self.ready_me.clear()
        self.ready_ve.clear()
        return lost

    def _pick_phase(self) -> bool:
        """PR-3 iteration selection (budget unset) — bit-identical to
        the pre-budget engine. Returns False when the tenant idles."""
        if not self.decoding:
            self.yield_to_decode = False   # nothing to yield to
        pick_decode = self.decoding and (
            self.yield_to_decode or not (self.prefilling or self.waiting))
        if not pick_decode and (self.prefilling or self.waiting):
            if self.prefilling:
                req = self.prefilling.pop(0)
            else:
                req = self.waiting.popleft()
            if req.prefill_done:
                # the budget knob was disabled while this request was
                # mid-slice: the unset engine only has whole-prompt /
                # whole-chunk programs, so ingestion RESTARTS from
                # token 0 (partial KV dropped, cost paid explicitly —
                # see ServingSession.set_iteration_token_budget)
                req.prefill_done = 0
            self.active = [req]
            phases = self.plan.prefill_phases()
            ph = phases[min(req.chunks_done, len(phases) - 1)]
            self.active_kind = ph.kind
            self.cur_program = ph.program
        elif self.decoding:
            self._begin_decode()
        else:
            return False
        return True

    # ---------------- live KV-cache ledger ----------------
    def _kv_led(self):
        """The tenant vNPU's live ledger, or None when KV accounting
        is off (re-read per call: a live resize swaps the vNPU — and
        its migrated ledger — under the runtime)."""
        if not self.kv_enabled:
            return None
        v = self.spec.vnpu
        return None if v is None else v.kv_ledger

    def _kv_charge(self, led, req: _Request, nbytes: float) -> bool:
        """Charge ``nbytes`` of KV growth to ``req``; mirrors the
        ledger's peak occupancy into the tenant stats. A failed charge
        retries ONCE after the serving layer's pressure hook (reclaim
        lent segments / borrow idle peer segments) frees bytes."""
        if nbytes <= 0:
            return True
        if not led.alloc(req.rid, nbytes):
            # retired (zero-holder, retained) prefix entries are the
            # cheapest victims: no live request loses state
            if led.retired:
                led.evict_retired(nbytes - led.available, now=self._t)
            if led.alloc(req.rid, nbytes):
                self._kv_mark_peaks(led)
                return True
            hook = self.kv_pressure_hook
            if hook is None or hook(nbytes - led.available) <= 0:
                return False
            if not led.alloc(req.rid, nbytes):
                return False
        self._kv_mark_peaks(led)
        return True

    def _kv_mark_peaks(self, led) -> None:
        st = self.stats
        if led.peak_bytes > st.kv_peak_bytes:
            st.kv_peak_bytes = led.peak_bytes
        if led.peak_segments > st.kv_peak_segments:
            st.kv_peak_segments = led.peak_segments

    # ---------------- cross-request shared KV prefix ----------------
    def _kv_prefix_bytes(self) -> float:
        return self.plan.prefix_len * self.plan.kv_token_bytes

    def _kv_prefix_attach(self, led, req: _Request) -> Optional[str]:
        """Transactionally take a shared-prefix reference for ``req``'s
        admission attempt. Returns ``"hit"`` (prefix resident: bump the
        refcount, the prefill skips the cached tokens and the rid
        charge covers only the suffix), ``"fill"`` (first holder: the
        prefix bytes charge all-or-nothing into the shared entry, the
        request runs full prefill but its rid still carries only the
        suffix), or None (no room to first-fill even after pressure
        relief — the caller proceeds unshared). The caller MUST undo a
        successful attach with :meth:`_kv_prefix_release` if the rest
        of the admission fails, so a parked request never pins the
        sole reference to an entry nobody is using. Hit stats are the
        caller's job (counted once, on the attempt that admits)."""
        key = req.prefix_key
        pbytes = self._kv_prefix_bytes()
        if led.shared_refs(key) > 0 or key in led.retired:
            # resident OR retained with zero holders: both are hits —
            # the retained entry's bytes never left
            led.acquire_shared(key, pbytes)
            req.prefix_ref = key
            req.prefix_cached = self.plan.prefix_len
            return "hit"
        if not led.acquire_shared(key, pbytes):
            if led.retired:
                led.evict_retired(pbytes - led.available, now=self._t)
            if not led.acquire_shared(key, pbytes):
                hook = self.kv_pressure_hook
                if hook is None or hook(pbytes - led.available) <= 0:
                    return None
                if not led.acquire_shared(key, pbytes):
                    return None
        req.prefix_ref = key
        req.prefix_cached = 0
        self._kv_mark_peaks(led)
        return "fill"

    def _kv_prefix_release(self, led, req: _Request) -> float:
        """Drop ``req``'s shared-prefix reference (no-op when it holds
        none). Returns the bytes freed (non-zero only on the LAST
        release)."""
        if req.prefix_ref is None or led is None:
            return 0.0
        freed = led.release_shared(req.prefix_ref, now=self._t)
        req.prefix_ref = None
        req.prefix_cached = 0
        return freed

    def _kv_phase_tokens(self, req: _Request) -> int:
        """Prompt tokens the request's NEXT prefill phase ingests
        (whole prompt when monolithic; this chunk's share when
        chunked) — the prefill-side KV write charged at admission."""
        phases = self.plan.prefill_phases()
        i = min(req.chunks_done, len(phases) - 1)
        prev = phases[i - 1].context if i > 0 else 0
        return max(phases[i].context - prev, 0)

    def _kv_evict_one(self, t: float,
                      exclude: Optional[_Request] = None) -> bool:
        """Free one victim's KV segments under pressure. The victim is
        the PREMA-style pick (largest tokens-remaining x bucket-cost
        service estimate — see
        :func:`repro.core.policies.pick_eviction_victim`); under
        ``"evict"`` its KV swaps out (HBM re-read on resume), under
        ``"reject"`` it aborts back to admission and restarts from
        token 0. Retired (zero-holder, retained) prefix entries go
        FIRST — freeing one costs no live request its state. Returns
        False when no candidate exists."""
        led = self._kv_led()
        if led is not None and led.retired \
                and led.evict_retired(1, now=t) > 0:
            return True
        cands = [r for r in self.decoding if r is not exclude]
        if not cands:
            return False
        refs_of = None
        if self.prefix_enabled:
            # shared-prefix holders whose entry other live requests
            # still reference go LAST: evicting them cannot free the
            # shared segments (the refcount keeps them resident)
            def refs_of(r):
                return (led.shared_refs(r.prefix_ref)
                        if r.prefix_ref is not None else 0)
        victim = pick_eviction_victim(cands, self.plan, self._context_of,
                                      shared_refs_of=refs_of)
        self.decoding.remove(victim)
        freed = led.release(victim.rid)
        st = self.stats
        st.kv_evictions += 1
        if self.kv_policy == "evict":
            if (victim.prefix_ref is not None
                    and led.shared_refs(victim.prefix_ref) <= 1):
                # sole holder: the shared entry swaps out with it (the
                # resume recharges prefix + suffix through the rid;
                # sharing for this key restarts at the next first-fill)
                freed += self._kv_prefix_release(led, victim)
            victim.kv_swapped = freed
            st.kv_swapped_bytes += freed
            self.swapped.append(victim)
        else:
            st.kv_restarts += 1
            self._kv_prefix_release(led, victim)
            victim.tokens_done = 0
            victim.prefill_done = 0
            victim.chunks_done = 0
            self.waiting.appendleft(victim)
        return True

    def _kv_admit_decode(self, t: float) -> None:
        """Charge one token of KV growth per decoding request (the
        token the next shared iteration emits). When the batch's
        growth outgrows the tenant's segments, evict victims until
        the remainder fits; a lone request that cannot grow is
        force-finished (single-request OOM) rather than deadlocked."""
        led = self._kv_led()
        if led is None or not self.decoding:
            return
        per = self.plan.kv_token_bytes
        charged = set()
        idx = 0
        while idx < len(self.decoding):
            req = self.decoding[idx]
            if req.rid in charged or self._kv_charge(led, req, per):
                charged.add(req.rid)
                idx += 1
                continue
            if self._kv_evict_one(t, exclude=req):
                idx = 0   # indices shifted; `charged` skips re-charges
                continue
            self.decoding.pop(idx)
            self.stats.kv_truncated += 1
            self._complete_request(req, t)

    def _kv_try_swapin(self, t: float):
        """Resume the longest-parked evicted request when its KV fits
        again, with one decode round of headroom so the resume does
        not instantly re-trigger the eviction it came from. Returns
        True (swap-in iteration set up), None (a permanently
        unfittable request was dropped — caller retries), or False
        (nothing to resume / still no room)."""
        if not self.swapped:
            return False
        led = self._kv_led()
        req = self.swapped[0]
        need = req.kv_swapped
        if need > led.capacity - led.reserved:
            # a shrink-resize left this context permanently unfittable
            self.swapped.pop(0)
            self.stats.kv_truncated += 1
            self._complete_request(req, t)
            return None
        headroom = (len(self.decoding) + 1) * self.plan.kv_token_bytes
        headroom = min(headroom, max(led.capacity - led.reserved - need, 0))
        if not led.fits(need + headroom):
            if not led.retired:
                return False
            led.evict_retired(need + headroom - led.available, now=t)
            if not led.fits(need + headroom):
                return False
        self.swapped.pop(0)
        self._kv_charge(led, req, need)
        req.kv_swapped = 0
        ph = self.plan.swapin_phase(self.plan.prompt_len + req.tokens_done)
        self.active = [req]
        self.active_kind = SWAPIN
        self.cur_program = ph.program
        return True

    def _kv_admit_prefill(self):
        """Admission under the ledger: start the next prefill
        chunk/prompt only if its KV write fits. Returns True
        (iteration set up), None (an unfittable request was dropped —
        caller retries), or False (blocked on memory / nothing to
        admit; FIFO order is preserved)."""
        if not (self.prefilling or self.waiting):
            return False
        led = self._kv_led()
        from_prefilling = bool(self.prefilling)
        req = (self.prefilling.pop(0) if from_prefilling
               else self.waiting.popleft())
        if req.prefill_done:
            # budget knob disabled mid-slice: ingestion restarts from
            # token 0 (same rule as _pick_phase) — the partial KV is
            # dropped, so its ledger share (and any shared-prefix
            # reference the slices held) frees too
            req.prefill_done = 0
            led.release(req.rid)
            self._kv_prefix_release(led, req)
        tokens = self._kv_phase_tokens(req)
        attach = None
        if (self.prefix_enabled and req.prefix_key
                and not self.plan.chunked and req.chunks_done == 0):
            attach = self._kv_prefix_attach(led, req)
            if attach is not None:
                # the prefix bytes live in the shared entry (first-fill
                # charged them there just now, or a hit found them
                # resident): the rid carries only the unshared suffix
                tokens -= self.plan.prefix_len
        need = tokens * self.plan.kv_token_bytes
        if self._kv_charge(led, req, need):
            self.active = [req]
            if attach == "hit":
                self.stats.kv_prefix_hits += 1
                self.stats.kv_shared_bytes += self._kv_prefix_bytes()
                ph = self.plan.prefix_phase(req.prefix_cached)
            else:
                phases = self.plan.prefill_phases()
                ph = phases[min(req.chunks_done, len(phases) - 1)]
            self.active_kind = ph.kind
            self.cur_program = ph.program
            return True
        # cumulative fit check: can the request's UNSHARED bytes (plus
        # the prefix share a first-fill would hold) ever fit? With no
        # shared reference this is the whole prompt — the pre-sharing
        # rule verbatim.
        cum = self.plan.kv_prompt_bytes
        if attach == "hit":
            cum = need
        if cum > led.capacity - led.reserved:
            # the request can never fit this tenant's segments
            # (checked cumulatively, not per chunk — a request whose
            # chunks fit one at a time but whose total cannot would
            # otherwise wedge mid-prefill holding partial KV):
            # admission reject, surfaced through kv_rejected
            led.release(req.rid)
            self._kv_prefix_release(led, req)
            self.stats.kv_rejected += 1
            return None
        # blocked on memory: undo the attach (a parked request must
        # not pin the sole reference) and keep FIFO order
        self._kv_prefix_release(led, req)
        if from_prefilling:
            self.prefilling.insert(0, req)
        else:
            self.waiting.appendleft(req)
        return False

    def _pick_phase_kv(self) -> bool:
        """Ledger-aware iteration selection (KV accounting on, budget
        unset): the PR-3 phase rules extended with swap-in resumes,
        prompt-KV admission gating, and decode-growth charging with
        PREMA eviction. KV-disabled tenants never enter here — their
        scheduling stays bit-identical (:meth:`_pick_phase`)."""
        t = self._t
        for _ in range(100_000):
            if not self.decoding:
                self.yield_to_decode = False   # nothing to yield to
            pick_decode = self.decoding and (
                self.yield_to_decode
                or not (self.prefilling or self.waiting or self.swapped))
            if not pick_decode:
                got = self._kv_try_swapin(t)
                if got:
                    return True
                if got is None:
                    continue
                got = self._kv_admit_prefill()
                if got:
                    return True
                if got is None:
                    continue
            if self.decoding:
                self._kv_admit_decode(t)
                if self.decoding:
                    self._begin_decode()
                    return True
                continue   # batch dissolved under pressure: re-pick
            return False
        raise RuntimeError("KV admission livelock")   # pragma: no cover

    def _begin_decode(self) -> None:
        """Set up one shared decode iteration over every in-flight
        decoding request. The step's cost is the largest live context
        bucket: the batched KV stream is paced by the longest
        sequence."""
        ctx = max(self._context_of(r) for r in self.decoding)
        phase = self.plan.decode_phase_for(ctx)
        self.active = list(self.decoding)
        self.active_kind = DECODE
        self.cur_program = phase.program
        self.yield_to_decode = False

    def _pick_budgeted(self) -> bool:
        """Adaptive per-iteration token budget (SARATHI-SF
        piggybacking): one iteration serves a prefill slice of
        ``budget - live decode batch`` prompt tokens AND the decode
        batch, as one fused program — decoding requests keep their
        token cadence through a neighbor request's prefill instead of
        waiting out whole chunk iterations.

        Floor/cap rules guarantee progress both ways: when the
        (bucketed) decode batch eats the budget to under
        ``PIGGYBACK_CHUNK_FLOOR`` tokens, ONE decode-only iteration
        runs and the next slice is floored — prefill always advances
        every other iteration; the slice is capped at the remaining
        prompt (the final slice may be partial). Program cost is
        looked up on the quantized grid (slice tokens, position,
        batch bucket, per-rider context buckets) while token
        bookkeeping stays exact.

        KV-accounted tenants additionally charge the ledger here:
        riders' decode growth first (with PREMA eviction under
        pressure), then the slice's prompt-KV write — a slice shrinks
        to the bytes available (never below the floor; with no room
        for even a floored slice the prompt parks and the decode
        cadence continues; a prompt whose TOTAL KV can never fit is
        rejected). Returns False when the tenant idles."""
        t = self._t
        led = self._kv_led()
        for _ in range(100_000):   # bounded: each retry dropped or
            if led is not None:    # evicted a request
                got = self._kv_try_swapin(t)
                if got:
                    return True
                if got is None:
                    continue       # a parked request dropped: re-pick
            if not (self.prefilling or self.waiting):
                if not self.decoding:
                    return False
                if led is not None:
                    self._kv_admit_decode(t)
                    if not self.decoding:
                        continue   # batch dissolved: re-pick
                self._begin_decode()    # no prompt left to slice
                return True
            budget = self.plan.iteration_token_budget
            if led is not None and self.decoding:
                # every budgeted iteration below serves the live batch
                # (fused or decode-only): charge its growth now
                self._kv_admit_decode(t)
            batch = len(self.decoding)
            bb = batch_bucket(batch)
            slice_ = budget - bb
            if (batch and slice_ < PIGGYBACK_CHUNK_FLOOR
                    and not self.force_prefill):
                # over-subscribed: the decode batch alone fills the
                # budget
                self.force_prefill = True
                self._begin_decode()
                return True
            self.force_prefill = False
            from_prefilling = bool(self.prefilling)
            if from_prefilling:
                req = self.prefilling.pop(0)
            else:
                req = self.waiting.popleft()
            attach = None
            if (self.prefix_enabled and req.prefix_key and led is not None
                    and req.prefill_done == 0 and req.prefix_ref is None):
                attach = self._kv_prefix_attach(led, req)
                if attach == "hit":
                    # resident prefix: slices start past the cached
                    # tokens (the quantized piggyback grid prices the
                    # suffix at its true kv_prior position)
                    req.prefill_done = req.prefix_cached
            shared_skip = (self.plan.prefix_len
                           if req.prefix_ref is not None else 0)
            remaining = max(self.plan.prompt_len - req.prefill_done, 1)
            slice_ = min(max(slice_, min(PIGGYBACK_CHUNK_FLOOR, remaining)),
                         remaining)
            if led is not None:
                per = self.plan.kv_token_bytes
                floor_tok = min(PIGGYBACK_CHUNK_FLOOR, remaining)
                cum = self.plan.kv_prompt_bytes
                if attach == "hit":
                    cum = (self.plan.prompt_len - shared_skip) * per
                if cum > led.capacity - led.reserved:
                    # the request's unshared bytes can never fit
                    # (cumulative check, like _kv_admit_prefill):
                    # reject
                    led.release(req.rid)
                    self._kv_prefix_release(led, req)
                    self.stats.kv_rejected += 1
                    continue
                # tokens below the shared boundary are already paid in
                # the shared entry: they cost the rid nothing, so the
                # slice fit is free tokens + what the available bytes
                # cover (identical to the pre-sharing rule when no
                # reference is held)
                free_tok = max(shared_skip - req.prefill_done, 0)
                fit = (free_tok + int(led.available // per)
                       if per > 0 else slice_)
                if fit < floor_tok and led.retired:
                    # retained zero-holder entries give way before a
                    # prompt parks
                    led.evict_retired(
                        (floor_tok - free_tok) * per - led.available, now=t)
                    fit = (free_tok + int(led.available // per)
                           if per > 0 else slice_)
                if fit < floor_tok:
                    # no memory for even a floored slice: the prompt
                    # waits for admission (dropping a just-taken
                    # reference so it never pins the sole holder);
                    # decode cadence keeps running
                    if attach is not None:
                        self._kv_prefix_release(led, req)
                        if attach == "hit":
                            req.prefill_done = 0
                    if from_prefilling:
                        self.prefilling.insert(0, req)
                    else:
                        self.waiting.appendleft(req)
                    if self.decoding:
                        self._begin_decode()
                        return True
                    return False
                slice_ = min(slice_, fit)
                lo = req.prefill_done
                chargeable = max(lo + slice_ - max(lo, shared_skip), 0)
                self._kv_charge(led, req, chargeable * per)
                if attach == "hit":
                    self.stats.kv_prefix_hits += 1
                    self.stats.kv_shared_bytes += self._kv_prefix_bytes()
            final = req.prefill_done + slice_ >= self.plan.prompt_len
            q = PIGGYBACK_TOKEN_QUANT
            cost_tokens = -(-slice_ // q) * q
            pq = PIGGYBACK_POS_QUANT
            pos = -(-req.prefill_done // pq) * pq if req.prefill_done else 0
            phase = self._piggyback_phase_for(cost_tokens, pos, final)
            self.active = [req] + (list(self.decoding)
                                   if self.decoding else [])
            self.piggy_req = req
            self.piggy_slice = slice_
            self.active_kind = PIGGYBACK
            self.cur_program = phase.program
            self.yield_to_decode = False
            return True
        raise RuntimeError("KV admission livelock")   # pragma: no cover

    def _piggyback_phase_for(self, cost_tokens: int, pos: int,
                             final: bool) -> CompiledPhase:
        """Fused-phase lookup for the live decode batch. Riders are
        costed at their OWN context bucket: the batch is grouped per
        bucket and each group's decode share priced there, so a
        small-context rider no longer pays the largest live bucket's
        KV stream. A single-bucket batch keeps the legacy
        (batch-bucket, ctx) cache key — those programs stay
        byte-identical to the pre-grouping engine."""
        if not self.decoding:
            return self.plan.piggyback_phase(cost_tokens, pos, 0, 0, final)
        per: Dict[int, int] = {}
        for r in self.decoding:
            c = self.plan.decode_phase_for(self._context_of(r)).context
            per[c] = per.get(c, 0) + 1
        bb = batch_bucket(len(self.decoding))
        if len(per) == 1:
            ctx = next(iter(per))
            return self.plan.piggyback_phase(cost_tokens, pos, bb, ctx,
                                             final)
        groups = tuple((batch_bucket(n), c) for c, n in sorted(per.items()))
        return self.plan.piggyback_phase(cost_tokens, pos, 0, 0, final,
                                         decode_groups=groups)

    def _on_iteration_complete(self, t: float) -> None:
        """A phase program finished: emit tokens, advance each served
        request's phase chain, then start the next iteration. A
        non-final prefill chunk emits no token — the request parks in
        ``prefilling`` and live decodes get one iteration first."""
        if self.active_kind == DECODE:
            self.stats.decode_iterations += 1
            self.stats.max_decode_batch = max(
                self.stats.max_decode_batch, len(self.active))
            if self.prefilling:
                # a same-tenant request is sitting between prefill
                # chunks: this decode interleaved into its prefill
                self.stats.chunk_interleaved_decodes += 1
            finished = []
            for req in self.active:
                req.tokens_done += 1
                self.stats.tokens += 1
                self.stats.tbt.append(t - req.last_token_t)
                req.last_token_t = t
                if req.tokens_done >= req.gen_len:
                    finished.append(req)
            for req in finished:
                self.decoding.remove(req)
                self._complete_request(req, t)
        elif self.active_kind == PIGGYBACK:
            self._complete_piggyback(t)
        elif self.active_kind == SWAPIN:
            # KV restored (the re-read program just paid the HBM
            # cost): the request rejoins the continuous batch; its
            # next token's TBT sample carries the full eviction gap
            req = self.active[0]
            self.stats.kv_swapins += 1
            self.decoding.append(req)
        else:
            req = self.active[0]
            req.chunks_done += 1
            if self.plan.chunked:
                self.stats.prefill_chunks += 1
            if req.chunks_done < self.plan.n_prefill_chunks:
                # chunk hand-off: prompt not fully ingested, no token
                self.prefilling.append(req)
                if self.decoding:
                    self.yield_to_decode = True
            else:
                if not req.ttft_seen:
                    # a reject-mode restart re-runs prefill; only the
                    # FIRST first-token samples TTFT
                    self.stats.ttft.append(t - req.arrival)
                    req.ttft_seen = True
                self.stats.tokens += 1
                req.tokens_done = 1       # prefill emits the first token
                req.last_token_t = t
                if req.gen_len > 1 and self.plan.has_decode:
                    # fabric hand-off point: a disaggregated tenant's
                    # decode pool may live on another core (hook True
                    # = migrated; rejected hand-offs decode locally)
                    if not (self.migrate_hook is not None
                            and self.migrate_hook(self, req, t)):
                        self.decoding.append(req)
                else:
                    self._complete_request(req, t)
        self.active = []
        self.in_request = False
        self._start_iteration(t)

    def _complete_piggyback(self, t: float) -> None:
        """A budgeted iteration finished. Token accounting rules:
        every co-riding decode request's token lands NOW (one TBT
        sample each — piggybacked tokens are decode tokens, whatever
        program carried them); the slice owner's ingestion cursor
        advances by the exact slice, and only the slice that completes
        the prompt emits the first token (one TTFT sample, measured
        from arrival — co-riders never touch TTFT)."""
        req = self.piggy_req
        riders = [r for r in self.active if r is not req]
        st = self.stats
        st.prefill_chunks += 1
        st.piggyback_iterations += 1
        if riders:
            st.piggyback_decode_tokens += len(riders)
            st.max_piggyback_batch = max(st.max_piggyback_batch,
                                         len(riders))
        finished = []
        for r in riders:
            r.tokens_done += 1
            st.tokens += 1
            st.tbt.append(t - r.last_token_t)
            r.last_token_t = t
            if r.tokens_done >= r.gen_len:
                finished.append(r)
        for r in finished:
            self.decoding.remove(r)
            self._complete_request(r, t)
        req.prefill_done += self.piggy_slice
        if req.prefill_done >= self.plan.prompt_len:
            if not req.ttft_seen:
                st.ttft.append(t - req.arrival)
                req.ttft_seen = True
            st.tokens += 1
            req.tokens_done = 1      # the final slice emits token 1
            req.last_token_t = t
            if req.gen_len > 1 and self.plan.has_decode:
                # same fabric hand-off point as the monolithic path
                if not (self.migrate_hook is not None
                        and self.migrate_hook(self, req, t)):
                    self.decoding.append(req)
            else:
                self._complete_request(req, t)
        else:
            self.prefilling.insert(0, req)   # same request continues
        self.piggy_req = None
        self.piggy_slice = 0

    def _complete_request(self, req: _Request, t: float) -> None:
        if self.kv_enabled:
            led = self._kv_led()
            if led is not None:
                led.release(req.rid)   # exact free of the request's KV
                self._kv_prefix_release(led, req)
        if req.retries > 0:
            self.stats.retry_successes += 1
        self.stats.latencies.append(t - req.arrival)
        self.stats.completions.append(t)
        self.stats.requests_done += 1
        if not self.open_loop:
            if (self.stats.requests_done >= self.spec.n_requests
                    and not self.done):
                self.done = True
                self.finished_at = t
            # closed loop: the next request arrives immediately
            self.waiting.append(self._new_request(t, self.plan.gen_len))

    # ---------------- program stepping ----------------
    def _advance(self, t: float) -> None:
        """Move to the next non-empty group/op; refill ready queues."""
        while True:
            nxt = self._next_cursor()
            if nxt is None:
                self._on_iteration_complete(t)
                return
            self.cursor = nxt
            if self._fill_ready():
                return

    def _next_cursor(self) -> Optional[int]:
        prog = self.cur_program
        if self.is_neuisa:
            n = len(prog.groups)
            if self.cursor < 0:
                return 0 if n else None
            # loop control (uTop.nextGroup). The per-group loop target
            # is static program structure, so the fast path derives it
            # once per program (cached like `_chunk_specs`) instead of
            # walking every μTOp of the group on each replay.
            if self.fast_path:
                tbl = getattr(prog, "_next_tbl", None)
                if tbl is None:
                    tbl = [next((u.next_group for u in g.all_utops()
                                 if u.next_group is not None), None)
                           for g in prog.groups]
                    prog._next_tbl = tbl
                tgt = tbl[self.cursor]
            else:
                g = prog.groups[self.cursor]
                tgt = next((u.next_group for u in g.all_utops()
                            if u.next_group is not None), None)
            if tgt is not None:
                trips = self.loop_remaining.get(
                    self.cursor, prog.loop_trips.get(self.cursor, 1))
                if trips > 1:
                    self.loop_remaining[self.cursor] = trips - 1
                    return tgt
            nxt = self.cursor + 1
            return nxt if nxt < n else None
        n = len(prog.ops)
        nxt = self.cursor + 1
        return nxt if nxt < n else None

    def _fill_ready(self) -> bool:
        """Expand current group/op into ready chunks. False if empty.
        Under the fast path, the per-group (filtered μTOp values) are
        precomputed once per program — they are pure derived data, so
        caching them on the shared program object is safe — and each
        replay only constructs the Chunk objects."""
        prog = self.cur_program
        phase = self.active_kind
        made = 0
        if self.fast_path:
            specs = getattr(prog, "_chunk_specs", None)
            if specs is None:
                specs = _build_chunk_specs(prog, self.is_neuisa)
                prog._chunk_specs = specs
            me_specs, ve_spec = specs[self.cursor]
            cursor, idx = self.cursor, self.idx
            # positional construction (field order matches the Chunk
            # dataclass) — this is the hottest allocation site in the
            # simulator, one Chunk per μTOp replay
            append_me = self.ready_me.append
            for cycles, hbm, name, n_eng in me_specs:
                append_me(Chunk(idx, ME, cycles, hbm, name, n_eng,
                                0.0, cursor, False, phase))
                made += 1
            if ve_spec is not None:
                cycles, hbm, name, slots, from_me = ve_spec
                append_ve = self.ready_ve.append
                for _ in range(slots):
                    append_ve(Chunk(idx, VE, cycles, hbm, name, 1,
                                    0.0, cursor, from_me, phase))
                    made += 1
            self.outstanding = made
            if made:
                self._dirty_sink.add(self.idx)
            return made > 0
        if self.is_neuisa:
            g: MuTOpGroup = prog.groups[self.cursor]
            for u in g.me_utops:
                if u.cycles > EPS or u.hbm_bytes > EPS:
                    self.ready_me.append(Chunk(
                        self.idx, ME, u.cycles, u.hbm_bytes, u.op_name,
                        group_key=self.cursor, phase=phase))
                    made += 1
            if g.ve_utop is not None and (
                    g.ve_utop.cycles > EPS or g.ve_utop.hbm_bytes > EPS):
                n_y = prog.n_y
                for _ in range(n_y):
                    self.ready_ve.append(Chunk(
                        self.idx, VE, g.ve_utop.cycles / n_y,
                        g.ve_utop.hbm_bytes / n_y, g.ve_utop.op_name,
                        group_key=self.cursor,
                        from_me_group=bool(g.me_utops), phase=phase))
                    made += 1
        else:
            op = prog.ops[self.cursor]
            if op.n_me_static > 0 and (op.me_cycles > EPS or op.hbm_bytes > EPS):
                self.ready_me.append(Chunk(
                    self.idx, ME, op.me_cycles, op.hbm_bytes, op.op_name,
                    n_engines=op.n_me_static, group_key=self.cursor,
                    phase=phase))
                made += 1
                # drain VE work is folded into the op span (pipelined)
            elif op.ve_cycles > EPS or op.hbm_bytes > EPS:
                self.ready_ve.append(Chunk(
                    self.idx, VE, op.ve_cycles, op.hbm_bytes, op.op_name,
                    group_key=self.cursor, phase=phase))
                made += 1
        self.outstanding = made
        if made:
            self._dirty_sink.add(self.idx)
        return made > 0

    def chunk_done(self, t: float) -> None:
        self.outstanding -= 1
        if self.outstanding <= 0 and not self.ready_me and not self.ready_ve:
            self._advance(t)

    def arrive(self, t: float, gen_len: Optional[int] = None,
               prefix_key: int = 0) -> None:
        """Open-loop request arrival at time t; ``gen_len`` overrides
        the plan's default generation length for this request.
        ``prefix_key`` != 0 marks the request as sharing its prompt
        prefix with every other request carrying the same key."""
        if self.removed:
            return
        self.start_request(t, arrival=t, gen_len=gen_len,
                           prefix_key=prefix_key)

    # ---------------- cluster-fabric migration ----------------
    def clone_inbound(self, req: _Request) -> _Request:
        """Materialize the migrated-in copy of ANOTHER core's request
        (cross-core prefill->decode hand-off): a fresh ledger rid on
        THIS tenant, with the arrival timestamp and token cursors
        preserved so end-to-end latency still spans the original
        arrival and the first decode token's TBT sample carries the
        fabric transfer gap."""
        m = _Request(req.arrival, req.gen_len, rid=next(self._rid),
                     prefix_key=req.prefix_key)
        m.tokens_done = req.tokens_done
        m.last_token_t = req.last_token_t
        m.ttft_seen = req.ttft_seen     # TTFT sampled on the prefill core
        return m

    def admit_migrated(self, t: float, req: _Request) -> None:
        """A request that finished prefill on another core joins this
        tenant's continuous decode batch (its KV was charged to this
        vNPU's ledger at hand-off time — see the session's migration
        hook). Dropped silently if the tenant was deregistered while
        the transfer was in flight (the ledger clear on removal
        already released the charge)."""
        if self.removed:
            return
        self.decoding.append(req)
        if not self.in_request:
            self._start_iteration(t)


# ----------------------------------------------------------------------
class Simulator:
    """Deterministic event-driven simulator for one physical NPU core
    shared by collocated vNPU tenants, under any registered
    :class:`~repro.core.policies.SchedulerPolicy`."""

    # heap compaction floor: below this many stale entries a sweep
    # costs more than the lazy pops it saves (tests shrink it to
    # exercise compaction on small runs)
    HEAP_COMPACT_MIN = 64

    def __init__(
        self,
        tenants: Sequence[TenantSpec] = (),
        policy: PolicyLike = "neu10",
        core: NPUCoreConfig = DEFAULT_CORE,
        hbm_scale: float = 1.0,
        fair_slice: float = 50_000.0,   # cycles of service imbalance
        max_events: int = 20_000_000,
        fast_path: bool = True,
        incremental: bool = True,
    ):
        """``fast_path`` enables the wall-clock optimizations that are
        *result-identical* by construction: memoized per-(chunk shape,
        mem-pressure) dispatch durations, incremental HBM-contention
        bookkeeping instead of an engine scan per dispatch, and the
        tightened ``neu10`` schedule pass. ``False`` runs the
        reference implementations — kept so benchmarks/tests can
        prove byte-for-byte SimResult equality and measure the
        speedup (``fig25_scaling``'s fast-path row).

        ``incremental`` additionally enables dirty-set scheduling when
        the policy opts in with ``schedule_incremental`` (see
        ``docs/architecture.md`` "Event engine"): the schedule pass is
        skipped outright while nothing marked a tenant dirty, runs
        through the policy's incremental hook otherwise, and the event
        loop/dispatch layers are fused into one frame. Policies
        without the hook (``pmt``/``v10``) silently fall back to the
        full pass per event. Result-identical by the same A/B proof
        (``fig25``'s ``sched_incremental`` row + the churn property
        test); requires ``fast_path``."""
        self.policy_obj = resolve_policy(policy)
        self.policy = self.policy_obj.name or type(self.policy_obj).__name__
        self.core = core
        self.hbm_scale = hbm_scale
        self.fair_slice = fair_slice
        self.max_events = max_events
        self.fast_path = fast_path
        self._span_memo: Dict[Tuple, float] = {}
        self._bw_inflight: Dict[int, int] = {}   # id(chunk) -> tenant
        self._bw_per_tenant: Dict[int, int] = {}  # tenant -> bw chunks
        self._bpc = core.hbm_bytes_per_cycle * hbm_scale  # bytes/cycle
        # fast path: engines per owner currently running a FOREIGN
        # tenant's chunk (harvest squatters) — lets the reclaim pass
        # skip its engine scan when an owner has nothing to reclaim
        self._squat: Dict[int, int] = {}
        self.now = 0.0
        self.tenants: List[_TenantRT] = []
        # maintained active-tenant cache (tenants minus removed, in
        # index order) — the incremental pass iterates it every event,
        # so it is kept up to date by add/remove instead of rebuilt
        self._act: List[_TenantRT] = []
        self.mes = [_Engine(ME, i, None) for i in range(core.n_me)]
        self.ves = [_Engine(VE, i, None) for i in range(core.n_ve)]
        self._heap: List[Tuple[float, int, str, int, int]] = []
        self._seq = itertools.count()
        self._tok = itertools.count()
        # in-flight cross-core hand-offs keyed by token: the payload
        # is (request clone, optional landing callback)
        self._mig_payloads: Dict[int, Tuple["_Request",
                                            Optional[Callable]]] = {}
        # prefix-keyed arrivals keyed by token: (gen_len, prefix_key)
        # — plain arrivals keep riding the _ARRIVAL token slot
        self._arr_payloads: Dict[int, Tuple[int, int]] = {}
        # retried arrivals keyed by token: (gen_len, prefix_key,
        # retries, original arrival, ttft_seen)
        self._retry_payloads: Dict[int, Tuple[int, int, int, float,
                                              bool]] = {}
        self._events = 0
        # lazy-deletion heap hygiene: count of stale entries (preempted
        # or cancelled tokens) still sitting in the heap; compacted
        # away past HEAP_COMPACT_MIN once they outnumber live entries
        self._stale = 0
        # incremental scheduling state: the shared dirty set (tenant
        # indices; -1 = global change such as an unowned engine
        # freeing) and the per-owner free-engine index the incremental
        # pass consumes instead of scanning pools
        self.incremental = incremental
        self._dirty: set = set()
        self._free_me_own: Dict[Optional[int], List[_Engine]] = {}
        self._free_ve_own: Dict[Optional[int], List[_Engine]] = {}
        # free-engine counters per pool (mirrors of the index) — one
        # integer check gates the harvest section of the pass
        self._nfree_me = 0
        self._nfree_ve = 0
        self._inc_fn = getattr(self.policy_obj, "schedule_incremental", None)
        self._inc = bool(incremental and fast_path
                         and self._inc_fn is not None)
        if self._inc:
            self._rebuild_free_index()
        self.policy_obj.on_attach(self)
        for s in tenants:
            self.add_tenant(s)

    # ------------------------------------------------------------------
    # dynamic tenant control plane
    # ------------------------------------------------------------------
    def active_tenants(self) -> List[_TenantRT]:
        return [rt for rt in self.tenants if not rt.removed]

    def add_tenant(self, spec: TenantSpec, open_loop: bool = False) -> int:
        """Attach a tenant (possibly mid-run). Closed-loop tenants
        start their request train immediately; open-loop tenants idle
        until :meth:`inject_request`. Returns the tenant index."""
        idx = len(self.tenants)
        rt = _TenantRT(idx, spec, self.core, open_loop=open_loop,
                       fast_path=self.fast_path)
        rt._dirty_sink = self._dirty   # before any ready-queue fill
        # a late joiner starts from the lowest live fair-share counter,
        # not zero — otherwise it would starve everyone until it
        # "caught up" on service it never queued for
        live = [r.active_cycles for r in self.active_tenants()]
        if live:
            rt.active_cycles = min(live)
        self.tenants.append(rt)
        self._act.append(rt)
        if self.policy_obj.spatial:
            self._claim_engines(rt)
            if self.fast_path:
                # a mid-run joiner can take ownership of engines still
                # running a departed neighbor's harvested chunks (the
                # deregister released ownership, not the work) — the
                # squatter counts must see them, like resize does
                self._recount_squat()
            if self._inc:
                # ownership keys the free-engine index; claiming moved
                # engines between owner buckets
                self._rebuild_free_index()
                self._dirty.add(-1)
        if not open_loop:
            rt.start_request(self.now)
        self.policy_obj.on_tenant_added(self, rt)
        return idx

    def remove_tenant(self, idx: int) -> None:
        """Detach a tenant mid-run: cancel its in-flight chunks (the
        context is discarded, not drained — vNPU deallocation cleans
        the engines), release engine ownership, drop queued work."""
        rt = self.tenants[idx]
        if rt.removed:
            return
        cancelled: set = set()   # distinct tokens -> stale heap entries
        for e in self.mes + self.ves:
            if not e.free and e.chunk is not None and e.tenant == idx:
                # one heap entry per token (VLIW multi-engine ops and
                # incremental cohorts share a token across engines)
                cancelled.add(e.token)
                self._unsquat(e, idx)
                e.token = -1       # pending completion event goes stale
                e.chunk = None
                e.tenant = -1
                e.harvested = False
            if e.owner == idx:
                e.owner = None
        self._squat.pop(idx, None)   # released engines reclaim nothing
        if cancelled:
            self._stale += len(cancelled)
            self._maybe_compact()
        if self._inc:
            # cancelled engines freed and ownership released: the
            # free-engine index is rebuilt, and everyone may now
            # harvest the released engines
            self._rebuild_free_index()
            self._dirty.add(-1)
        rt.ready_me.clear()
        rt.ready_ve.clear()
        rt.waiting.clear()
        rt.prefilling.clear()
        rt.decoding.clear()
        rt.swapped.clear()
        if rt.kv_enabled:
            led = rt._kv_led()
            if led is not None:
                # mid-run churn: every per-request allocation releases
                # with the tenant (the vNPU's reserved weights free
                # when the control plane destroys the vNPU itself)
                led.clear()
        rt.active = []
        rt.piggy_req = None
        rt.piggy_slice = 0
        rt.in_request = False
        rt.removed = True
        self._act = [r for r in self.tenants if not r.removed]
        if self._bw_per_tenant.pop(idx, None) is not None:
            # cancelled chunks left the engines above: drop their
            # bandwidth-contention entries too
            for cid in [c for c, ten in self._bw_inflight.items()
                        if ten == idx]:
                del self._bw_inflight[cid]
        rt.done = True
        rt.finished_at = min(rt.finished_at, self.now)
        self.policy_obj.on_tenant_removed(self, rt)
        self._schedule(self.now)

    def update_tenant_vnpu(self, idx: int, vnpu: VNPU) -> None:
        """Re-size a live tenant onto a reconfigured vNPU (paper
        hypercall (2) taking effect mid-run). In-flight chunks finish
        where they run; ownership moves to the new engine set."""
        rt = self.tenants[idx]
        if rt.removed:
            raise ValueError(f"tenant {idx} was deregistered")
        rt.spec.vnpu = vnpu
        rt.me_ids = set(vnpu.me_ids)
        rt.ve_ids = set(vnpu.ve_ids)
        if self.policy_obj.spatial:
            for e in self.mes + self.ves:
                if e.owner == idx:
                    e.owner = None
            self._claim_engines(rt)
            self._recount_squat()   # ownership moved under live chunks
            if self._inc:
                self._rebuild_free_index()
                self._dirty.add(-1)
        self._schedule(self.now)

    def _claim_engines(self, rt: _TenantRT) -> None:
        for pool, ids in ((self.mes, rt.me_ids), (self.ves, rt.ve_ids)):
            for e in pool:
                if e.eid in ids:
                    if e.owner is not None and e.owner != rt.idx:
                        raise ValueError(
                            f"engine {e.kind}{e.eid} already owned by "
                            f"tenant {e.owner}; vNPU mapping conflict")
                    e.owner = rt.idx

    def inject_request(self, idx: int, at: float,
                       gen_len: Optional[int] = None,
                       prefix_key: int = 0) -> None:
        """Open-loop arrival: tenant ``idx`` receives a request at
        cycle ``at`` (>= now). ``gen_len`` overrides the tenant plan's
        default generation length for this request (generation-length
        distributions sample it per request at the serving layer).
        ``prefix_key`` != 0 marks the request's prompt prefix as
        shared with every other same-key request (refcounted in the
        tenant's KV ledger); the tenant's plan must carry a prefix
        builder. Key-less arrivals take the original event path
        byte-for-byte."""
        rt = self.tenants[idx]
        if not rt.open_loop:
            raise ValueError(f"tenant {idx} is closed-loop")
        if rt.removed:
            raise ValueError(f"tenant {idx} was deregistered")
        if at < self.now - EPS:
            raise ValueError(f"arrival at {at} is in the past (now={self.now})")
        if gen_len is not None and gen_len < 1:
            raise ValueError(f"gen_len must be >= 1, got {gen_len}")
        if gen_len is not None and gen_len > 1 and not rt.plan.has_decode:
            raise ValueError(
                f"tenant {idx} has no decode phases; gen_len={gen_len} "
                f"would be silently truncated to 1 token")
        if prefix_key:
            if not rt.prefix_enabled:
                raise ValueError(
                    f"tenant {idx} has no shared-prefix support "
                    f"(plan prefix_len=0 or KV accounting off); "
                    f"prefix_key={prefix_key} would be silently ignored")
            key = next(self._tok)
            self._arr_payloads[key] = (
                -1 if gen_len is None else int(gen_len), int(prefix_key))
            heapq.heappush(self._heap,
                           (max(at, self.now), next(self._seq), _ARRIVAL_K,
                            idx, key))
            return
        heapq.heappush(self._heap,
                       (max(at, self.now), next(self._seq), _ARRIVAL, idx,
                        -1 if gen_len is None else int(gen_len)))

    def inject_migration(self, idx: int, at: float, req: "_Request",
                         on_land: Optional[Callable[[float], None]] = None,
                         ) -> None:
        """Cluster-fabric hand-off: a request that finished prefill on
        ANOTHER core's simulator joins tenant ``idx``'s decode batch
        at cycle ``at`` (hand-off time + the priced link transfer).
        ``req`` must be this tenant's own clone
        (:meth:`_TenantRT.clone_inbound`) — its KV charge happened at
        hand-off. ``on_land`` fires when the transfer lands (the
        serving session's in-transit accounting)."""
        rt = self.tenants[idx]
        if rt.removed:
            raise ValueError(f"tenant {idx} was deregistered")
        if at < self.now - EPS:
            raise ValueError(
                f"migration landing at {at} is in the past (now={self.now})")
        key = next(self._tok)
        self._mig_payloads[key] = (req, on_land)
        heapq.heappush(self._heap,
                       (max(at, self.now), next(self._seq), _MIGRATE, idx,
                        key))

    def inject_retry(self, idx: int, at: float,
                     gen_len: Optional[int] = None, prefix_key: int = 0,
                     retries: int = 1, orig_arrival: float = 0.0,
                     ttft_seen: bool = False) -> None:
        """Deadline/fault re-admission: tenant ``idx`` re-receives a
        request at cycle ``at`` (arrival + backoff). The request lands
        carrying its retry count and ORIGINAL arrival timestamp so
        end-to-end latency spans every attempt."""
        rt = self.tenants[idx]
        if rt.removed:
            raise ValueError(f"tenant {idx} was deregistered")
        if at < self.now - EPS:
            raise ValueError(
                f"retry at {at} is in the past (now={self.now})")
        key = next(self._tok)
        self._retry_payloads[key] = (
            -1 if gen_len is None else int(gen_len), int(prefix_key),
            int(retries), float(orig_arrival), bool(ttft_seen))
        heapq.heappush(self._heap,
                       (max(at, self.now), next(self._seq), _ARRIVAL_R,
                        idx, key))

    def inject_wake(self, idx: int, at: float) -> None:
        """Kick tenant ``idx``'s iteration picker at cycle ``at`` (an
        evacuated vNPU resumes once its bulk transfer lands). No-op if
        an iteration is already in flight when the event fires."""
        heapq.heappush(self._heap,
                       (max(at, self.now), next(self._seq), _WAKE, idx, 0))

    def abort_tenant(self, idx: int, t: float) -> List["_Request"]:
        """Fault-abort tenant ``idx``'s in-flight iteration: cancel its
        chunks on the engines (pending completion events go stale, like
        :meth:`remove_tenant`) and restitute every served request to a
        queue with its ledger charges consistent
        (:meth:`_TenantRT.abort_iteration`). The tenant stays attached.
        Returns the requests whose attempt was lost."""
        rt = self.tenants[idx]
        cancelled: set = set()
        for e in self.mes + self.ves:
            if not e.free and e.chunk is not None and e.tenant == idx:
                cancelled.add(e.token)
                self._unsquat(e, idx)
                e.token = -1
                e.chunk = None
                e.tenant = -1
                e.harvested = False
        if cancelled:
            self._stale += len(cancelled)
            self._maybe_compact()
            if self._inc:
                # cancelled engines freed: co-tenants may harvest them
                self._rebuild_free_index()
                self._dirty.add(-1)
        lost = rt.abort_iteration(t)
        self._schedule(self.now)
        return lost

    def extract_tenant_events(self, idx: int
                              ) -> List[Tuple[float, str, object]]:
        """Remove every pending arrival / retry / migration / wake
        event addressed to tenant ``idx`` from the heap (failover: the
        events follow the tenant to the simulator it evacuates to).
        Returns ``[(t, kind, payload)]`` sorted by time — the payload
        is the kind's side-table tuple (the ``gen_len`` token slot for
        plain arrivals; wakes are dropped, the re-attach re-kicks)."""
        fault_kinds = (_ARRIVAL, _ARRIVAL_K, _ARRIVAL_R, _MIGRATE, _WAKE)
        keep, out = [], []
        for ev in self._heap:
            t, _, kind, eid, token = ev
            if kind not in fault_kinds or eid != idx:
                keep.append(ev)
                continue
            if kind == _ARRIVAL:
                out.append((t, _ARRIVAL, token))
            elif kind == _ARRIVAL_K:
                out.append((t, _ARRIVAL_K, self._arr_payloads.pop(token)))
            elif kind == _ARRIVAL_R:
                out.append((t, _ARRIVAL_R, self._retry_payloads.pop(token)))
            elif kind == _MIGRATE:
                out.append((t, _MIGRATE, self._mig_payloads.pop(token)))
        if len(keep) != len(self._heap):
            self._heap = keep
            heapq.heapify(self._heap)
        out.sort(key=lambda e: e[0])
        return out

    def replay_tenant_events(self, idx: int,
                             events: Sequence[Tuple[float, str, object]]
                             ) -> None:
        """Re-inject events extracted by :meth:`extract_tenant_events`
        onto tenant ``idx`` of THIS simulator (the failover
        destination). Times earlier than ``now`` clamp forward."""
        for t, kind, payload in events:
            at = max(t, self.now)
            if kind == _ARRIVAL:
                g = payload
                self.inject_request(idx, at,
                                    gen_len=None if g < 0 else g)
            elif kind == _ARRIVAL_K:
                g, pk = payload
                self.inject_request(idx, at,
                                    gen_len=None if g < 0 else g,
                                    prefix_key=pk)
            elif kind == _ARRIVAL_R:
                g, pk, n, orig, seen = payload
                self.inject_retry(idx, at,
                                  gen_len=None if g < 0 else g,
                                  prefix_key=pk, retries=n,
                                  orig_arrival=orig, ttft_seen=seen)
            elif kind == _MIGRATE:
                req, on_land = payload
                self.inject_migration(idx, at, req, on_land=on_land)

    @property
    def next_event_at(self) -> float:
        """Cycle time of the earliest pending event (inf when idle) —
        the cluster-level lockstep driver advances whichever core's
        simulator is globally earliest."""
        return self._heap[0][0] if self._heap else math.inf

    # ------------------------------------------------------------------
    # event loop
    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        """Closed-loop batch run: simulate until every closed-loop
        tenant has completed its ``n_requests``. Open-loop tenants
        (which never 'finish') don't gate termination — drive those
        with :meth:`run_until` instead."""
        closed = [rt for rt in self.tenants if not rt.open_loop]
        if not closed:
            raise ValueError(
                "run() needs at least one closed-loop tenant; "
                "open-loop simulations are driven with run_until()")
        self._schedule(self.now)
        if self._inc:
            self._drain_fast(math.inf, closed)
        else:
            while self._heap:
                t = self._step()
                if t is None:
                    continue
                if all(rt.done for rt in closed):
                    break
                self._schedule(t)
                self._check_liveness(t)
        makespan = max((rt.finished_at for rt in closed), default=self.now)
        if not all(rt.done for rt in closed):
            makespan = self.now
        return self._result(max(makespan, EPS))

    def run_until(self, t_end: float = math.inf) -> float:
        """Open-loop driver: process events up to ``t_end`` cycles
        (inclusive); returns the new simulation time. With no bound,
        drains every injected arrival and all in-flight work."""
        self._schedule(self.now)
        if self._inc:
            self._drain_fast(t_end, None)
        else:
            while self._heap and self._heap[0][0] <= t_end + EPS:
                t = self._step()
                if t is None:
                    continue
                self._schedule(t)
                self._check_liveness(t)
        if math.isfinite(t_end) and t_end > self.now:
            self.now = t_end
        return self.now

    def _drain_fast(self, t_end: float, closed) -> None:
        """Incremental-path event loop: event semantics identical to
        ``_step``/``_schedule``/``_check_liveness`` (stale pops skip
        scheduling without advancing ``now``; same-time events batch
        within EPS; ``closed`` — run()'s termination list, None for
        run_until — is checked before scheduling), with the call
        layers flattened and the schedule pass gated on the dirty
        set."""
        heap = self._heap
        pop = heapq.heappop
        mes, ves = self.mes, self.ves
        sched = self._inc_fn
        dirty = self._dirty
        complete = self._complete
        apply_ev = self._apply
        max_events = self.max_events
        bound = t_end + EPS
        while heap and heap[0][0] <= bound:
            self._events += 1
            if self._events > max_events:
                raise RuntimeError("simulator exceeded max_events")
            t, _, kind, eid, token = pop(heap)
            if kind == ME:
                eng = mes[eid]
            elif kind == VE:
                eng = ves[eid]
            else:
                apply_ev(kind, eid, token, t)
                eng = None
            if eng is not None:
                if eng.token != token:
                    self._stale -= 1
                    continue
                complete(eng, t)
            self.now = t
            tb = t + EPS
            while heap and heap[0][0] <= tb:
                t2, _, k2, e2, tok2 = pop(heap)
                if k2 == ME:
                    eng = mes[e2]
                elif k2 == VE:
                    eng = ves[e2]
                else:
                    apply_ev(k2, e2, tok2, t2)
                    continue
                if eng.token == tok2:
                    complete(eng, t2)
                else:
                    self._stale -= 1
            if closed is not None:
                for rt in closed:
                    if not rt.done:
                        break
                else:
                    return
            if dirty:
                snap = set(dirty)
                dirty.clear()
                sched(self, t, snap)
            if not heap:
                self._check_liveness(t)

    def _step(self) -> Optional[float]:
        """Pop and apply the next event (plus its same-time batch).
        Returns the event time, or None for a stale token."""
        self._events += 1
        if self._events > self.max_events:
            raise RuntimeError("simulator exceeded max_events")
        t, _, kind, eid, token = heapq.heappop(self._heap)
        if not self._apply(kind, eid, token, t):
            return None
        self.now = t
        # batch any same-time events before rescheduling
        while self._heap and self._heap[0][0] <= t + EPS:
            t2, _, k2, e2, tok2 = heapq.heappop(self._heap)
            self._apply(k2, e2, tok2, t2)
        return t

    def _apply(self, kind: str, eid: int, token: int, t: float) -> bool:
        if kind == _ARRIVAL:
            # the token slot carries the per-request gen_len (-1: default)
            self.tenants[eid].arrive(t, gen_len=None if token < 0 else token)
            return True
        if kind == _ARRIVAL_K:
            g, pk = self._arr_payloads.pop(token)
            self.tenants[eid].arrive(t, gen_len=None if g < 0 else g,
                                     prefix_key=pk)
            return True
        if kind == _ARRIVAL_R:
            g, pk, n, orig, seen = self._retry_payloads.pop(token)
            self.tenants[eid].arrive_retry(
                t, gen_len=None if g < 0 else g, prefix_key=pk,
                retries=n, orig_arrival=orig, ttft_seen=seen)
            return True
        if kind == _WAKE:
            rt = self.tenants[eid]
            if not rt.removed and not rt.in_request:
                rt._start_iteration(t)
            return True
        if kind == _MIGRATE:
            req, on_land = self._mig_payloads.pop(token)
            rt = self.tenants[eid]
            rt.admit_migrated(t, req)
            if not rt.removed:
                self.policy_obj.on_request_migrated(self, rt, req)
            if on_land is not None:
                on_land(t)
            return True
        eng = (self.mes if kind == ME else self.ves)[eid]
        if eng.token != token:
            self._stale -= 1
            return False  # stale (preempted / cancelled)
        self._complete(eng, t)
        return True

    def _check_liveness(self, t: float) -> None:
        if not self._heap:
            pending = [rt.idx for rt in self.active_tenants()
                       if rt.ready_me or rt.ready_ve]
            if pending:
                raise RuntimeError(
                    f"scheduler deadlock at t={t}: tenants {pending} have "
                    f"ready work but nothing is in flight")

    def _result(self, makespan: float) -> SimResult:
        return SimResult(
            policy=self.policy,
            makespan=makespan,
            tenants=[rt.stats for rt in self.tenants],
            n_me=self.core.n_me,
            n_ve=self.core.n_ve,
            freq_hz=self.core.freq_hz,
        )

    def result(self) -> SimResult:
        """Snapshot of the stats so far (open-loop sessions)."""
        return self._result(max(self.now, EPS))

    # ------------------------------------------------------------------
    def _complete(self, eng: _Engine, t: float) -> None:
        chunk, tenant = eng.chunk, eng.tenant
        inc = self._inc
        if chunk is None:
            # context-switch drain window finished
            token = eng.token
            for e in self.mes + self.ves:
                if e.token == token:
                    e.token = -1
                    if inc:
                        self._free_idx_add(e)
                        self._dirty.add(-1 if e.owner is None else e.owner)
            return
        if chunk.cohort > 1:
            # incremental cohort: n identical compute-only siblings
            # under one token/event — replay per-chunk completion in
            # engine order (== dispatch order == the order the
            # reference's n consecutive events would complete in)
            self._complete_cohort(eng.token, chunk.kind, tenant, t)
            return
        squat = self._squat
        if chunk.n_dispatched == 1:     # single-engine μTOp fast path
            if squat:
                # inlined _unsquat (hot path)
                ow = eng.owner
                if ow is not None and ow != tenant:
                    ns = squat.get(ow, 0) - 1
                    if ns <= 0:
                        squat.pop(ow, None)
                    else:
                        squat[ow] = ns
            eng.token = -1
            eng.chunk = None
            if inc:
                self._free_idx_add(eng)
                self._dirty.add(-1 if eng.owner is None else eng.owner)
        else:
            for e in self._engines_of(chunk):
                if squat:
                    self._unsquat(e, tenant)
                e.token = -1
                e.chunk = None
                if inc:
                    self._free_idx_add(e)
                    self._dirty.add(-1 if e.owner is None else e.owner)
        if self._bw_inflight:
            self._bw_unregister(chunk)
        rt = self.tenants[tenant]
        st = rt.stats
        cycles = chunk.cycles
        if chunk.kind == ME:
            st.me_work += cycles
            # note: a VLIW ME op's fused VE-drain work rides inside the
            # op span without occupying modeled VE engines, so it is
            # NOT counted as VE work — utilization stats are physical
            # occupancy for every policy (conservation-exact).
            if eng.harvested:
                st.harvested_me_work += cycles
        else:
            st.ve_work += cycles
            if eng.harvested:
                st.harvested_ve_work += cycles
        # fairness bookkeeping counts ACTIVE (compute) cycles, like the
        # paper's per-vNPU performance counters (§III-E) — an
        # HBM-stalled tenant accrues little and keeps its priority,
        # which is precisely V10's Fig. 27 pathology.
        rt.active_cycles += (cycles if chunk.n_engines <= 1
                             else cycles / chunk.n_engines)
        # inlined chunk_done (hot path)
        rt.outstanding -= 1
        if rt.outstanding <= 0 and not rt.ready_me and not rt.ready_ve:
            rt._advance(t)

    def _complete_cohort(self, token: int, kind: str, tenant: int,
                         t: float) -> None:
        """Finish every member of a dispatch cohort (see
        ``Chunk.cohort``): each engine carries its OWN chunk, so the
        per-chunk accounting below is exactly what the reference's n
        separate completion events do, in the same (engine-id) order.
        Cohort chunks are compute-only (never bandwidth-registered),
        single-engine, and run un-harvested on the owner's own engines
        (so no squatter bookkeeping applies)."""
        pool = self.mes if kind == ME else self.ves
        rt = self.tenants[tenant]
        st = rt.stats
        dirty = self._dirty
        is_me = kind == ME
        n = 0
        freed = []
        for e in pool:
            if e.token != token:
                continue
            cycles = e.chunk.cycles
            e.token = -1
            e.chunk = None
            freed.append(e)
            if is_me:
                st.me_work += cycles
            else:
                st.ve_work += cycles
            rt.active_cycles += cycles
            n += 1
        # batched free-index merge: members normally share one owner
        # (they were dispatched off one owner bucket), and that bucket
        # is normally empty now (the members WERE its contents) — one
        # dict store replaces n shift inserts. A mid-flight resize can
        # break either assumption; fall back to per-member inserts.
        ow = freed[0].owner
        for e in freed:
            if e.owner != ow:
                ow = _MIXED
                break
        if ow is not _MIXED:
            d = self._free_me_own if is_me else self._free_ve_own
            lst = d.get(ow)
            if lst:
                for e in freed:
                    eid = e.eid
                    i = len(lst)
                    while i and lst[i - 1].eid > eid:
                        i -= 1
                    lst.insert(i, e)
            else:
                d[ow] = freed   # already in eid (pool-scan) order
            if is_me:
                self._nfree_me += n
            else:
                self._nfree_ve += n
            dirty.add(-1 if ow is None else ow)
        else:
            for e in freed:
                self._free_idx_add(e)
                dirty.add(-1 if e.owner is None else e.owner)
        # batched chunk_done: intermediate members can never trigger
        # _advance (outstanding still counts their in-flight siblings),
        # so one decrement + one check is state-identical to n calls
        rt.outstanding -= n
        if rt.outstanding <= 0 and not rt.ready_me and not rt.ready_ve:
            rt._advance(t)

    def _engines_of(self, chunk: Chunk,
                    eng: Optional[_Engine] = None) -> List[_Engine]:
        if eng is not None and chunk.n_dispatched == 1:
            return [eng]   # single-engine μTOp: no pool scan needed
        pool = self.mes if chunk.kind == ME else self.ves
        return [e for e in pool if e.chunk is chunk]

    # ------------------------------------------------------------------
    def _duration(self, chunk: Chunk, n_dispatched: int) -> float:
        rt = self.tenants[chunk.tenant]
        if self.fast_path:
            # the overwhelmingly common case — a compute-only μTOp —
            # IS its span; skip the key build entirely
            if rt.is_neuisa and chunk.hbm_bytes <= 0:
                return chunk.cycles + chunk.penalty
            pressure = (self._mem_pressure(chunk.tenant)
                        if chunk.hbm_bytes > 0 else (1, 1))
            # memoized per-(chunk shape, mem-pressure) span: identical
            # chunk shapes recur thousands of times per run (one per
            # μTOp replay), so the arithmetic is computed once per
            # key. VLIW ME spans also read ops[chunk.group_key] off
            # the live program — the (program id, group index) pair
            # stands in for that dereference in the key.
            key = (chunk.kind, chunk.cycles, chunk.hbm_bytes,
                   chunk.n_engines, n_dispatched, pressure, rt.is_neuisa,
                   (id(rt.cur_program), chunk.group_key)
                   if not rt.is_neuisa and chunk.kind == ME else None)
            span = self._span_memo.get(key)
            if span is None:
                span = self._span(chunk, rt, n_dispatched, pressure)
                self._span_memo[key] = span
            return span + chunk.penalty
        pressure = (self._mem_pressure(chunk.tenant)
                    if chunk.hbm_bytes > 0 else (1, 1))
        return self._span(chunk, rt, n_dispatched, pressure) + chunk.penalty

    def _span(self, chunk: Chunk, rt: _TenantRT, n_dispatched: int,
              pressure: Tuple[int, int]) -> float:
        if rt.is_neuisa:
            # μTOps are single-engine units (a VE μTOp was pre-split
            # into n_y slot chunks)
            span = chunk.cycles
        elif chunk.kind == ME:
            # VLIW op: rate fixed by its compiled-in ME count (Fig. 9 —
            # extra engines seized but unusable); drain VE work is
            # pipelined inside the op span.
            span = chunk.cycles / max(chunk.n_engines, 1)
            op = rt.cur_program.ops[chunk.group_key]
            frac = min(chunk.cycles / max(op.me_cycles, EPS), 1.0)
            span = max(span, op.ve_cycles * frac / max(rt.cur_program.n_y, 1))
        else:
            # VLIW VE op addresses every VE slot granted at dispatch
            span = chunk.cycles / max(n_dispatched, 1)
        if chunk.hbm_bytes > 0:
            # two-level fair sharing (computed at dispatch): HBM BW is
            # split across tenants with in-flight DMA (§III-B "fair
            # sharing of HBM bandwidth"), then across THIS tenant's
            # own in-flight memory chunks — so partitioning one
            # operator into μTOps never manufactures bandwidth.
            n_ten, n_mine = pressure
            bw = (self.core.hbm_bytes_per_cycle * self.hbm_scale
                  / n_ten / n_mine)
            span = max(span, chunk.hbm_bytes / bw)
        return span

    def _mem_pressure(self, tenant: int) -> Tuple[int, int]:
        """Max-min fair HBM sharing: only BANDWIDTH-BOUND in-flight
        chunks contend (a compute-bound neighbor's trickle of weight
        streaming doesn't halve a decode tenant's BW — §V-F: the
        collocated LLM 'suffers negligible overhead'). The fast path
        reads the incrementally-maintained per-tenant contender
        counts; the reference path recomputes them with an engine
        scan — same answer, proven equal by the fig25 fast-path
        row."""
        if self.fast_path:
            per = self._bw_per_tenant
            return (len(per) + (0 if tenant in per else 1),
                    1 + per.get(tenant, 0))
        bpc = self.core.hbm_bytes_per_cycle * self.hbm_scale
        tenants = {tenant}
        mine = 1  # the chunk being dispatched
        seen = set()
        for e in self.mes + self.ves:
            c = e.chunk
            if c is None or c.hbm_bytes <= 0 or id(c) in seen:
                continue
            seen.add(id(c))
            # compute-bound chunks are not HBM contenders. Ties DO
            # count: memory-paced μTOp chunks sit exactly at the
            # boundary, and exempting them would let k sibling chunks
            # stream at k x BW.
            if c.hbm_bytes / bpc < c.cycles:
                continue
            tenants.add(e.tenant)
            if e.tenant == tenant:
                mine += 1
        return len(tenants), mine

    def _bw_unregister(self, chunk: Chunk) -> None:
        ten = self._bw_inflight.pop(id(chunk), None)
        if ten is not None:
            n = self._bw_per_tenant[ten] - 1
            if n <= 0:
                del self._bw_per_tenant[ten]
            else:
                self._bw_per_tenant[ten] = n

    def _unsquat(self, eng: _Engine, tenant: int) -> None:
        """Engine stops running a chunk: drop its squatter entry if it
        was running a foreign tenant's work on an owned engine."""
        owner = eng.owner
        if owner is not None and owner != tenant and self._squat:
            n = self._squat.get(owner, 0) - 1
            if n <= 0:
                self._squat.pop(owner, None)
            else:
                self._squat[owner] = n

    def _recount_squat(self) -> None:
        """Rebuild the squatter counts from engine state (ownership
        was reassigned with chunks in flight — rare)."""
        self._squat.clear()
        for e in self.mes + self.ves:
            if (e.chunk is not None and e.owner is not None
                    and e.owner != e.tenant):
                self._squat[e.owner] = self._squat.get(e.owner, 0) + 1

    # ------------------------------------------------------------------
    # policy-facing dispatch API (stable for third-party policies)
    # ------------------------------------------------------------------
    def dispatch(self, chunk: Chunk, engines: List[_Engine], t: float,
                 harvested: bool = False) -> None:
        """Start ``chunk`` on one or more free engines at time ``t``."""
        if len(engines) == 1:
            # single source of truth for the 1-engine case
            self._dispatch1(chunk, engines[0], t, harvested)
            return
        token = next(self._tok)
        n = len(engines)
        dur = self._duration(chunk, n)
        chunk.n_dispatched = n
        end = t + dur
        fast = self.fast_path
        inc = self._inc
        for e in engines:
            if inc:
                self._free_idx_remove(e)
            e.token = token
            e.chunk = chunk
            e.tenant = chunk.tenant
            e.start = t
            e.end = end
            e.harvested = harvested
            if fast and e.owner is not None and e.owner != chunk.tenant:
                self._squat[e.owner] = self._squat.get(e.owner, 0) + 1
        if fast:
            self._bw_register(chunk)
        lead = engines[0]
        heapq.heappush(
            self._heap, (end, next(self._seq), lead.kind, lead.eid, token))

    def _dispatch1(self, chunk: Chunk, e: _Engine, t: float,
                   harvested: bool = False) -> None:
        """Single-engine dispatch (same semantics as
        ``dispatch(chunk, [e], t, harvested)``, which delegates here —
        the hot schedule pass calls it directly to skip the list
        plumbing, and the common compute-only μTOp duration is
        inlined)."""
        token = next(self._tok)
        fast = self.fast_path
        if (fast and chunk.hbm_bytes <= 0
                and self.tenants[chunk.tenant].is_neuisa):
            dur = chunk.cycles + chunk.penalty
        else:
            dur = self._duration(chunk, 1)
            if fast:
                self._bw_register(chunk)
        chunk.n_dispatched = 1
        if self._inc:
            self._free_idx_remove(e)
        e.token = token
        e.chunk = chunk
        e.tenant = chunk.tenant
        e.start = t
        end = t + dur
        e.end = end
        e.harvested = harvested
        if fast and e.owner is not None and e.owner != chunk.tenant:
            self._squat[e.owner] = self._squat.get(e.owner, 0) + 1
        heapq.heappush(
            self._heap, (end, next(self._seq), e.kind, e.eid, token))

    def _bw_register(self, chunk: Chunk) -> None:
        """Incremental HBM-contention bookkeeping (fast path): the
        chunk is a bandwidth contender iff it is memory-paced (ties
        count — see :meth:`_mem_pressure`)."""
        if (chunk.hbm_bytes > 0
                and chunk.hbm_bytes / self._bpc >= chunk.cycles):
            self._bw_inflight[id(chunk)] = chunk.tenant
            self._bw_per_tenant[chunk.tenant] = \
                self._bw_per_tenant.get(chunk.tenant, 0) + 1

    def preempt(self, eng: _Engine, t: float,
                blocked_owner: Optional[int] = None) -> None:
        """Preempt the chunk on `eng` (and sibling engines for VLIW
        ops): remaining work returns to its tenant's ready queue with
        the context-switch penalty; engines drain for ctx cycles.
        ``blocked_owner``: tenant reclaiming its engine — it eats the
        drain window (Table III 'blocked because harvested')."""
        chunk = eng.chunk
        if chunk.cohort:
            # members carry 1, the lead carries n: either way the
            # engine shares its completion token with siblings, and
            # preempting one would orphan the rest. Unreachable from
            # the in-tree policies (cohorts run on the owner's OWN
            # engines, which reclaim never targets) — fail loudly for
            # third-party policies instead of hanging.
            raise RuntimeError(
                "cannot preempt a cohort member: cohorts run on owner "
                "engines, which policies must not reclaim")
        engines = self._engines_of(chunk, eng)
        if self._bw_inflight:
            self._bw_unregister(chunk)
        # VE state is tiny vs the 256-cycle systolic drain (§III-G)
        ctx = float(self.core.ctx_switch_cycles if chunk.kind == ME else 32)
        # VLIW ops span every ME: their contexts drain serially through
        # the shared SRAM port — V10 "needs to preempt the entire
        # operator from ALL MEs" (§V-C), Neu10 only the harvested one.
        ctx *= len(engines)
        frac_done = (t - eng.start) / max(eng.end - eng.start, EPS)
        frac_done = min(max(frac_done, 0.0), 1.0)
        rt = self.tenants[eng.tenant]
        remaining = Chunk(
            chunk.tenant, chunk.kind, chunk.cycles * (1 - frac_done),
            chunk.hbm_bytes * (1 - frac_done), chunk.op_name,
            n_engines=chunk.n_engines, penalty=ctx,
            group_key=chunk.group_key, from_me_group=chunk.from_me_group,
            phase=chunk.phase)
        (rt.ready_me if chunk.kind == ME else rt.ready_ve).insert(0, remaining)
        rt.stats.preemptions += 1
        if blocked_owner is not None:
            self.tenants[blocked_owner].stats.reclaim_blocked += ctx
        # account the completed fraction as useful work
        if chunk.kind == ME:
            rt.stats.me_work += chunk.cycles * frac_done
            if eng.harvested:
                rt.stats.harvested_me_work += chunk.cycles * frac_done
        else:
            rt.stats.ve_work += chunk.cycles * frac_done
        rt.active_cycles += chunk.cycles * frac_done / max(chunk.n_engines, 1)
        # engines drain their state for ctx cycles
        token = next(self._tok)
        for e in engines:
            self._unsquat(e, chunk.tenant)
            e.token = token
            e.chunk = None
            e.tenant = -1
            e.start = t
            e.end = t + ctx
            e.harvested = False
        heapq.heappush(
            self._heap,
            (t + ctx, next(self._seq), engines[0].kind, engines[0].eid,
             token))
        # the preempted chunk's pending completion entry is now stale
        # (engines carry the drain token); its remaining work landed
        # back on the tenant's ready queue
        self._stale += 1
        if self._inc:
            self._dirty.add(chunk.tenant)
        self._maybe_compact()

    # back-compat aliases (pre-registry internal names)
    _dispatch = dispatch
    _preempt = preempt

    # ------------------------------------------------------------------
    # incremental scheduling plumbing (see docs/architecture.md,
    # "Event engine")
    # ------------------------------------------------------------------
    def mark_dirty(self, idx: int = -1) -> None:
        """Force the next schedule pass to run. Third-party policies
        using ``schedule_incremental`` must call this whenever they
        stash enabling state OUTSIDE the ready queues / engine pools
        (those are marked automatically); ``idx`` is the affected
        tenant, -1 for a global change."""
        self._dirty.add(idx)

    def _rebuild_free_index(self) -> None:
        """Recompute the per-owner free-engine index from engine state
        (ownership changed — tenant add/remove/resize)."""
        me: Dict[Optional[int], List[_Engine]] = {}
        for e in self.mes:
            if e.token < 0:
                me.setdefault(e.owner, []).append(e)
        ve: Dict[Optional[int], List[_Engine]] = {}
        for e in self.ves:
            if e.token < 0:
                ve.setdefault(e.owner, []).append(e)
        self._free_me_own = me
        self._free_ve_own = ve
        self._nfree_me = sum(len(v) for v in me.values())
        self._nfree_ve = sum(len(v) for v in ve.values())

    def _free_idx_add(self, e: _Engine) -> None:
        """Engine freed: insert into its owner's bucket keeping eid
        order (buckets are at most pool-sized, so a shift insert beats
        re-sorting)."""
        if e.kind == ME:
            d = self._free_me_own
            self._nfree_me += 1
        else:
            d = self._free_ve_own
            self._nfree_ve += 1
        lst = d.get(e.owner)
        if lst is None:
            d[e.owner] = [e]
            return
        eid = e.eid
        i = len(lst)
        while i and lst[i - 1].eid > eid:
            i -= 1
        lst.insert(i, e)

    def _free_idx_remove(self, e: _Engine) -> None:
        """Engine goes busy: drop it from its owner's bucket."""
        if e.kind == ME:
            self._free_me_own[e.owner].remove(e)
            self._nfree_me -= 1
        else:
            self._free_ve_own[e.owner].remove(e)
            self._nfree_ve -= 1

    def _maybe_compact(self) -> None:
        if (self._stale >= self.HEAP_COMPACT_MIN
                and self._stale * 2 >= len(self._heap)):
            self._compact_heap()

    def _compact_heap(self) -> None:
        """Sweep lazily-deleted entries (stale engine tokens) out of
        the event heap. Pop ORDER of surviving entries is unchanged —
        the heap orders on the (t, seq) prefix, which compaction
        preserves — so results are identical; only ``max_events``
        accounting changes (stale pops no longer count).

        Compaction mutates ``self._heap`` IN PLACE: the event loop and
        scheduling passes bind local aliases to the heap list, and a
        compaction can fire mid-pass (``preempt`` during a reclaim).
        Rebinding to a fresh list would split pushes and pops across
        two heaps and silently drop live events."""
        mes, ves = self.mes, self.ves
        keep = []
        for ev in self._heap:
            kind = ev[2]
            if kind == ME:
                if mes[ev[3]].token != ev[4]:
                    continue
            elif kind == VE:
                if ves[ev[3]].token != ev[4]:
                    continue
            keep.append(ev)
        self._heap[:] = keep
        heapq.heapify(self._heap)
        self._stale = 0

    def _schedule(self, t: float) -> None:
        if self._inc:
            d = self._dirty
            if not d:
                return
            # snapshot-and-clear keeps the set OBJECT alive (tenant
            # sinks hold a reference); marks made during the pass
            # (reclaim preemptions) land in the live set and trigger
            # the next one
            snap = set(d)
            d.clear()
            self._inc_fn(self, t, snap)
        else:
            self.policy_obj.schedule(self, t)


# ----------------------------------------------------------------------
def run_collocation(
    specs: Sequence[TenantSpec],
    policy: PolicyLike,
    core: NPUCoreConfig = DEFAULT_CORE,
    hbm_scale: float = 1.0,
) -> SimResult:
    return Simulator(specs, policy=policy, core=core,
                     hbm_scale=hbm_scale).run()
