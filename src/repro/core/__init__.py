"""Neu10: the paper's primary contribution.

vNPU abstraction (§III-A) -> allocator (§III-B) -> mapper (§III-C) ->
NeuISA μTOp compiler (§III-D) -> hardware scheduler + event-driven
simulator (§III-E/G) with the PMT / V10 / Neu10-NH baselines (§V-A).
"""
from repro.core.allocator import (
    Allocation,
    allocate_eus,
    allocate_for_trace,
    eu_utilization,
    normalized_exec_time,
    optimal_ratio,
    place_phase_pair,
)
from repro.core.allocator import pick_evacuation_core
from repro.core.fabric import (
    FabricLink,
    FabricTopology,
    Placement,
    random_phase_pair,
)
from repro.core.faults import FaultEvent, FaultSchedule
from repro.core.compiler import (
    CompiledPhase,
    CompiledRequestPlan,
    ProgramCache,
    compile_neuisa,
    compile_request_plan,
    compile_vliw,
)
from repro.core.mapper import VNPUManager
from repro.core.stats import mean, p50, p95, p99, percentile
from repro.core.neuisa import MuTOp, MuTOpGroup, NeuISAProgram, VLIWProgram
from repro.core.policies import (
    SchedulerPolicy,
    UnknownPolicyError,
    available_policies,
    get_policy,
    register_policy,
    resolve_policy,
)
from repro.core.simulator import (
    SimResult,
    Simulator,
    TenantSpec,
    run_collocation,
)
from repro.core.vnpu import PRESETS, VNPU, VNPUConfig, VNPUState

__all__ = [
    "Allocation",
    "allocate_eus",
    "allocate_for_trace",
    "eu_utilization",
    "normalized_exec_time",
    "optimal_ratio",
    "place_phase_pair",
    "pick_evacuation_core",
    "FabricLink",
    "FaultEvent",
    "FaultSchedule",
    "FabricTopology",
    "Placement",
    "random_phase_pair",
    "CompiledPhase",
    "CompiledRequestPlan",
    "ProgramCache",
    "compile_neuisa",
    "compile_request_plan",
    "compile_vliw",
    "percentile",
    "mean",
    "p50",
    "p95",
    "p99",
    "VNPUManager",
    "MuTOp",
    "MuTOpGroup",
    "NeuISAProgram",
    "VLIWProgram",
    "SchedulerPolicy",
    "UnknownPolicyError",
    "available_policies",
    "get_policy",
    "register_policy",
    "resolve_policy",
    "SimResult",
    "Simulator",
    "TenantSpec",
    "run_collocation",
    "PRESETS",
    "VNPU",
    "VNPUConfig",
    "VNPUState",
]
