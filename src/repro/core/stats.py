"""Shared latency-series statistics.

One percentile convention for the whole stack (simulator TenantStats,
serve-layer reports, autoscaler hooks): the nearest-rank method,
``x[ceil(q * n) - 1]`` on the sorted series. This is the exact index
arithmetic the seed ``TenantStats.p95`` used, extracted so every layer
agrees bit-for-bit on what "p95" means.

These helpers are unit-agnostic: they return a value in whatever unit
the input series carries. By convention the simulator feeds them
CYCLES and the serve layer feeds them MILLISECONDS — callers must not
mix series from the two domains (convert with ``1e3 / freq_hz`` at
the TenantReport boundary, nowhere else).
"""
from __future__ import annotations

import math
from typing import Sequence


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of ``xs`` (q in [0, 1]); 0.0 if empty."""
    if not xs:
        return 0.0
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"percentile q={q} outside [0, 1]")
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, math.ceil(q * len(ys)) - 1))
    return ys[i]


def p50(xs: Sequence[float]) -> float:
    return percentile(xs, 0.50)


def p95(xs: Sequence[float]) -> float:
    return percentile(xs, 0.95)


def p99(xs: Sequence[float]) -> float:
    return percentile(xs, 0.99)


def mean(xs: Sequence[float]) -> float:
    return sum(xs) / len(xs) if xs else 0.0
