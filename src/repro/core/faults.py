"""Deterministic fault injection for the cluster fabric.

Production NPU pools lose cores, links, and HBM rows; the serving
stack's job is to keep tenants alive through it. This module is the
*chaos source*: a :class:`FaultSchedule` is a seeded, pre-materialised
list of :class:`FaultEvent`\\ s that :class:`repro.serve.session.ServingSession`
interleaves with simulator progress at exact times — two runs with
the same schedule replay the same faults, so every failover path is
reproducible and testable. Times are unit-agnostic here; the serving
session interprets ``at`` / ``recovery`` as SECONDS of simulated time
(its public API domain) and converts to cycles on ingest.

Fault taxonomy (mirrors the failure modes of real multi-chip boards):

* ``core_down`` — a pNPU core stops executing. Transient faults carry
  a ``recovery`` horizon (the core returns that many cycles later via
  an auto-generated ``core_up``); ``recovery == 0`` means permanent.
* ``core_up`` — a previously failed core rejoins the pool.
* ``link_degrade`` — a fabric link's bandwidth is scaled by
  ``bw_scale`` (``0`` removes the link entirely: an outage).
* ``link_restore`` — the link returns to its base bandwidth.
* ``hbm_fault`` — ``n_segments`` HBM isolation segments of the vNPU
  resident on ``core`` fault away; the tenant shrinks through the
  constrained-resize path instead of dying.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple

__all__ = ["FaultEvent", "FaultSchedule"]

_KINDS = frozenset({
    "core_down", "core_up", "link_degrade", "link_restore", "hbm_fault",
})


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault, pinned to an absolute time (seconds of
    simulated time when consumed through the serving session)."""

    at: float                       # time the fault fires
    kind: str                       # member of the taxonomy above
    core: int = -1                  # core_down/core_up/hbm_fault target
    link: Tuple[int, int] = (-1, -1)   # link_degrade/link_restore target
    bw_scale: float = 0.0           # link_degrade: 0 -> outage
    recovery: float = 0.0           # core_down: delay until auto core_up
    n_segments: int = 1             # hbm_fault: segments lost

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {sorted(_KINDS)}")
        if self.at < 0:
            raise ValueError(f"fault time must be >= 0, got {self.at}")
        if self.kind in ("core_down", "core_up", "hbm_fault") and self.core < 0:
            raise ValueError(f"{self.kind} needs a core index")
        if self.kind in ("link_degrade", "link_restore"):
            a, b = self.link
            if a < 0 or b < 0 or a == b:
                raise ValueError(f"{self.kind} needs a (src, dst) link")
        if self.kind == "link_degrade" and self.bw_scale < 0:
            raise ValueError("bw_scale must be >= 0 (0 = outage)")
        if self.kind == "hbm_fault" and self.n_segments < 1:
            raise ValueError("hbm_fault needs n_segments >= 1")

    @property
    def transient(self) -> bool:
        return self.kind == "core_down" and self.recovery > 0


class FaultSchedule:
    """An ordered, replayable list of :class:`FaultEvent`."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: List[FaultEvent] = sorted(
            events, key=lambda e: (e.at, e.kind, e.core, e.link))

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    @classmethod
    def chaos(cls, *, horizon: float, n_cores: int,
              links: Sequence[Tuple[int, int]] = (),
              seed: int = 0,
              core_fault_rate: float = 0.0,
              link_fault_rate: float = 0.0,
              hbm_fault_rate: float = 0.0,
              transient_frac: float = 1.0,
              recovery: float = 0.0,
              bw_scale: float = 0.25,
              link_outage_frac: float = 0.0,
              start: float = 0.0) -> "FaultSchedule":
        """Seeded Poisson chaos over ``[start, horizon)``.

        Each ``*_fault_rate`` is an expected fault count per
        ``horizon - start`` cycles (a rate of 2.0 injects ~2 such
        faults over the window). ``transient_frac`` of core faults
        are transient with the given ``recovery`` horizon; degraded
        links are scaled to ``bw_scale`` except a ``link_outage_frac``
        share that go fully dark, and every link fault auto-restores
        halfway to the horizon end. Same seed -> same schedule."""
        if horizon <= start:
            raise ValueError("chaos needs horizon > start")
        rng = random.Random(seed)
        span = horizon - start
        out: List[FaultEvent] = []

        def _times(rate: float) -> List[float]:
            if rate <= 0:
                return []
            ts, t = [], start
            while True:
                t += rng.expovariate(rate / span)
                if t >= horizon:
                    return ts
                ts.append(t)

        for t in _times(core_fault_rate):
            core = rng.randrange(n_cores)
            rec = recovery if rng.random() < transient_frac else 0.0
            out.append(FaultEvent(at=t, kind="core_down", core=core,
                                  recovery=rec))
        for t in _times(link_fault_rate):
            if not links:
                break
            link = links[rng.randrange(len(links))]
            scale = 0.0 if rng.random() < link_outage_frac else bw_scale
            out.append(FaultEvent(at=t, kind="link_degrade", link=link,
                                  bw_scale=scale))
            t_up = t + (horizon - t) * 0.5
            if t_up < horizon:
                out.append(FaultEvent(at=t_up, kind="link_restore",
                                      link=link))
        for t in _times(hbm_fault_rate):
            out.append(FaultEvent(at=t, kind="hbm_fault",
                                  core=rng.randrange(n_cores)))
        return cls(out)
