"""vNPU allocator (§III-B): pick the ME/VE split for a tenant.

Implements the paper's Amdahl-style model verbatim:

  Eq. 1  T(n_m, n_v)   = (1-v)/n_m + (1-m)/n_v + (m+v-1)/min(n_m,n_v)
  Eq. 2  U = T_h / T,   T_h = (m+v)/(n_m+n_v)
  Eq. 4  k* = n_m/n_v = sqrt(m/(1-m))      if m < 0.5
                       sqrt((1-v)/v)       if v < 0.5
                       1                   otherwise

(m, v) are the ME/VE active-time fractions profiled on a 1ME+1VE
core at compile time (``WorkloadTrace.profile_mv``). Integer splits
are chosen by evaluating U over the feasible lattice — the continuous
k* only seeds the search, matching the paper's "approximate the
allocated quantity ratio" language.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

from repro.core.vnpu import VNPUConfig
from repro.npu.cost_model import WorkloadTrace
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.fabric import FabricTopology


def normalized_exec_time(m: float, v: float, n_m: int, n_v: int) -> float:
    """Paper Eq. 1. Requires m+v >= 1 (at least one engine active)."""
    if n_m < 1 or n_v < 1:
        return math.inf
    both = max(m + v - 1.0, 0.0)
    only_me = max(1.0 - v, 0.0)
    only_ve = max(1.0 - m, 0.0)
    return only_me / n_m + only_ve / n_v + both / min(n_m, n_v)


def hypothetical_exec_time(m: float, v: float, n_m: int, n_v: int) -> float:
    return (m + v) / (n_m + n_v)


def eu_utilization(m: float, v: float, n_m: int, n_v: int) -> float:
    """Paper Eq. 2: ratio of hypothetical to modeled execution time."""
    t = normalized_exec_time(m, v, n_m, n_v)
    if not math.isfinite(t) or t <= 0:
        return 0.0
    return hypothetical_exec_time(m, v, n_m, n_v) / t


def optimal_ratio(m: float, v: float) -> float:
    """Paper Eq. 4 closed form (continuous k* = n_m / n_v)."""
    if m < 0.5:
        return math.sqrt(m / (1.0 - m)) if m > 0 else 1e-6
    if v < 0.5:
        return math.sqrt((1.0 - v) / v) if v > 0 else 1e6
    return 1.0


@dataclass(frozen=True)
class Allocation:
    n_me: int
    n_ve: int
    utilization: float
    k_star: float
    m: float
    v: float

    def as_vnpu_config(self, trace: Optional[WorkloadTrace] = None,
                       core: NPUCoreConfig = DEFAULT_CORE,
                       priority: float = 1.0) -> VNPUConfig:
        sram, hbm = 0, 0
        if trace is not None:
            sram, hbm = estimate_memory(trace, self.n_me, core)
        return VNPUConfig(n_me=self.n_me, n_ve=self.n_ve,
                          sram_bytes=sram, hbm_bytes=hbm, priority=priority)


def allocate_eus(m: float, v: float, total_eus: int,
                 core: NPUCoreConfig = DEFAULT_CORE) -> Allocation:
    """Split a total-EU budget (the pay-as-you-go knob) into MEs/VEs.

    Evaluates Eq. 2 over every feasible (n_m, n_v) with
    n_m + n_v = total_eus, n_m,n_v >= 1, capped by the pNPU core —
    equivalently, rounds the Eq. 4 continuous optimum to the best
    integer lattice point.
    """
    if total_eus < 2:
        raise ValueError("need at least 2 EUs (1 ME + 1 VE)")
    k_star = optimal_ratio(m, v)
    best: Optional[Tuple[float, int, int]] = None
    for n_m in range(1, total_eus):
        n_v = total_eus - n_m
        if n_m > core.n_me or n_v > core.n_ve:
            continue
        u = eu_utilization(m, v, n_m, n_v)
        if best is None or u > best[0] + 1e-12:
            best = (u, n_m, n_v)
    if best is None:  # budget exceeds a single core in every split
        n_m = min(core.n_me, max(1, round(total_eus * k_star / (1 + k_star))))
        n_v = min(core.n_ve, max(1, total_eus - n_m))
        best = (eu_utilization(m, v, n_m, n_v), n_m, n_v)
    return Allocation(best[1], best[2], best[0], k_star, m, v)


def allocate_for_trace(trace: WorkloadTrace, total_eus: int,
                       core: NPUCoreConfig = DEFAULT_CORE) -> Allocation:
    m, v = trace.profile_mv()
    return allocate_eus(m, v, total_eus, core)


def place_phase_pair(topology: "FabricTopology",
                     loads: Optional[Sequence[float]] = None,
                     kv_bytes: float = 0.0,
                     distinct: bool = True) -> Tuple[int, int]:
    """Topology-aware companion to the Eq. 1-4 split: pick the
    (prefill_core, decode_core) pair for a chatty phase pair — a
    generative tenant's prefill pool hands every request's KV to its
    decode pool, so the pools must land on NEIGHBORING cores.

    Minimizes, in order: the priced hand-off cost
    (``topology.transfer_cycles`` of one request's ``kv_bytes`` —
    hop count x bytes over the link model), then the combined load of
    the two cores (``loads``, any per-core utilization measure; the
    control plane passes EU + memory used fractions), then the core
    ids (deterministic tie-break). ``distinct`` keeps the pools on
    separate cores (the disaggregation invariant) whenever the fabric
    has more than one."""
    n = topology.n_cores
    if loads is not None and len(loads) != n:
        raise ValueError(
            f"loads has {len(loads)} entries for {n} cores")
    best_key: Optional[Tuple] = None
    best = (0, 0)
    for a in range(n):
        for b in range(n):
            if distinct and n > 1 and a == b:
                continue
            cost = topology.transfer_cycles(a, b, kv_bytes)
            if not math.isfinite(cost):
                continue              # disconnected: never pair them
            load = (loads[a] + loads[b]) if loads is not None else 0.0
            key = (cost, load, a, b)
            if best_key is None or key < best_key:
                best_key, best = key, (a, b)
    if best_key is None:
        raise ValueError("no connected core pair in the topology")
    return best


def pick_evacuation_core(topology: "FabricTopology", src: int,
                         healthy: Sequence[int],
                         loads: Optional[Sequence[float]] = None,
                         kv_bytes: float = 0.0) -> Optional[int]:
    """Failover companion to :func:`place_phase_pair`: pick the core a
    whole vNPU evacuates to after ``src`` faults.

    Minimizes, in order: the priced bulk-transfer cost of the vNPU's
    live occupancy (``topology.transfer_cycles(src, dst, kv_bytes)``
    — an unreachable destination still qualifies, but only after every
    reachable one, since the state must then be rebuilt rather than
    copied), then the destination load, then the core id. ``src``
    itself and non-``healthy`` cores are never candidates. Returns
    ``None`` when no healthy destination exists (the caller falls
    back to suspend/restart)."""
    best_key: Optional[Tuple] = None
    best: Optional[int] = None
    for dst in healthy:
        if dst == src:
            continue
        cost = topology.transfer_cycles(src, dst, kv_bytes)
        load = loads[dst] if loads is not None else 0.0
        key = (0 if math.isfinite(cost) else 1,
               cost if math.isfinite(cost) else 0.0, load, dst)
        if best_key is None or key < best_key:
            best_key, best = key, dst
    return best


def credit_weighted_fill(asks: Sequence[Tuple[str, float, int, int]],
                         free_eus: int, free_segments: int,
                         total_eus: int, total_segments: int,
                         ) -> List[str]:
    """Credit-weighted DRF/knapsack over the fleet's two scarce
    resources — the admission companion to the Eq. 1-4 split. Each
    ask is ``(name, credit, eus, hbm_segments)``; the fill ranks asks
    by credit per dominant share (DRF's scalar:
    ``max(eus/total_eus, segs/total_segs)``) and greedily grants
    while the free pool lasts, skipping asks that no longer fit
    (classic knapsack greedy — a big low-density ask never blocks a
    small affordable one behind it).

    Tie-breaks, in order (all deterministic): higher credit-per-
    dominant-share, then higher absolute credit (of two equal-density
    asks the longer-accrued account goes first), then ascending name.
    Returns the granted names in drain order."""
    def density(credit: float, eus: int, segs: int) -> float:
        dom = max(eus / total_eus if total_eus else 0.0,
                  segs / total_segments if total_segments else 0.0)
        return credit / dom if dom > 0 else math.inf
    ranked = sorted(asks, key=lambda a: (-density(a[1], a[2], a[3]),
                                         -a[1], a[0]))
    granted: List[str] = []
    for name, _credit, eus, segs in ranked:
        if eus <= free_eus and segs <= free_segments:
            granted.append(name)
            free_eus -= eus
            free_segments -= segs
    return granted


def estimate_memory(trace: WorkloadTrace, n_me: int,
                    core: NPUCoreConfig = DEFAULT_CORE) -> Tuple[int, int]:
    """§III-B memory allocation: HBM from the compiler's footprint
    estimate; SRAM proportional to allocated MEs (larger tile sizes).
    Rounded up to the isolation segment granularity."""
    sram = int(core.sram_bytes * n_me / core.n_me)
    sram = -(-sram // core.sram_segment) * core.sram_segment
    hbm = -(-int(trace.hbm_footprint) // core.hbm_segment) * core.hbm_segment
    hbm = min(hbm, core.hbm_bytes)
    return sram, hbm
