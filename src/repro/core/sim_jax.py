"""Vectorized fleet simulator (beyond-paper): the Neu10 spatial
scheduler as a fluid-flow model in pure JAX.

The Python simulator (simulator.py) is the discrete-event ORACLE.
This module trades per-μTOp discreteness for a group-granular fluid
approximation that is jit-able and vmap-able: a whole fleet
evaluation — every workload pair × every HBM-bandwidth point × both
spatial policies — runs as ONE XLA program. Cloud operators use this
shape of model for capacity planning / collocation search, where the
question is "which pairs co-locate well at what EU splits", not exact
per-request tails.

Model
-----
Per tenant: a compiled NeuISA program flattened to per-group arrays
  me[g]    total ME work (cycles), parallel up to par[g] engines
  ve[g]    total VE work (cycles), parallel up to n_y engines
  hbm[g]   HBM bytes
Groups execute sequentially; within a group ME/VE/HBM proceed
concurrently (the μTOp + operation schedulers' pipelining). Rates:
  me_rate = min(par_remaining, own_me + harvestable_from_other)
  ve_rate = min(n_y_cap,       own_ve + harvestable)
  hbm_rate = BW / #{tenants with hbm remaining}
Harvestable = the other tenant's idle engines (zero under neu10_nh).
Advance to the next group-completion event; closed-loop requests.

Validated against the discrete oracle in tests/test_sim_jax.py:
policy orderings match and makespans agree within a documented
tolerance (the fluid model ignores preemption quanta = 256-cycle
drains, which are <1% of group spans for real traces).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.neuisa import NeuISAProgram
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig

BIG = 1e30


@dataclass
class FluidProgram:
    """Per-group arrays (padded to a common length for vmap)."""

    me: np.ndarray     # (G,) cycles
    ve: np.ndarray     # (G,) cycles
    hbm: np.ndarray    # (G,) bytes
    par: np.ndarray    # (G,) max engines the group's μTOps can use
    n_groups: int

    @staticmethod
    def from_program(prog: NeuISAProgram) -> "FluidProgram":
        me, ve, hbm, par = [], [], [], []
        for g in prog.groups:
            me.append(g.me_work)
            ve.append(g.ve_work)
            hbm.append(sum(u.hbm_bytes for u in g.all_utops()))
            par.append(max(len(g.me_utops), 1))
        return FluidProgram(
            np.asarray(me, np.float64), np.asarray(ve, np.float64),
            np.asarray(hbm, np.float64), np.asarray(par, np.float64),
            len(prog.groups))

    def padded(self, G: int) -> "FluidProgram":
        def pad(a):
            return np.pad(a, (0, G - len(a)))

        return FluidProgram(pad(self.me), pad(self.ve), pad(self.hbm),
                            np.pad(self.par, (0, G - len(self.par)),
                                   constant_values=1.0),
                            self.n_groups)


def pack_pair(p1: NeuISAProgram, p2: NeuISAProgram):
    """-> dict of (2, G) arrays + (2,) group counts."""
    f1, f2 = FluidProgram.from_program(p1), FluidProgram.from_program(p2)
    G = max(f1.n_groups, f2.n_groups)
    f1, f2 = f1.padded(G), f2.padded(G)
    stack = lambda a, b: jnp.asarray(np.stack([a, b]))
    return {
        "me": stack(f1.me, f2.me),
        "ve": stack(f1.ve, f2.ve),
        "hbm": stack(f1.hbm, f2.hbm),
        "par": stack(f1.par, f2.par),
        "n_groups": jnp.asarray([f1.n_groups, f2.n_groups], jnp.int32),
    }


def simulate_pair(
    prog,                      # dict from pack_pair (optionally vmapped)
    alloc_me: jax.Array,       # (2,) MEs per vNPU
    alloc_ve: jax.Array,       # (2,)
    n_requests: int,
    harvest: bool = True,
    hbm_scale: jax.Array = 1.0,
    core: NPUCoreConfig = DEFAULT_CORE,
    max_events: int = 200_000,
):
    """Returns dict: makespan (cycles), per-tenant request throughput,
    me/ve busy-cycle utilizations."""
    n_y = jnp.asarray(float(core.n_ve))
    bw0 = core.hbm_bytes_per_cycle * hbm_scale

    def current(prog_field, gidx):
        return jax.vmap(lambda a, i: a[jnp.minimum(i, a.shape[0] - 1)])(
            prog_field, gidx)

    def rates(state):
        gidx, rem_me, rem_ve, rem_hbm, done = state[:5]
        par = current(prog["par"], gidx)
        # ME demand of each tenant (0 once done)
        want = jnp.where(done | (rem_me <= 0), 0.0, par)
        own = jnp.minimum(want, alloc_me)
        idle = jnp.maximum(alloc_me - want, 0.0)
        if harvest:
            surplus = jnp.maximum(want - alloc_me, 0.0)
            grant = jnp.minimum(surplus, idle[::-1])
            me_rate = own + grant
        else:
            me_rate = own
        # VE: same policy over the VE pool
        ve_want = jnp.where(done | (rem_ve <= 0), 0.0, n_y)
        ve_own = jnp.minimum(ve_want, alloc_ve)
        ve_idle = jnp.maximum(alloc_ve - ve_want, 0.0)
        if harvest:
            ve_rate = ve_own + jnp.minimum(
                jnp.maximum(ve_want - alloc_ve, 0.0), ve_idle[::-1])
        else:
            ve_rate = ve_own
        # HBM: fair share among tenants with demand
        has_mem = (~done) & (rem_hbm > 0)
        n_mem = jnp.maximum(jnp.sum(has_mem), 1)
        hbm_rate = jnp.where(has_mem, bw0 / n_mem, 0.0)
        return me_rate, ve_rate, hbm_rate

    def finish_time(rem, rate):
        return jnp.where(rem > 0, rem / jnp.maximum(rate, 1e-12), 0.0)

    def cond(carry):
        state, t, ev = carry
        done = state[4]
        return (~jnp.all(done)) & (ev < max_events)

    def body(carry):
        state, t, ev = carry
        (gidx, rem_me, rem_ve, rem_hbm, done, reqs, req_t,
         me_busy, ve_busy) = state
        me_r, ve_r, hbm_r = rates(
            (gidx, rem_me, rem_ve, rem_hbm, done))
        # group completion = max over the three resources
        t_grp = jnp.maximum(
            finish_time(rem_me, me_r),
            jnp.maximum(finish_time(rem_ve, ve_r),
                        finish_time(rem_hbm, hbm_r)))
        t_grp = jnp.where(done, BIG, t_grp)
        dt = jnp.min(t_grp)
        dt = jnp.where(jnp.isfinite(dt) & (dt < BIG), dt, 0.0)
        rem_me = jnp.maximum(rem_me - me_r * dt, 0.0)
        rem_ve = jnp.maximum(rem_ve - ve_r * dt, 0.0)
        rem_hbm = jnp.maximum(rem_hbm - hbm_r * dt, 0.0)
        me_busy = me_busy + jnp.where(done, 0.0, me_r * dt)
        ve_busy = ve_busy + jnp.where(done, 0.0, ve_r * dt)
        t = t + dt
        finished = (~done) & (rem_me <= 0) & (rem_ve <= 0) & (rem_hbm <= 0)
        # advance finished tenants to next group (or next request)
        next_g = gidx + 1
        wrapped = next_g >= prog["n_groups"]
        reqs = reqs + jnp.where(finished & wrapped, 1, 0)
        req_t = jnp.where(finished & wrapped & (reqs <= n_requests),
                          t, req_t)
        next_g = jnp.where(wrapped, 0, next_g)
        gidx = jnp.where(finished, next_g, gidx)
        new_me = current(prog["me"], gidx)
        new_ve = current(prog["ve"], gidx)
        new_hbm = current(prog["hbm"], gidx)
        rem_me = jnp.where(finished, new_me, rem_me)
        rem_ve = jnp.where(finished, new_ve, rem_ve)
        rem_hbm = jnp.where(finished, new_hbm, rem_hbm)
        done = done | (reqs >= n_requests)
        return ((gidx, rem_me, rem_ve, rem_hbm, done, reqs, req_t,
                 me_busy, ve_busy), t, ev + 1)

    zero2 = jnp.zeros((2,))
    g0 = jnp.zeros((2,), jnp.int32)
    state0 = (
        g0,
        current(prog["me"], g0),
        current(prog["ve"], g0),
        current(prog["hbm"], g0),
        jnp.zeros((2,), bool),
        jnp.zeros((2,), jnp.int32),
        zero2,                      # completion time of the Nth request
        zero2, zero2,               # busy-cycle accumulators
    )
    (state, t, ev) = jax.lax.while_loop(cond, body, (state0, 0.0, 0))
    reqs, req_t = state[5], state[6]
    makespan = jnp.maximum(t, 1e-9)
    return {
        "makespan": makespan,
        "throughput": reqs.astype(jnp.float32)
        / (req_t / core.freq_hz + 1e-12),
        "me_util": jnp.sum(state[7]) / (core.n_me * makespan),
        "ve_util": jnp.sum(state[8]) / (core.n_ve * makespan),
        "events": ev,
    }


def pack_batch(pairs: List[Tuple[NeuISAProgram, NeuISAProgram]]):
    """Stack ``pack_pair`` outputs for a list of pairs into one
    (P, 2, G) pytree, padding every program to the widest group
    count (pad groups carry zero work and par=1, so they complete
    instantly and never affect rates)."""
    packed = [pack_pair(a, b) for a, b in pairs]
    G = max(p["me"].shape[1] for p in packed)

    def pad(p):
        w = G - p["me"].shape[1]
        return {
            k: (jnp.pad(v, ((0, 0), (0, w)),
                        constant_values=1.0 if k == "par" else 0.0)
                if k != "n_groups" else v)
            for k, v in p.items()
        }

    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[pad(p) for p in packed])


def fleet_sweep(
    pairs: List[Tuple[NeuISAProgram, NeuISAProgram]],
    alloc_me=(2, 2),
    alloc_ve=(2, 2),
    n_requests: int = 6,
    hbm_scales=(1.0,),
    harvest: bool = True,
    core: NPUCoreConfig = DEFAULT_CORE,
):
    """Every (pair × hbm_scale) cell in one jitted vmap nest."""
    batch = pack_batch(pairs)
    scales = jnp.asarray(hbm_scales)
    a_me = jnp.asarray(alloc_me, jnp.float32)
    a_ve = jnp.asarray(alloc_ve, jnp.float32)

    @jax.jit
    def run_all(batch, scales):
        def per_pair(prog):
            return jax.vmap(
                lambda s: simulate_pair(
                    prog, a_me, a_ve, n_requests, harvest=harvest,
                    hbm_scale=s, core=core))(scales)

        return jax.vmap(per_pair)(batch)

    return run_all(batch, scales)


def sweep_collocations(
    pairs: List[Tuple[NeuISAProgram, NeuISAProgram]],
    eu_splits,                 # S ((me1, me2), (ve1, ve2)) splits
    bw_points=(1.0,),          # B HBM-bandwidth scale factors
    n_requests: int = 6,
    harvest: bool = True,
    core: NPUCoreConfig = DEFAULT_CORE,
):
    """The whole collocation-search grid — every workload pair ×
    EU split × HBM-bandwidth point — as ONE jitted XLA program.

    This is the fleet-planning query behind the fig22/fig25 outer
    grids: "which pairs co-locate well at which splits, and how does
    that ranking move under memory pressure". The discrete simulator
    answers it one cell at a time (a fresh event loop per cell); here
    the full (P, S, B) lattice is a single three-level vmap nest over
    :func:`simulate_pair`, so XLA fuses every cell into one program
    and one device dispatch.

    ``eu_splits`` entries are ``((me1, me2), (ve1, ve2))`` engine
    counts, one per collocated tenant. Returns the
    :func:`simulate_pair` dict with every leaf shaped ``(P, S, B)``.
    """
    batch = pack_batch(pairs)
    mes = jnp.asarray([s[0] for s in eu_splits], jnp.float32)   # (S, 2)
    ves = jnp.asarray([s[1] for s in eu_splits], jnp.float32)   # (S, 2)
    scales = jnp.asarray(bw_points)                             # (B,)

    @jax.jit
    def run_all(batch, mes, ves, scales):
        def cell(prog, me, ve, s):
            return simulate_pair(prog, me, ve, n_requests,
                                 harvest=harvest, hbm_scale=s, core=core)

        def per_split(prog, me, ve):
            return jax.vmap(lambda s: cell(prog, me, ve, s))(scales)

        def per_pair(prog):
            return jax.vmap(
                lambda me, ve: per_split(prog, me, ve))(mes, ves)

        return jax.vmap(per_pair)(batch)

    return run_all(batch, mes, ves, scales)
