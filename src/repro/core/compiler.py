"""NeuISA compiler (§III-D "Compiler support").

Lowers a WorkloadTrace (operator list) into

* a ``NeuISAProgram``: each ME-bearing operator is partitioned into
  up to n_x ME μTOps (ROLLER-style tile partitioning — here the tile
  counts come from the cost model, which uses the same 128-aligned
  tiling as the Pallas kernels in ``repro/kernels``); each μTOp is
  compiled "for a fictional NPU with one ME and n_y VEs". Fused VE
  epilogues ride in the group's VE μTOp (pipelined with the MEs).
  Operators whose tiling cut the reduction dimension get a trailing
  VE-reduce group — the Fig. 16 overhead case (no ME/VE pipelining
  across the group boundary).

* a ``VLIWProgram``: the baseline lowering where each operator's
  instruction stream statically couples n_me_static MEs (what PMT/V10
  schedule).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core.neuisa import (
    ME,
    VE,
    MuTOp,
    MuTOpGroup,
    NeuISAProgram,
    VLIWOp,
    VLIWProgram,
)
from repro.npu.cost_model import (PIGGYBACK_POS_QUANT, Operator, RequestPlan,
                                  WorkloadTrace)
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig


class _SnippetTable:
    """Deduplicates μTOp code snippets (§III-D code-inflation note):
    μTOps that execute the same code (same op, same tile shape) share
    one snippet; only the μTOp index register differs."""

    def __init__(self):
        self._table: Dict[Tuple, int] = {}

    def get(self, key: Tuple) -> int:
        if key not in self._table:
            self._table[key] = len(self._table)
        return self._table[key]


def compile_neuisa(
    trace: WorkloadTrace,
    core: NPUCoreConfig = DEFAULT_CORE,
    n_x: Optional[int] = None,
    n_y: Optional[int] = None,
) -> NeuISAProgram:
    n_x = n_x or core.n_me
    n_y = n_y or core.n_ve
    snip = _SnippetTable()
    groups: List[MuTOpGroup] = []

    for op in trace.ops:
        if op.kind == "me":
            p = max(1, min(n_x, op.n_tiles))
            me_share = op.me_cycles / p
            hbm_share = op.hbm_bytes / p
            sid = snip.get((op.name.split("_")[0], "me", round(me_share, 3)))
            g = MuTOpGroup(op_name=op.name)
            for i in range(p):
                g.me_utops.append(
                    MuTOp(ME, me_share, hbm_share, op.name, sid)
                )
            if op.reduction_split:
                # drain VE work stays fused; the cross-partition SUM
                # must wait for every partial -> its own group.
                drain = max(op.ve_cycles - _reduce_cycles(op, p, core), 0.0)
                if drain > 0:
                    g.ve_utop = MuTOp(
                        VE, drain, 0.0, op.name,
                        snip.get((op.name, "ve_drain")),
                    )
                groups.append(g)
                groups.append(
                    MuTOpGroup(
                        ve_utop=MuTOp(
                            VE, _reduce_cycles(op, p, core), 0.0,
                            f"{op.name}:reduce",
                            snip.get((op.name, "ve_reduce")),
                        ),
                        op_name=f"{op.name}:reduce",
                    )
                )
            else:
                if op.ve_cycles > 0:
                    g.ve_utop = MuTOp(
                        VE, op.ve_cycles, 0.0, op.name,
                        snip.get((op.name, "ve_fused")),
                    )
                groups.append(g)
        else:  # ve / mem operator -> one VE μTOp group
            groups.append(
                MuTOpGroup(
                    ve_utop=MuTOp(
                        VE, op.ve_cycles, op.hbm_bytes, op.name,
                        snip.get((op.name.split("_")[0], "ve",
                                  round(op.ve_cycles, 3))),
                    ),
                    op_name=op.name,
                )
            )

    prog = NeuISAProgram(
        name=trace.name, groups=groups, n_x=n_x, n_y=n_y,
        source_ops=len(trace.ops),
    )
    prog.validate()
    return prog


def _reduce_cycles(op: Operator, p: int, core: NPUCoreConfig) -> float:
    """VE cycles to sum p partial outputs ((p-1) adds per element)."""
    return op.out_elems * max(p - 1, 0) / core.ve_elems_per_cycle


def compile_vliw(
    trace: WorkloadTrace,
    core: NPUCoreConfig = DEFAULT_CORE,
    max_mes: Optional[int] = None,
    n_x: Optional[int] = None,
    n_y: Optional[int] = None,
) -> VLIWProgram:
    """Baseline lowering: the compiler bakes the ME count into the
    instruction stream (Fig. 9's rigidity).

    ``max_mes`` is the ME count the program is compiled FOR — the
    tenant's vNPU size. Under V10's temporal sharing an op then
    occupies the whole physical array while only exploiting
    ``min(max_mes, n_tiles)`` MEs (the paper's false contention);
    under PMT the whole core is the vNPU, so ``max_mes`` defaults to
    the core width."""
    n_x = n_x or core.n_me
    n_y = n_y or core.n_ve
    cap = max_mes or n_x
    ops: List[VLIWOp] = []
    for op in trace.ops:
        if op.kind == "me":
            p = max(1, min(cap, op.n_tiles))
            ops.append(VLIWOp(op.name, p, op.me_cycles, op.ve_cycles,
                              op.hbm_bytes))
        else:
            ops.append(VLIWOp(op.name, 0, 0.0, op.ve_cycles, op.hbm_bytes))
    return VLIWProgram(name=trace.name, ops=ops, n_x=n_x, n_y=n_y)


# ----------------------------------------------------------------------
# phase-aware compilation: one program per (phase, context bucket)
# ----------------------------------------------------------------------
AnyProgram = Union[NeuISAProgram, VLIWProgram]

PREFILL = "prefill"
DECODE = "decode"
PIGGYBACK = "piggyback"   # one fused prefill-chunk + decode-batch program
SWAPIN = "swapin"         # KV restore of an evicted (swapped) request
PREFIX = "prefix"         # suffix-only prefill over a shared resident prefix


@dataclass
class CompiledPhase:
    """One phase of a compiled request: the program the scheduler
    replays for it, plus the context it was compiled at (``context``
    is in TOKENS: the decode bucket ceiling, or — for a prefill chunk
    — the prompt tokens ingested once this chunk completes).
    ``est_cycles`` is the phase trace's ideal-parallel lower bound on
    the full core (cycles) — the per-step service estimate
    PREMA-style eviction victim selection multiplies by
    tokens-remaining."""

    kind: str                    # "prefill" | "decode" | "" (legacy)
    program: AnyProgram
    context: int = 0
    est_cycles: float = 0.0


@dataclass
class CompiledRequestPlan:
    """Compiled :class:`~repro.npu.cost_model.RequestPlan`: the
    prefill program(s) plus one decode program per context bucket.
    The simulator walks a request's phase chain through these; a plan
    without decode phases is the degenerate single-phase case (seed
    behavior).

    Chunked prefill (``RequestPlan.prefill_chunk_tokens``) compiles
    one program per chunk into ``prefill_chunks`` (ingestion order);
    ``prefill`` is then the first chunk. Monolithic plans leave
    ``prefill_chunks`` empty — :meth:`prefill_phases` abstracts over
    both shapes.

    Piggybacked iterations (``iteration_token_budget`` > 0) cannot be
    pre-compiled — the (slice tokens, position, decode batch) mix is
    only known at iteration start — so :meth:`piggyback_phase` builds
    and compiles them on demand through the shared
    :class:`ProgramCache`, memoizing per quantized key: slice tokens
    (rounded up to ``PIGGYBACK_TOKEN_QUANT``), prior-context position
    (rounded up to ``PIGGYBACK_POS_QUANT``), decode-batch bucket
    (power of two) and decode-context bucket. The cache therefore
    holds a bounded program set per (model shape, budget, ISA),
    shared across requests and tenants like every other phase
    program."""

    name: str
    prefill: CompiledPhase
    decode: List[CompiledPhase] = field(default_factory=list)
    prompt_len: int = 0          # tokens
    gen_len: int = 1             # default tokens generated per request
    prefill_chunks: List[CompiledPhase] = field(default_factory=list)
    # adaptive piggybacked iterations (0 = off); `_piggyback` is the
    # on-demand (build trace -> compile via shared cache) factory set
    # by compile_request_plan for plans that carry a builder
    iteration_token_budget: int = 0
    _piggyback: Optional[Callable[..., AnyProgram]] = \
        field(default=None, repr=False, compare=False)
    _piggy_memo: Dict[Tuple, CompiledPhase] = \
        field(default_factory=dict, repr=False, compare=False)
    # live KV-cache accounting (bytes; 0 disables the ledger path) —
    # see RequestPlan.kv_token_bytes. `_swapin` builds the HBM
    # re-read program an evicted request replays on resume, one per
    # decode context bucket through the shared cache.
    kv_token_bytes: float = 0.0
    weight_bytes: float = 0.0
    _swapin: Optional[Callable[[int], AnyProgram]] = \
        field(default=None, repr=False, compare=False)
    _swapin_memo: Dict[int, CompiledPhase] = \
        field(default_factory=dict, repr=False, compare=False)
    # cross-request shared KV prefix (0 = off); `_prefix` builds the
    # suffix-only prefill program for a quantized cached-token count
    prefix_len: int = 0
    _prefix: Optional[Callable[[int], AnyProgram]] = \
        field(default=None, repr=False, compare=False)
    _prefix_memo: Dict[int, CompiledPhase] = \
        field(default_factory=dict, repr=False, compare=False)

    @property
    def has_decode(self) -> bool:
        return bool(self.decode)

    @property
    def chunked(self) -> bool:
        return bool(self.prefill_chunks)

    @property
    def n_prefill_chunks(self) -> int:
        """Prefill phases per request (1 when monolithic)."""
        return len(self.prefill_chunks) or 1

    def prefill_phases(self) -> List[CompiledPhase]:
        """The prefill phase chain a request walks, in order."""
        return list(self.prefill_chunks) or [self.prefill]

    def decode_phase_for(self, context: int) -> CompiledPhase:
        """Decode phase covering a step at ``context`` tokens; clamps
        to the largest precompiled bucket for out-of-coverage
        requests."""
        if not self.decode:
            raise ValueError(
                f"plan {self.name!r} has no decode phases")
        for ph in self.decode:
            if context <= ph.context:
                return ph
        return self.decode[-1]   # clamp: out-of-coverage contexts

    @property
    def can_piggyback(self) -> bool:
        """True when on-demand piggyback programs are available."""
        return self._piggyback is not None

    def piggyback_phase(self, chunk_tokens: int, kv_prior: int,
                        decode_batch: int, decode_ctx: int,
                        final: bool = False,
                        decode_groups: Optional[Tuple[Tuple[int, int], ...]]
                        = None) -> CompiledPhase:
        """Fused (prefill slice + decode batch) phase for one budgeted
        iteration, compiled on first use and memoized. Callers pass
        QUANTIZED arguments (the simulator quantizes; see the class
        docstring) — the exact token bookkeeping stays with the
        runtime, these programs are the cost proxy.

        ``decode_groups`` (``((batch_bucket, ctx_bucket), ...)``,
        sorted) costs each rider's decode share at ITS OWN context
        bucket instead of the largest live bucket — the simulator
        passes it whenever the live batch straddles buckets; the
        legacy single-bucket arguments stay the calling convention
        (and cache key) when it doesn't, so single-bucket programs
        are byte-identical to the pre-grouping compiler. The memo
        stays bounded either way: group tuples are drawn from the
        finite (bucket x batch-bucket) grid.

        ``context`` on the returned phase is the prompt tokens
        ingested once the slice completes (cost-grid tokens, not
        exact)."""
        if self._piggyback is None:
            raise ValueError(
                f"plan {self.name!r} was compiled without a piggyback "
                f"builder (non-generative RequestPlan)")
        key = (chunk_tokens, kv_prior, decode_batch, decode_ctx, final,
               decode_groups)
        ph = self._piggy_memo.get(key)
        if ph is None:
            ph = CompiledPhase(
                PIGGYBACK,
                self._piggyback(chunk_tokens, kv_prior, decode_batch,
                                decode_ctx, final,
                                decode_groups=decode_groups),
                context=kv_prior + chunk_tokens)
            self._piggy_memo[key] = ph
        return ph

    @property
    def kv_prompt_bytes(self) -> float:
        """KV bytes the whole prompt writes (the cumulative
        prefill-side charge; admission must test THIS against the KV
        budget, not a single chunk's share)."""
        return self.prompt_len * self.kv_token_bytes

    @property
    def can_swapin(self) -> bool:
        """True when on-demand KV-restore programs are available."""
        return self._swapin is not None

    def swapin_phase(self, context: int) -> CompiledPhase:
        """KV-restore phase for an evicted request resuming at
        ``context`` tokens, compiled per decode bucket on first use
        (the re-read is costed at the bucket ceiling, like every
        decode-side program; the ledger's byte bookkeeping stays
        exact)."""
        if self._swapin is None:
            raise ValueError(
                f"plan {self.name!r} was compiled without a swap-in "
                f"builder (no live KV accounting)")
        bucket = (self.decode_phase_for(context).context
                  if self.decode else context)
        ph = self._swapin_memo.get(bucket)
        if ph is None:
            ph = CompiledPhase(SWAPIN, self._swapin(bucket),
                               context=bucket)
            self._swapin_memo[bucket] = ph
        return ph

    @property
    def can_prefix(self) -> bool:
        """True when on-demand shared-prefix programs are available."""
        return self._prefix is not None

    def prefix_phase(self, cached_tokens: int) -> CompiledPhase:
        """Suffix-only prefill phase for a request whose leading
        ``cached_tokens`` are already resident in a shared ledger
        entry. The cached count quantizes DOWN to the
        ``PIGGYBACK_POS_QUANT`` grid (never skipping tokens the cost
        proxy didn't pay for), so the memo holds one program per grid
        point; the ledger's byte bookkeeping stays exact."""
        if self._prefix is None:
            raise ValueError(
                f"plan {self.name!r} was compiled without a prefix "
                f"builder (no shared-prefix support)")
        q = PIGGYBACK_POS_QUANT
        cached = max(min(int(cached_tokens), self.prompt_len - 1), 0)
        cached = (cached // q) * q
        ph = self._prefix_memo.get(cached)
        if ph is None:
            ph = CompiledPhase(PREFIX, self._prefix(cached),
                               context=self.prompt_len)
            self._prefix_memo[cached] = ph
        return ph


class ProgramCache:
    """Per-(phase, context-bucket) compiled-program cache (§III-D).

    Decode programs at context 512 / 1k / 2k / ... are identical for
    every request — and for every tenant serving the same model shape —
    so they compile once. Keyed by (isa, trace name, op count, work
    totals, core): trace names embed model:phase:bNsM, and the ME/VE/
    HBM totals (cycles, cycles, bytes) fingerprint the content so a
    rebuilt or hand-scaled trace that reuses a name cannot collide
    with another shape's program. Prefill chunk traces embed their
    prior-context offset (…:bNkP+C), so a chunk program likewise
    compiles once per (model shape, chunk size, position, ISA) and is
    shared by every request and tenant with that shape. Piggybacked
    iteration traces embed their full quantized mix
    (...:piggy:bNkP+C[f]+dB@CTX — slice tokens, position,
    decode-batch bucket, decode-context bucket), so the budgeted path
    adds one program per quantized grid point, not per live
    iteration.
    """

    def __init__(self) -> None:
        self._cache: Dict[Tuple, AnyProgram] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._cache)

    def compile(self, trace: WorkloadTrace, core: NPUCoreConfig,
                isa: str = "neuisa") -> AnyProgram:
        """Compile ``trace`` for ``isa`` (``"neuisa"`` μTOp groups or
        ``"vliw"`` whole operators), returning the cached program when
        an identical (name, op count, cycle/byte totals, core) build
        exists."""
        key = (isa, trace.name, len(trace.ops), trace.totals(), core)
        prog = self._cache.get(key)
        if prog is not None:
            self.hits += 1
            return prog
        self.misses += 1
        prog = (compile_neuisa(trace, core) if isa == "neuisa"
                else compile_vliw(trace, core))
        self._cache[key] = prog
        return prog


def compile_request_plan(
    plan: RequestPlan,
    core: NPUCoreConfig = DEFAULT_CORE,
    isa: str = "neuisa",
    cache: Optional[ProgramCache] = None,
) -> CompiledRequestPlan:
    """Lower a phase-structured request into per-phase programs,
    reusing ``cache`` across buckets / requests / tenants."""
    cache = cache if cache is not None else ProgramCache()
    chunks: List[CompiledPhase] = []
    if plan.prefill_chunks:
        # one program per chunk position — the cache collapses these
        # across requests/tenants of the same (shape, chunk size, ISA)
        ingested = 0
        step = plan.prefill_chunk_tokens or plan.prompt_len
        for tr in plan.prefill_chunks:
            ingested = min(ingested + step, plan.prompt_len)
            chunks.append(CompiledPhase(PREFILL,
                                        cache.compile(tr, core, isa),
                                        context=ingested))
        prefill = chunks[0]
    else:
        prefill = CompiledPhase(PREFILL,
                                cache.compile(plan.prefill, core, isa),
                                context=plan.prompt_len)
    decode = [CompiledPhase(DECODE, cache.compile(tr, core, isa),
                            context=ctx,
                            est_cycles=tr.ideal_cycles(core.n_me, core.n_ve))
              for ctx, tr in plan.decode]
    piggyback = None
    if plan.piggyback_builder is not None:
        builder = plan.piggyback_builder

        def piggyback(chunk_tokens: int, kv_prior: int, decode_batch: int,
                      decode_ctx: int, final: bool,
                      decode_groups=None) -> AnyProgram:
            tr = builder(chunk_tokens, kv_prior, decode_batch, decode_ctx,
                         final, decode_groups=decode_groups)
            return cache.compile(tr, core, isa)

    swapin = None
    if plan.swapin_builder is not None:
        swapin_builder = plan.swapin_builder

        def swapin(context: int) -> AnyProgram:
            return cache.compile(swapin_builder(context), core, isa)

    prefix = None
    if plan.prefix_builder is not None and plan.prefix_len > 0:
        prefix_builder = plan.prefix_builder

        def prefix(cached: int) -> AnyProgram:
            return cache.compile(prefix_builder(cached), core, isa)

    return CompiledRequestPlan(
        name=plan.name, prefill=prefill, decode=decode,
        prompt_len=plan.prompt_len, gen_len=plan.gen_len,
        prefill_chunks=chunks,
        iteration_token_budget=plan.iteration_token_budget,
        _piggyback=piggyback,
        kv_token_bytes=plan.kv_token_bytes,
        weight_bytes=plan.weight_bytes,
        _swapin=swapin,
        prefix_len=plan.prefix_len if plan.prefix_builder is not None else 0,
        _prefix=prefix,
    )


def neuisa_overhead_terms(trace: WorkloadTrace,
                          core: NPUCoreConfig = DEFAULT_CORE
                          ) -> Tuple[float, float]:
    """Analytic single-tenant makespans (VLIW vs NeuISA) used by the
    Fig. 16 benchmark; the simulator measures the same quantity
    dynamically."""
    n_x, n_y = core.n_me, core.n_ve
    t_vliw = t_neu = 0.0
    for op in trace.ops:
        p = max(1, min(n_x, op.n_tiles))
        me = op.me_cycles / p
        ve = op.ve_cycles / n_y
        hbm = op.hbm_bytes / core.hbm_bytes_per_cycle
        t_vliw += max(me, ve, hbm)
        if op.reduction_split:
            red = _reduce_cycles(op, p, core) / n_y
            t_neu += max(me, ve - red, hbm) + red
        else:
            t_neu += max(me, ve, hbm)
    return t_vliw, t_neu
