"""NeuISA (§III-D): μTOps, μTOp groups, the execution table, and the
control-flow instructions.

A μTOp is a slice of a tensor operator whose instructions drive ONE
ME (plus the n_y VE slots needed to drain/post-process it), or a pure
VE μTOp with no ME slot. μTOps within a group may run concurrently
and in any order; groups execute sequentially unless a
``uTop.nextGroup`` retargets control flow (loops/branches across
groups). μTOps sharing identical code use one snippet (the paper's
code-inflation mitigation) — we track snippet ids to report code
size.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

ME = "me"
VE = "ve"

# -- control instructions (Fig. 14) ------------------------------------
# Modeled as (opcode, operand) pairs appended to a μTOp's snippet.
UTOP_FINISH = "uTop.finish"
UTOP_NEXT_GROUP = "uTop.nextGroup"
UTOP_GROUP = "uTop.group"
UTOP_INDEX = "uTop.index"


@dataclass
class MuTOp:
    """One μTOp: VLIW snippet metadata + its simulated cost."""

    kind: str                   # ME | VE
    cycles: float               # engine-cycles of work in this μTOp
    hbm_bytes: float = 0.0
    op_name: str = ""
    snippet: int = -1           # shared code-snippet id
    # control: executed at μTOp end. None -> implicit uTop.finish.
    next_group: Optional[int] = None

    def control_instructions(self) -> List[Tuple[str, Optional[int]]]:
        ins: List[Tuple[str, Optional[int]]] = []
        if self.next_group is not None:
            ins.append((UTOP_NEXT_GROUP, self.next_group))
        ins.append((UTOP_FINISH, None))
        return ins


@dataclass
class MuTOpGroup:
    """Up to n_x ME μTOps + up to one VE μTOp (with n_y VE slots)."""

    me_utops: List[MuTOp] = field(default_factory=list)
    ve_utop: Optional[MuTOp] = None
    op_name: str = ""

    def all_utops(self) -> List[MuTOp]:
        out = list(self.me_utops)
        if self.ve_utop is not None:
            out.append(self.ve_utop)
        return out

    @property
    def me_work(self) -> float:
        return sum(u.cycles for u in self.me_utops)

    @property
    def ve_work(self) -> float:
        return self.ve_utop.cycles if self.ve_utop else 0.0


@dataclass
class NeuISAProgram:
    """A compiled NeuISA binary: μTOp snippets + the execution table.

    ``exec_table()`` reproduces Fig. 15's structure: one row per
    group with n_x ME entries + 1 VE entry (snippet start addresses;
    None = no μTOp in that slot).
    """

    name: str
    groups: List[MuTOpGroup]
    n_x: int                    # MEs on the physical core
    n_y: int                    # VEs on the physical core
    loop_trips: Dict[int, int] = field(default_factory=dict)
    # per-op metadata for analysis
    source_ops: int = 0

    def validate(self) -> None:
        for gi, g in enumerate(self.groups):
            if len(g.me_utops) > self.n_x:
                raise ValueError(
                    f"group {gi}: {len(g.me_utops)} ME μTOps > n_x={self.n_x}")
            # all μTOps setting next_group must agree (else: exception
            # raised by hardware, Fig. 14 semantics)
            targets = {u.next_group for u in g.all_utops()
                       if u.next_group is not None}
            if len(targets) > 1:
                raise ValueError(
                    f"group {gi}: conflicting uTop.nextGroup targets {targets}")
            tgt = next(iter(targets), None)
            if tgt is not None and not (0 <= tgt < len(self.groups)):
                raise ValueError(f"group {gi}: nextGroup {tgt} out of range")

    def exec_table(self) -> List[List[Optional[int]]]:
        rows: List[List[Optional[int]]] = []
        for g in self.groups:
            row: List[Optional[int]] = [None] * (self.n_x + 1)
            for i, u in enumerate(g.me_utops):
                row[i] = u.snippet
            if g.ve_utop is not None:
                row[self.n_x] = g.ve_utop.snippet
            rows.append(row)
        return rows

    # -- code-size accounting (the §III-D inflation discussion) --
    def n_utops(self) -> int:
        return sum(len(g.all_utops()) for g in self.groups)

    def n_snippets(self) -> int:
        return len({u.snippet for g in self.groups for u in g.all_utops()})

    def code_inflation(self) -> float:
        """μTOps per distinct snippet — 1.0 means perfect sharing."""
        n = self.n_snippets()
        return self.n_utops() / n if n else 0.0

    def total_work(self) -> Tuple[float, float, float]:
        me = sum(g.me_work for g in self.groups)
        ve = sum(g.ve_work for g in self.groups)
        hbm = sum(u.hbm_bytes for g in self.groups for u in g.all_utops())
        return me, ve, hbm

    def with_loop(self, start: int, end: int, trips: int) -> "NeuISAProgram":
        """Wire a loop: after `end` runs, jump back to `start`
        (trips-1) times via uTop.nextGroup on the group's μTOps."""
        if trips > 1:
            for u in self.groups[end].all_utops():
                u.next_group = start
            self.loop_trips[end] = trips
        self.validate()
        return self


@dataclass
class FusedIssueGroup:
    """A cross-tenant issue group (Fig. 6 co-scheduling).

    While one tenant's prefill(-chunk) ME μTOps grind through the
    systolic arrays, its VE slots are mostly idle (the drain work is
    pipelined into the ME span). A fused issue group co-issues
    co-tenant *decode* VE μTOps into that window: the ME μTOps keep
    their engines, the decode μTOps ride the idle VE slots, and —
    like μTOps of one compile-time group — members run to completion
    without preempting each other (the scheduler's reclaim pass skips
    fused members). This is purely an issue-time pairing formed by the
    scheduler; it adds no instructions to either program."""

    me_tenant: int                       # tenant whose prefill MEs anchor
    op_name: str                         # the anchoring ME operator
    ve_members: List[Tuple[int, str]] = field(default_factory=list)
                                         # (tenant idx, op name) co-issued

    @property
    def fused(self) -> bool:
        return bool(self.ve_members)


def form_fused_group(
    me_tenant: int,
    op_name: str,
    candidates: Sequence[Tuple[int, str, str]],
    max_ve: int = 1,
) -> FusedIssueGroup:
    """Form a fused issue group under a tenant's in-flight prefill ME
    μTOps. ``candidates`` are (tenant idx, op name, phase) tuples of
    ready VE μTOps; only *decode*-phase μTOps from OTHER tenants fuse
    (same-tenant VE work already shares the compile-time group, and
    non-decode work has no latency claim on the window). At most
    ``max_ve`` members join — one per donated VE slot."""
    g = FusedIssueGroup(me_tenant=me_tenant, op_name=op_name)
    for tenant, op, phase in candidates:
        if len(g.ve_members) >= max_ve:
            break
        if phase == "decode" and tenant != me_tenant:
            g.ve_members.append((tenant, op))
    return g


@dataclass
class VLIWOp:
    """Baseline ISA unit (Fig. 8 left): one tensor operator whose
    VLIW instruction stream couples the control flow of all
    ``n_me_static`` MEs it was compiled for."""

    op_name: str
    n_me_static: int            # compiled-in ME count (0 = VE-only op)
    me_cycles: float            # total ME work
    ve_cycles: float
    hbm_bytes: float

    @property
    def duration_me(self) -> float:
        """ME busy span when granted its static allocation."""
        return self.me_cycles / max(self.n_me_static, 1)


@dataclass
class VLIWProgram:
    name: str
    ops: List[VLIWOp]
    n_x: int
    n_y: int

    def total_work(self) -> Tuple[float, float, float]:
        return (
            sum(o.me_cycles for o in self.ops),
            sum(o.ve_cycles for o in self.ops),
            sum(o.hbm_bytes for o in self.ops),
        )
