"""Grouped (per-expert) matmul kernel — the MoE FFN hot-spot.

x: (E, C, d) dispatched token slots, w: (E, d, f) expert weights.
Grid (e, ci, fi, di) with the reduction (d) innermost; an fp32
accumulator tile lives in VMEM scratch across d-blocks. Block shapes
are MXU-aligned (128 multiples); the (bc x bd) x (bd x bf) working
set stays within a VMEM budget of a few MB.

Validated with interpret=True against ``ref.grouped_matmul_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nd: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)          # (bc, bd)
    w = w_ref[0].astype(jnp.float32)          # (bd, bf)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _final():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(
    x: jax.Array,       # (E, C, d)
    w: jax.Array,       # (E, d, f)
    *,
    bc: int = 128,
    bd: int = 512,
    bf: int = 128,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = x.shape
    f = w.shape[2]
    bc = min(bc, c)
    bd = min(bd, d)
    bf = min(bf, f)
    assert c % bc == 0 and d % bd == 0 and f % bf == 0, (c, d, f, bc, bd, bf)
    grid = (e, c // bc, f // bf, d // bd)
    kernel = functools.partial(_gmm_kernel, nd=d // bd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e_, ci, fi, di: (e_, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda e_, ci, fi, di: (e_, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda e_, ci, fi, di: (e_, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
