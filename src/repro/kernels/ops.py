"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on a
real TPU the same code lowers to Mosaic. Padding to block multiples
is handled here so callers pass natural shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import moe_gmm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, bq: int = 128, bk: int = 128
                    ) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, Hkv, hd) -> (B, S, H, hd)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, -1, hd)
    sk = kf.shape[1]
    pad_q = (-s) % bq
    pad_k = (-sk) % bk
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    o = fa.flash_attention_bhsd(
        qf, kf, vf, n_heads=h, n_kv_heads=hkv, causal=causal,
        bq=bq, bk=bk, interpret=_interpret())
    o = o[:, :s]
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("bc", "bd", "bf"))
def grouped_matmul(x: jax.Array, w: jax.Array, bc: int = 128,
                   bd: int = 512, bf: int = 128) -> jax.Array:
    """x: (E, C, d) @ w: (E, d, f) -> (E, C, f), padding-safe."""
    e, c, d = x.shape
    f = w.shape[2]
    bc_, bd_, bf_ = min(bc, c), min(bd, d), min(bf, f)
    pc, pd, pf = (-c) % bc_, (-d) % bd_, (-f) % bf_
    if pc or pd:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    o = moe_gmm.grouped_matmul(x, w, bc=bc_, bd=bd_, bf=bf_,
                               interpret=_interpret())
    return o[:, :c, :f]
