"""Flash attention for the TPU MXU (pl.pallas_call + BlockSpec VMEM
tiling), causal + GQA.

Tiling: grid = (batch*heads, Sq/bq, Sk/bk) with the K index innermost
so the online-softmax running stats (m, l) and the fp32 accumulator
live in VMEM scratch across K blocks. Block shapes are 128-aligned to
the 128x128 systolic array — the same tiles the NeuISA compiler uses
as μTOp granularity (DESIGN.md §3).

GQA is handled in the index maps: query head h reads KV head
h // (H // Hkv); KV blocks are never materialized per-Q-head.

Validated with interpret=True against ``ref.attention_ref`` (this
container has no TPU).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref,
                  *, bq: int, bk: int, nk: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0].astype(jnp.float32)          # (bk, hd)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)

    if causal:
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]                        # (bq,)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _final():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,       # (BH, Sq, hd)   flattened batch*q-heads
    k: jax.Array,       # (BKV, Sk, hd)  flattened batch*kv-heads
    v: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    nq, nk = sq // bq, sk // bk
    group = n_heads // n_kv_heads
    scale = 1.0 / math.sqrt(hd)

    def kv_head(bh_idx):
        b = bh_idx // n_heads
        h = bh_idx % n_heads
        return b * n_kv_heads + h // group

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (kv_head(b), j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (kv_head(b), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
