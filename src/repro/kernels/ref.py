"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd) — kv already head-matched.
    fp32 softmax, output in q.dtype."""
    _, sq, hd = q.shape
    sk = k.shape[1]
    scores = jnp.einsum("bqh,bkh->bqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        scores = jnp.where(mask[None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w.astype(v.dtype), v)


def gqa_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, Hkv, hd). Returns (B, S, H, hd)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, -1, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, -1, hd)
    o = attention_ref(qf, kf, vf, causal)
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (E, C, d); w: (E, d, f) -> (E, C, f). fp32 accumulation."""
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    return out.astype(x.dtype)
