"""Pallas TPU kernels for the serving/training compute hot-spots:
flash attention (prefill) and the MoE grouped matmul.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit wrapper, interpret-mode fallback off-TPU), and
ref.py (pure-jnp oracle used by the allclose test sweeps).

The paper itself contributes no kernels (its mechanism is an ISA /
scheduler change realized in the simulator); these kernels are the
framework's compute layer, and their 128-aligned tile shapes define
the μTOp granularity used by the NeuISA compiler (DESIGN.md §3).
"""
