"""Fault-tolerant checkpointing: msgpack tensor store with atomic
rename, async (background-thread) saves, integrity manifest, and
keep-last-k retention.

Layout:  <dir>/step_<N>/arrays.msgpack   (+ manifest.json)
A save is visible only after the atomic directory rename, so a crash
mid-save never corrupts the restore point — the restart path picks
the newest complete step.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np


def _flatten(state) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(state)
    keyed = jax.tree_util.tree_flatten_with_path(state)[0]
    out = []
    for (path, leaf) in keyed:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out, treedef


def _dtype_of(name: str) -> np.dtype:
    # name-based so ml_dtypes (bfloat16, fp8) round-trip correctly
    import jax.numpy as jnp

    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(jnp, name))


def _pack_array(a: np.ndarray) -> Dict[str, Any]:
    return {"dtype": a.dtype.name, "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d) -> np.ndarray:
    return np.frombuffer(
        d["data"], dtype=_dtype_of(d["dtype"])).reshape(d["shape"]).copy()


def save_checkpoint(ckpt_dir: str, step: int, state) -> str:
    """Synchronous atomic save. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, _ = _flatten(state)
    payload = {k: _pack_array(v) for k, v in arrays}
    blob = msgpack.packb(payload)
    with open(os.path.join(tmp, "arrays.msgpack"), "wb") as f:
        f.write(blob)
    manifest = {
        "step": step,
        "n_arrays": len(arrays),
        "bytes": len(blob),
        "sha256": hashlib.sha256(blob).hexdigest(),
        "keys": [k for k, _ in arrays],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_checkpoints(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def restore_checkpoint(ckpt_dir: str, like, step: Optional[int] = None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). Verifies the integrity hash."""
    steps = list_checkpoints(ckpt_dir)
    if not steps:
        return None, -1
    step = step if step is not None else steps[-1]
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "arrays.msgpack"), "rb") as f:
        blob = f.read()
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    if hashlib.sha256(blob).hexdigest() != manifest["sha256"]:
        raise IOError(f"checkpoint {path} failed integrity check")
    payload = msgpack.unpackb(blob)
    keyed = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for (pth, leaf) in keyed[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pth)
        arr = _unpack_array(payload[key])
        want = np.dtype(leaf.dtype)
        if arr.dtype != want:
            arr = arr.astype(want)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(keyed[1], leaves), step


class Checkpointer:
    """Async checkpointer: `save()` snapshots to host memory on the
    caller thread (cheap) and writes in a background thread, so the
    train loop never blocks on disk. `keep` newest checkpoints are
    retained."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, state, blocking: bool = False) -> None:
        self.wait()
        host_state = jax.tree_util.tree_map(np.asarray, state)

        def work():
            try:
                save_checkpoint(self.dir, step, host_state)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._err = e

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def restore(self, like, step: Optional[int] = None):
        return restore_checkpoint(self.dir, like, step)

    def _gc(self) -> None:
        steps = list_checkpoints(self.dir)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
