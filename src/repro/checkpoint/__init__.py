from repro.checkpoint.checkpointer import (
    Checkpointer,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.elastic import reshard_state

__all__ = ["Checkpointer", "save_checkpoint", "restore_checkpoint",
           "reshard_state"]
