"""Elastic scaling: reshard a checkpoint for a different mesh.

When a pod (or hosts) drop out, the surviving cluster rebuilds a
smaller mesh and the coordinator replays the newest checkpoint with
new shardings. Parameters are topology-independent (full logical
arrays in the checkpoint), so resharding is: load -> re-place with
the new mesh's NamedShardings -> resume. The DATA order is also
preserved: the synthetic pipeline keys batches on (step, shard), and
`plan_elastic_restart` recomputes shard assignments for the new
world size.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def reshard_state(state, mesh: Mesh, spec_tree) -> Any:
    """Place a host-memory state pytree onto `mesh` under `spec_tree`
    (PartitionSpec pytree). Works for both grow and shrink."""
    from repro.distributed.sharding import named

    shardings = named(mesh, spec_tree)
    return jax.tree_util.tree_map(
        lambda a, sh: jax.device_put(a, sh), state, shardings)


@dataclass
class ElasticPlan:
    old_world: int
    new_world: int
    new_data_axis: int
    new_global_batch: int
    restart_step: int


def plan_elastic_restart(
    old_world: int,
    surviving: int,
    model_parallel: int,
    global_batch: int,
    last_step: int,
) -> ElasticPlan:
    """Compute the largest viable mesh from surviving chips: the
    model axis is fixed (sharded params must fit), data axis shrinks
    to the largest multiple that divides the batch."""
    if surviving < model_parallel:
        raise RuntimeError(
            f"cannot rebuild: {surviving} chips < model axis "
            f"{model_parallel}")
    new_data = surviving // model_parallel
    while new_data > 0 and global_batch % new_data != 0:
        new_data -= 1
    if new_data == 0:
        raise RuntimeError("no data-axis size divides the global batch")
    return ElasticPlan(
        old_world=old_world,
        new_world=new_data * model_parallel,
        new_data_axis=new_data,
        new_global_batch=global_batch,
        restart_step=last_step,
    )
