"""Analytic operator cost model for the NPU core.

Maps tensor operators onto (ME cycles, VE cycles, HBM bytes) plus the
tiling metadata the NeuISA compiler needs (how many independent ME
partitions exist, and whether the partition had to cut the reduction
dimension — the Fig. 16 overhead case).

Conventions
-----------
* ``me_cycles`` / ``ve_cycles`` are TOTAL work expressed as cycles on
  ONE engine; executing on ``k`` engines divides the span by ``k``
  (the trailing fill/drain inefficiency is already baked into
  ``me_cycles`` via the block model below).
* Matrix engine model: a ``me_dim x me_dim`` weight-stationary block
  takes ``rows + me_dim`` cycles to stream ``rows`` activations
  (fill + drain). Total cycles = #weight-blocks x (rows + me_dim).
  This reproduces the paper's Fig. 6 behavior: an 8-row pop takes 8
  cycles of VE post-processing per 8x128 vector but ~me_dim cycles of
  ME time, so VEs idle during ME-heavy ops and MXU utilization
  collapses for small-row (decode) matmuls.
* Vector engine model: ``elems / (lanes * ops_per_lane)`` cycles.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig


@dataclass
class Operator:
    """One tensor operator in a workload trace (paper §III-G schema).

    Units: ``me_cycles`` / ``ve_cycles`` are engine cycles of total
    work on ONE engine (see the module conventions above);
    ``hbm_bytes`` is bytes moved over HBM; ``out_elems`` is output
    elements (not bytes)."""

    name: str
    me_cycles: float = 0.0
    ve_cycles: float = 0.0
    hbm_bytes: float = 0.0
    # tiling metadata (selected by the "compiler")
    n_tiles: int = 1              # independent output partitions for MEs
    reduction_split: bool = False  # K-dim partition -> trailing VE-reduce
    fused_ve: bool = True          # VE work rides in ME uTOps' VE slots
    out_elems: float = 0.0        # output size (reduce cost on K-splits)
    shapes: Tuple[Tuple[int, ...], ...] = ()
    # bytes of ``hbm_bytes`` that are parameter (weight) streaming — a
    # piggybacked iteration that runs this op twice (prefill chunk +
    # decode batch) streams the weights ONCE and dedupes this share
    weight_bytes: float = 0.0

    @property
    def kind(self) -> str:
        if self.me_cycles <= 0 and self.ve_cycles <= 0:
            return "mem"
        if self.me_cycles <= 0:
            return "ve"
        return "me"

    def scaled(self, factor: float) -> "Operator":
        """Copy with cycle/byte costs multiplied by ``factor`` (tiling
        metadata unchanged) — scaling by k is profile-equivalent to
        repeating the op k times."""
        return Operator(
            self.name,
            me_cycles=self.me_cycles * factor,
            ve_cycles=self.ve_cycles * factor,
            hbm_bytes=self.hbm_bytes * factor,
            n_tiles=self.n_tiles,
            reduction_split=self.reduction_split,
            fused_ve=self.fused_ve,
            out_elems=self.out_elems,
            shapes=self.shapes,
            weight_bytes=self.weight_bytes * factor,
        )

    def without_weight_stream(self) -> "Operator":
        """Copy with the parameter-streaming HBM share removed — the
        weights were already streamed by an identical op earlier in
        the same fused program (piggybacked iterations count shared
        weight reads once)."""
        if self.weight_bytes <= 0:
            return self
        return Operator(
            self.name,
            me_cycles=self.me_cycles,
            ve_cycles=self.ve_cycles,
            hbm_bytes=max(self.hbm_bytes - self.weight_bytes, 0.0),
            n_tiles=self.n_tiles,
            reduction_split=self.reduction_split,
            fused_ve=self.fused_ve,
            out_elems=self.out_elems,
            shapes=self.shapes,
            weight_bytes=0.0,
        )


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def matmul_op(
    name: str,
    m: int,
    k: int,
    n: int,
    core: NPUCoreConfig = DEFAULT_CORE,
    dtype_bytes: int = 2,
    ve_post_elems: float = 0.0,
    weight_resident: bool = False,
    act_in_sram: bool = True,
    out_to_hbm: bool = False,
) -> Operator:
    """(m,k) @ (k,n) on the systolic MEs.

    ``ve_post_elems``: extra per-output VE work fused into the op
    (bias/activation/residual), in elements; defaults to a pop-side
    aggregation of 1 op per output element (the VE must always drain
    the systolic array — paper Fig. 6).
    """
    md = core.me_dim
    blocks = _ceil_div(k, md) * _ceil_div(n, md)
    # Per weight-stationary block: stream `m` activation rows through
    # the array; the next block's weights load in the shadow (double
    # buffered) at HBM rate (or SRAM rate when the operand is
    # resident). Small-row (decode) matmuls thus become weight-stream
    # paced — the paper's §V-F "memory-bound, MEs underutilized" case.
    w_stream = (md * md * dtype_bytes) / core.hbm_bytes_per_cycle
    if weight_resident:
        w_stream = 8.0  # SRAM-resident operand: near-free reload
    me_cycles = blocks * max(float(m), w_stream) + md
    # VE drains every output vector + any fused epilogue
    ve_elems = m * n + ve_post_elems
    ve_cycles = ve_elems / core.ve_elems_per_cycle

    hbm = 0.0
    w_bytes = 0.0
    if not weight_resident:
        w_bytes = k * n * dtype_bytes       # stream weights once
        hbm += w_bytes
    if not act_in_sram:
        hbm += m * k * dtype_bytes
    if out_to_hbm:
        hbm += m * n * dtype_bytes

    # tiling: prefer output partitions. A partition must amortize the
    # array fill/drain, so the compiler's tile floor is 128 rows x 256
    # cols (the same floor as the Pallas kernels' BlockSpecs) — small
    # operators therefore CANNOT fill every ME, which is exactly the
    # paper's Fig. 9 false-contention case for VLIW baselines.
    out_tiles = _ceil_div(m, md) * _ceil_div(n, 2 * md)
    reduction_split = False
    n_tiles = out_tiles
    if out_tiles < core.n_me and _ceil_div(k, md) >= 2:
        n_tiles = min(core.n_me, _ceil_div(k, md))
        reduction_split = True
    return Operator(
        name,
        me_cycles=float(me_cycles),
        ve_cycles=float(ve_cycles),
        hbm_bytes=float(hbm),
        n_tiles=max(int(n_tiles), 1),
        reduction_split=reduction_split,
        out_elems=float(m * n),
        shapes=((m, k), (k, n)),
        weight_bytes=float(w_bytes),
    )


def vector_op(
    name: str,
    elems: float,
    core: NPUCoreConfig = DEFAULT_CORE,
    flops_per_elem: float = 1.0,
    hbm_bytes: float = 0.0,
) -> Operator:
    """Pure VE operator (softmax, norm, activation, rope, scan step...)."""
    cycles = elems * flops_per_elem / core.ve_elems_per_cycle
    return Operator(
        name,
        ve_cycles=float(cycles),
        hbm_bytes=float(hbm_bytes),
        n_tiles=1,
        shapes=((int(elems),),),
    )


def memory_op(
    name: str,
    hbm_bytes: float,
    core: NPUCoreConfig = DEFAULT_CORE,
    ve_elems: float = 0.0,
) -> Operator:
    """HBM-dominated operator (embedding gather, KV-cache read...).

    The VEs issue the gathers/DMA descriptors, so their active time is
    paced by HBM bandwidth — this is what makes DLRM/NCF "VE-intensive"
    in the paper's Fig. 4/5 even though they do little arithmetic.
    """
    issue_cycles = ve_elems / core.ve_elems_per_cycle if ve_elems else 0.0
    ve_cycles = max(issue_cycles, hbm_bytes / core.hbm_bytes_per_cycle)
    return Operator(
        name,
        ve_cycles=float(ve_cycles),
        hbm_bytes=float(hbm_bytes),
        n_tiles=1,
    )


# ----------------------------------------------------------------------
@dataclass
class WorkloadTrace:
    """A sequence of dependent operators = one inference request.

    Matches the paper's replayed trace: DNN inference graphs are
    (post-fusion) a dependency chain of operators; intra-operator
    parallelism is expressed through ``n_tiles``.
    """

    name: str
    ops: List[Operator] = field(default_factory=list)
    hbm_footprint: float = 0.0   # resident bytes (weights + cache)
    core: NPUCoreConfig = DEFAULT_CORE

    def extend(self, ops: List[Operator]) -> None:
        self.ops.extend(ops)

    # -- §III-B profile: run on 1 ME + 1 VE, ME/VE pipelined per op --
    def profile_mv(self) -> Tuple[float, float]:
        """Returns (m, v): ME / VE active-time fractions on a 1ME+1VE
        core (compile-time profile the vNPU allocator consumes)."""
        t_total = me_t = ve_t = 0.0
        for op in self.ops:
            span = max(op.me_cycles, op.ve_cycles, 1e-9)
            t_total += span
            me_t += op.me_cycles
            ve_t += op.ve_cycles
        if t_total <= 0:
            return 0.0, 0.0
        return me_t / t_total, ve_t / t_total

    def totals(self) -> Tuple[float, float, float]:
        """(ME cycles, VE cycles, HBM bytes) summed over the trace."""
        return (
            sum(o.me_cycles for o in self.ops),
            sum(o.ve_cycles for o in self.ops),
            sum(o.hbm_bytes for o in self.ops),
        )

    def ideal_cycles(self, n_me: int, n_ve: int) -> float:
        """Lower bound in cycles: perfectly parallel + overlapped
        execution on ``n_me`` MEs / ``n_ve`` VEs."""
        me, ve, hbm = self.totals()
        return max(me / n_me, ve / n_ve, hbm / self.core.hbm_bytes_per_cycle)


# ----------------------------------------------------------------------
# phase-structured request IR
# ----------------------------------------------------------------------
def decode_bucket(context: int, base: int = 512) -> int:
    """Context bucket a decode step at ``context`` falls into: the
    smallest ``base * 2**k`` >= context. Decode cost (KV stream, score
    matmul K-dim) is bucketed so the compiler emits one program per
    bucket instead of one per context length."""
    b = base
    while b < context:
        b <<= 1
    return b


def batch_bucket(n: int) -> int:
    """Decode-batch bucket for piggybacked programs: the smallest
    power of two >= ``n`` (0 for an empty batch). Piggyback program
    cache keys use the bucket instead of the live batch so the cache
    stays bounded; cost is taken at the bucket ceiling (conservative,
    same idiom as :func:`decode_bucket`)."""
    if n <= 0:
        return 0
    return 1 << (int(n) - 1).bit_length()


# tokens a budgeted prefill slice never shrinks below (prefill must
# always progress — SARATHI-SF's "stall-free" floor), and the grids
# the piggyback program cache quantizes on: slice token counts round
# up to PIGGYBACK_TOKEN_QUANT and the chunk's prior-context position
# rounds up to PIGGYBACK_POS_QUANT, so the cache holds O(log) programs
# per (shape, budget) instead of one per live (slice, position) pair.
PIGGYBACK_CHUNK_FLOOR = 32
PIGGYBACK_TOKEN_QUANT = 32
PIGGYBACK_POS_QUANT = 64


@dataclass
class RequestPlan:
    """Phase-structured request IR: one generation request = a prefill
    phase (prompt ingestion, emits the first token) followed by up to
    ``gen_len - 1`` decode steps against a growing KV cache.

    Decode cost is *context-bucketed*: ``decode`` holds one
    (bucket_context, trace) pair per power-of-two bucket covering
    ``prompt_len + 1 .. prompt_len + max_gen``; a decode step at
    context c uses the smallest bucket >= c. A single-phase workload
    (the seed's fixed-phase traces) is the degenerate plan with
    ``gen_len <= 1`` and no decode entries.

    Prefill may additionally be *chunked* (SARATHI-style): when
    ``prefill_chunk_tokens > 0`` and the prompt is longer than one
    chunk, ``prefill_chunks`` holds one partial-context trace per
    chunk of the prompt. Each chunk ingests up to
    ``prefill_chunk_tokens`` prompt tokens against the KV written by
    the chunks before it; only the final chunk emits the first token.
    The simulator treats each chunk as its own phase, so a tenant's
    in-flight decode iterations interleave *between* its prefill
    chunks instead of head-of-line blocking behind the whole prompt.
    With the knob unset (0), ``prefill_chunks`` is empty and the plan
    is bit-identical to the monolithic-prefill IR.

    A further refinement replaces the *static* chunk knob entirely:
    ``iteration_token_budget`` > 0 enables SARATHI-SF-style
    **piggybacked iterations** — the simulator sizes each prefill
    slice adaptively (budget minus the live decode batch, floored so
    prefill always progresses) and runs the slice and the tenant's
    live decode tokens as ONE fused program. Those mixed programs are
    built on demand through ``piggyback_builder`` (a callable
    ``(chunk_tokens, kv_prior, decode_batch, decode_ctx, final) ->
    WorkloadTrace``); the trace layer attaches it for generative
    plans. With the budget unset (0) the builder is never invoked and
    every path is bit-identical to the static-chunk / monolithic IR.

    Per-phase KV-cache accounting: ``kv_token_bytes`` is the HBM bytes
    the KV cache grows by for every token the request ingests or
    generates, at the REAL (unbucketed) context — the prefill phase
    writes ``kv_prompt_bytes`` (= ``prompt_len`` tokens; a chunk or
    piggybacked slice writes only its own tokens' share), and each
    decode step grows the cache by one token. The live-ledger
    simulator charges these against the tenant's
    :class:`~repro.core.vnpu.KVLedger` at phase boundaries; with
    ``kv_token_bytes == 0`` (non-attention families, or single-phase
    plans) the ledger path is inert and ``hbm_footprint`` stays the
    static admission-time max it always was. ``weight_bytes`` is the
    resident parameter share the ledger reserves up front.
    ``swapin_builder`` makes the HBM re-read trace an evicted
    request's KV restore pays on resume (one
    :func:`memory_op` over the context's KV bytes, built per decode
    bucket). ``prefix_len`` marks the leading prompt tokens that may be
    shared across requests (refcounted in the ledger); on a prefix hit
    the simulator runs the suffix-only trace from ``prefix_builder``
    (``cached_tokens -> WorkloadTrace``) and charges only the unshared
    suffix bytes. With ``prefix_len == 0`` the path is inert.

    Units: trace costs are engine cycles / HBM bytes (see
    :class:`Operator`); ``prompt_len`` / ``gen_len`` / ``max_gen`` /
    ``prefill_chunk_tokens`` / ``iteration_token_budget`` are token
    counts; ``hbm_footprint`` / ``kv_token_bytes`` / ``weight_bytes``
    are bytes.
    """

    name: str
    prefill: WorkloadTrace
    decode: List[Tuple[int, WorkloadTrace]] = field(default_factory=list)
    prompt_len: int = 0
    gen_len: int = 1             # default generated tokens per request
    max_gen: int = 0             # bucket coverage (>= any sampled gen len)
    bucket_base: int = 512
    hbm_footprint: float = 0.0
    # SARATHI-style chunked prefill: tokens per chunk (0 = monolithic)
    prefill_chunk_tokens: int = 0
    prefill_chunks: List[WorkloadTrace] = field(default_factory=list)
    # adaptive piggybacked iterations: target tokens per iteration
    # (0 = off); the builder makes mixed chunk+decode traces on demand
    iteration_token_budget: int = 0
    piggyback_builder: Optional[Callable[..., WorkloadTrace]] = \
        field(default=None, repr=False, compare=False)
    # live KV-cache accounting (0 = ledger path inert)
    kv_token_bytes: float = 0.0  # KV bytes written per ingested/generated
                                 # token (real context, unbucketed)
    weight_bytes: float = 0.0    # resident parameter bytes (ledger reserve)
    swapin_builder: Optional[Callable[[int], WorkloadTrace]] = \
        field(default=None, repr=False, compare=False)
    # cross-request shared KV prefix: leading tokens that may already be
    # resident in a refcounted shared ledger entry (0 = no sharing); the
    # builder makes the suffix-only prefill trace for a given cached
    # prefix length
    prefix_len: int = 0
    prefix_builder: Optional[Callable[[int], WorkloadTrace]] = \
        field(default=None, repr=False, compare=False)

    def __post_init__(self) -> None:
        self.decode = sorted(self.decode, key=lambda p: p[0])
        if not self.max_gen:
            self.max_gen = self.gen_len
        if not self.hbm_footprint:
            traces = ([self.prefill] + list(self.prefill_chunks)
                      + [t for _, t in self.decode])
            self.hbm_footprint = max(t.hbm_footprint for t in traces)

    @property
    def has_decode(self) -> bool:
        """True when the plan carries context-bucketed decode phases."""
        return bool(self.decode)

    @property
    def kv_prompt_bytes(self) -> float:
        """KV bytes the (whole) prefill phase writes: the prompt's
        cache at the real context. Chunked/budgeted prefill charges
        this incrementally, one chunk/slice's tokens at a time."""
        return self.prompt_len * self.kv_token_bytes

    @property
    def chunked(self) -> bool:
        """True when prefill runs as a chain of chunk phases."""
        return bool(self.prefill_chunks)

    @property
    def piggybacked(self) -> bool:
        """True when iterations are budgeted: prefill slices size
        adaptively and carry the live decode batch in one program."""
        return self.iteration_token_budget > 0

    @property
    def n_prefill_chunks(self) -> int:
        """Prefill phases per request (1 when monolithic)."""
        return len(self.prefill_chunks) or 1

    def prefill_phases(self) -> List[WorkloadTrace]:
        """The prefill phase chain: the chunk traces in ingestion
        order, or the single monolithic trace."""
        return list(self.prefill_chunks) or [self.prefill]

    def decode_trace_for(self, context: int) -> Tuple[int, WorkloadTrace]:
        """(bucket, trace) for a decode step at ``context``; clamps to
        the largest precompiled bucket for out-of-coverage requests."""
        if not self.decode:
            raise ValueError(f"plan {self.name!r} has no decode phases")
        for ctx, tr in self.decode:
            if context <= ctx:
                return ctx, tr
        return self.decode[-1]

    def decode_steps(self, gen_len: Optional[int] = None) -> int:
        """Decode iterations a request needs: the prefill emits token 1,
        each decode step one more."""
        n = self.gen_len if gen_len is None else gen_len
        return max(n - 1, 0)

    def profile_trace(self) -> WorkloadTrace:
        """Flatten into one WorkloadTrace weighted by the default
        generation length — feeds the compile-time (m, v) profile the
        Eq. 1-4 allocator consumes, so a decode-heavy tenant's vNPU
        split reflects its decode:prefill cycle mix. A chunked plan
        blends the chunk traces (which carry the real per-chunk KV
        re-read and fill/drain overhead) instead of the monolithic
        prefill, so the allocator sees the cost of what will actually
        execute."""
        tr = WorkloadTrace(name=f"{self.name}:profile",
                           core=self.prefill.core)
        for ptr in self.prefill_phases():
            tr.ops.extend(ptr.ops)
        steps = self.decode_steps()
        if steps and self.decode:
            # distribute the default request's steps over its buckets;
            # scaling an op by k is profile-equivalent to repeating it
            per_bucket = self._steps_per_bucket(steps)
            for (_, dtr), n in zip(self.decode, per_bucket):
                if n <= 0:
                    continue
                tr.ops.extend(op.scaled(float(n)) for op in dtr.ops)
        tr.hbm_footprint = self.hbm_footprint
        return tr

    def _steps_per_bucket(self, steps: int) -> List[int]:
        out = []
        done = 0
        for ctx, _ in self.decode:
            # steps whose context (prompt + tokens emitted so far + 1)
            # fits under this bucket's ceiling
            hi = min(ctx - self.prompt_len - 1, steps)
            n = max(hi - done, 0)
            out.append(n)
            done += n
        if out and done < steps:
            out[-1] += steps - done  # clamp tail to the largest bucket
        return out
