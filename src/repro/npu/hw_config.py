"""Hardware configurations.

``NPUCoreConfig`` mirrors the paper's Table II simulator config (a
TPUv4-like core). ``TPUv5eRoofline`` carries the roofline constants
the brief prescribes for the dry-run analysis (TPU v5e is the compile
TARGET; this container only executes on CPU).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class NPUCoreConfig:
    """Paper Table II: one physical NPU core (pNPU core)."""

    n_me: int = 4                  # matrix engines (systolic arrays)
    n_ve: int = 4                  # vector engines
    me_dim: int = 128              # 128x128 systolic array
    ve_lanes: int = 128            # 128 lanes ...
    ve_ops_per_lane: int = 8       # ... x 8 FP32 ops/cycle  (Table II)
    freq_hz: float = 1.05e9        # 1050 MHz
    sram_bytes: int = 128 * 1024 * 1024          # 128 MB on-chip
    hbm_bytes: int = 64 * 1024**3                # 64 GB
    hbm_bw: float = 1200e9                       # 1200 GB/s
    # ME preemption: pop partial sums (128) + pop weights (128) — §III-G
    ctx_switch_cycles: int = 256
    # memory isolation segment sizes — §III-C
    sram_segment: int = 2 * 1024 * 1024          # 2 MB
    hbm_segment: int = 1 * 1024**3               # 1 GB

    # ------------------------------------------------------------------
    @property
    def me_flops_per_cycle(self) -> float:
        """One ME: me_dim x me_dim MACs/cycle, 2 FLOPs per MAC."""
        return 2.0 * self.me_dim * self.me_dim

    @property
    def ve_elems_per_cycle(self) -> float:
        """One VE: lanes x ops-per-lane elementwise ops/cycle."""
        return float(self.ve_lanes * self.ve_ops_per_lane)

    @property
    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_bw / self.freq_hz

    def with_(self, **kw) -> "NPUCoreConfig":
        import dataclasses

        return dataclasses.replace(self, **kw)


DEFAULT_CORE = NPUCoreConfig()


@dataclass(frozen=True)
class TPUv5eRoofline:
    """Roofline constants for the dry-run target (per brief)."""

    peak_flops_bf16: float = 197e12   # per chip
    hbm_bw: float = 819e9             # per chip
    ici_bw: float = 50e9              # per link
    hbm_per_chip: int = 16 * 1024**3  # 16 GB v5e


V5E = TPUv5eRoofline()
