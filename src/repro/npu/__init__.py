"""NPU hardware model: core config (paper Table II), analytic
operator cost model, and workload trace generation.

This package replaces the paper's real-TPUv4 profiling step: traces
carry the exact schema the paper replays (per-operator ME/VE time,
HBM bytes, tensor shapes, tiling) but are derived analytically from
the model-zoo configs. See DESIGN.md §2.
"""
from repro.npu.hw_config import NPUCoreConfig, TPUv5eRoofline, DEFAULT_CORE
from repro.npu.cost_model import (Operator, RequestPlan, decode_bucket,
                                  matmul_op, memory_op, vector_op)

__all__ = [
    "NPUCoreConfig",
    "TPUv5eRoofline",
    "DEFAULT_CORE",
    "Operator",
    "RequestPlan",
    "decode_bucket",
    "matmul_op",
    "vector_op",
    "memory_op",
]
