"""Workload trace generation: ModelConfig -> operator trace.

Replaces the paper's real-TPU profiling (§III-G): for each assigned
architecture we emit the per-operator (ME cycles, VE cycles, HBM
bytes, tiling) sequence of one inference request (prefill or decode)
or one training step. The op inventory mirrors what XLA emits for
these models post-fusion: projection matmuls with fused epilogues,
attention score/context matmuls, softmax/norm/rope vector ops,
embedding gathers, MoE routing + expert GEMMs, SSD chunk scans.

Approximations are documented inline; the simulator consumes only the
(me, ve, hbm, tiling) schema, exactly like the paper's replayed
traces.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.npu.cost_model import (
    Operator,
    RequestPlan,
    WorkloadTrace,
    decode_bucket,
    matmul_op,
    memory_op,
    vector_op,
)
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig

DTYPE = 2  # bf16


# ----------------------------------------------------------------------
# per-block builders. T = tokens processed this call.
# ----------------------------------------------------------------------
def _attention_ops(
    cfg: ModelConfig, B: int, S: int, T: int, phase: str, core: NPUCoreConfig,
    kv_prior: int = 0,
) -> List[Operator]:
    """Attention block ops. ``kv_prior`` > 0 makes a prefill call a
    *chunk*: the S new tokens attend to ``kv_prior`` already-ingested
    keys (streamed back from HBM) plus themselves. ``kv_prior == 0``
    is the monolithic prefill and emits bit-identical operators to the
    pre-chunking builder (the causal 0.5 factor is the kv_prior=0 case
    of the general chunk fraction)."""
    d, dq, dkv, hd = cfg.d_model, cfg.d_q, cfg.d_kv, cfg.d_head
    H = cfg.n_heads
    ops: List[Operator] = [
        vector_op("rmsnorm", T * d, core, flops_per_elem=4.0),
        matmul_op(
            "qkv_proj", T, d, dq + 2 * dkv, core,
            ve_post_elems=(T * (dq + 2 * dkv)) if cfg.qkv_bias else 0.0,
        ),
        vector_op("rope", T * (dq + dkv), core, flops_per_elem=3.0),
    ]
    if cfg.qk_norm:
        ops.append(vector_op("qk_norm", T * (dq + dkv), core, flops_per_elem=4.0))
    if phase == "prefill":
        # scores: (B*H*S, hd) @ (hd, K) over K = prior + new keys;
        # causal masking keeps frac of the score matrix (exactly 0.5
        # for a monolithic prefill, approaching 1.0 for a late chunk
        # whose rows attend to nearly the whole context)
        K = kv_prior + S
        frac = (kv_prior + 0.5 * S) / K
        ops.append(
            matmul_op("attn_scores", B * H * S, hd, K, core).scaled(frac)
        )
        ops.append(vector_op("softmax", frac * B * H * S * K, core, flops_per_elem=5.0))
        ops.append(matmul_op("attn_ctx", B * H * S, K, hd, core).scaled(frac))
        if kv_prior:
            # chunked prefill re-reads the earlier chunks' KV from HBM
            # — the per-chunk overhead that bounds how small a chunk
            # is worth making
            ops.append(
                memory_op(
                    "kv_chunk_read",
                    2.0 * B * cfg.n_kv_heads * kv_prior * hd * DTYPE, core)
            )
    else:
        # decode: stream the KV cache from HBM; MXU sees tiny row counts
        kv_bytes = 2.0 * B * cfg.n_kv_heads * S * hd * DTYPE
        qk = matmul_op("attn_scores_dec", B * H, hd, S, core, weight_resident=True)
        ctx = matmul_op("attn_ctx_dec", B * H, S, hd, core, weight_resident=True)
        ops.append(
            Operator(
                "attn_decode",
                me_cycles=qk.me_cycles + ctx.me_cycles,
                ve_cycles=(B * H * S * 6.0) / core.ve_elems_per_cycle,
                hbm_bytes=kv_bytes,
                n_tiles=min(core.n_me, max(B * H // 8, 1)),
            )
        )
    ops.append(matmul_op("o_proj", T, dq, d, core, ve_post_elems=T * d))
    return ops


def _dense_mlp_ops(
    cfg: ModelConfig, T: int, core: NPUCoreConfig, d_ff: int = 0, tag: str = "mlp"
) -> List[Operator]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ops = [vector_op(f"{tag}_norm", T * d, core, flops_per_elem=4.0)]
    if cfg.mlp_gated:
        ops.append(matmul_op(f"{tag}_gate_up", T, d, 2 * ff, core))
        ops.append(vector_op(f"{tag}_silu_mul", T * ff, core, flops_per_elem=4.0))
    else:
        # GELU epilogue ~6 VE ops/element
        ops.append(matmul_op(f"{tag}_up", T, d, ff, core,
                             ve_post_elems=T * ff * 6.0))
    ops.append(matmul_op(f"{tag}_down", T, ff, d, core, ve_post_elems=T * d))
    return ops


def _moe_ops(cfg: ModelConfig, T: int, core: NPUCoreConfig) -> List[Operator]:
    d, E, k = cfg.d_model, cfg.n_experts, cfg.n_experts_per_tok
    d_e = cfg.d_expert or cfg.d_ff
    ops = [
        vector_op("moe_norm", T * d, core, flops_per_elem=4.0),
        matmul_op("router", T, d, E, core),
        vector_op("topk_softmax", T * E, core, flops_per_elem=6.0),
        # dispatch gather/scatter is VE + HBM traffic
        vector_op("dispatch", T * k * d, core, flops_per_elem=2.0),
    ]
    # expert GEMMs: T*k token-slots across experts; expert weights
    # streamed for every *activated* expert.
    import math

    activated = E * (1.0 - (1.0 - min(k / E, 1.0)) ** max(T, 1))
    n_act = min(E, max(int(math.ceil(activated)), k))
    w_bytes = n_act * 3 * d * d_e * DTYPE
    gu = matmul_op("experts_gate_up", T * k, d, 2 * d_e, core, weight_resident=True)
    dn = matmul_op("experts_down", T * k, d_e, d, core, weight_resident=True)
    ops.append(
        Operator(
            "experts_gate_up",
            me_cycles=gu.me_cycles,
            ve_cycles=gu.ve_cycles + (T * k * d_e * 4.0) / core.ve_elems_per_cycle,
            hbm_bytes=w_bytes * (2.0 / 3.0),
            n_tiles=min(core.n_me, max(n_act, 1)),
            weight_bytes=w_bytes * (2.0 / 3.0),
        )
    )
    ops.append(
        Operator(
            "experts_down",
            me_cycles=dn.me_cycles,
            ve_cycles=dn.ve_cycles,
            hbm_bytes=w_bytes / 3.0,
            n_tiles=min(core.n_me, max(n_act, 1)),
            weight_bytes=w_bytes / 3.0,
        )
    )
    ops.append(vector_op("combine", T * k * d * 2.0, core))
    if cfg.n_shared_experts:
        ops.extend(
            _dense_mlp_ops(
                cfg, T, core, d_ff=cfg.n_shared_experts * d_e, tag="shared_exp"
            )[1:]  # skip the extra norm
        )
    return ops


def _mamba2_ops(
    cfg: ModelConfig, B: int, S: int, T: int, phase: str, core: NPUCoreConfig
) -> List[Operator]:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = cfg.ssm_heads or max(d_in // max(cfg.ssm_head_dim, 1), 1)
    hd = cfg.ssm_head_dim or d_in // nh
    st = cfg.ssm_state
    C = cfg.ssm_chunk
    d_proj = 2 * d_in + 2 * st + nh
    ops = [
        vector_op("ssm_norm", T * d, core, flops_per_elem=4.0),
        matmul_op("ssm_in_proj", T, d, d_proj, core),
        vector_op("ssm_conv1d", T * (d_in + 2 * st) * cfg.ssm_conv, core),
    ]
    if phase == "prefill":
        # SSD chunked form: intra-chunk "attention" (CxC per head) +
        # chunk-state matmuls (hd x st) + inter-chunk scan combine.
        intra = matmul_op("ssd_intra", T * nh, hd, C, core).scaled(0.5)
        state = matmul_op("ssd_state", T * nh, hd, st, core)
        ops.append(intra)
        ops.append(state)
        n_chunks = max(T // C, 1)
        ops.append(
            vector_op("ssd_scan", n_chunks * nh * hd * st, core, flops_per_elem=6.0)
        )
    else:
        # recurrent decode: h = a*h + dBx ; y = C.h  — pure VE + state I/O
        state_elems = B * nh * hd * st
        ops.append(
            vector_op(
                "ssd_step", state_elems, core, flops_per_elem=6.0,
                hbm_bytes=2.0 * state_elems * DTYPE,
            )
        )
    ops.append(vector_op("ssm_gate_norm", T * d_in, core, flops_per_elem=5.0))
    ops.append(matmul_op("ssm_out_proj", T, d_in, d, core, ve_post_elems=T * d))
    return ops


def _xlstm_ops(
    cfg: ModelConfig, kind: str, B: int, S: int, T: int, phase: str,
    core: NPUCoreConfig,
) -> List[Operator]:
    d = cfg.d_model
    up = int(cfg.xlstm_proj_factor * d)
    H = cfg.n_heads
    hd = up // H
    ops = [
        vector_op(f"{kind}lstm_norm", T * d, core, flops_per_elem=4.0),
        matmul_op(f"{kind}lstm_up", T, d, 2 * up, core),
    ]
    if kind == "m":
        ops.append(matmul_op("mlstm_qkv", T, up, 3 * up, core))
        if phase == "prefill":
            C = min(256, S)
            ops.append(matmul_op("mlstm_intra", T * H, hd, C, core).scaled(0.5))
            ops.append(matmul_op("mlstm_state", T * H, hd, hd, core).scaled(1.0 / C))
            ops.append(vector_op("mlstm_gates", T * up * 6.0, core))
        else:
            # matrix memory update: per-head hd x hd outer-product + read
            state_elems = B * H * hd * hd
            ops.append(
                vector_op(
                    "mlstm_step", state_elems, core, flops_per_elem=4.0,
                    hbm_bytes=2.0 * state_elems * DTYPE,
                )
            )
    else:  # sLSTM: block-diag recurrent matvecs — VE-bound by design
        ops.append(
            vector_op(
                f"slstm_recurrence", T * up * (hd + 8.0), core,
                hbm_bytes=(B * up * DTYPE * 2.0 if phase == "decode" else 0.0),
            )
        )
    ops.append(vector_op(f"{kind}lstm_gate", T * up * 3.0, core))
    ops.append(matmul_op(f"{kind}lstm_down", T, up, d, core, ve_post_elems=T * d))
    return ops


# ----------------------------------------------------------------------
def lm_trace(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    phase: str = "prefill",
    core: NPUCoreConfig = DEFAULT_CORE,
    include_head: bool = True,
    kv_prior: int = 0,
) -> WorkloadTrace:
    """Operator trace of ONE forward pass (one request batch).

    phase: "prefill" (T = batch*seq tokens) | "decode" (T = batch
    tokens against a cache of length `seq`).

    ``kv_prior`` (prefill only): tokens of context already ingested by
    earlier prefill *chunks* — the new ``seq`` tokens attend to the
    prior KV (streamed from HBM) plus themselves, turning this trace
    into one SARATHI-style prefill chunk. SSM/recurrent families carry
    their state in SRAM between chunks, so only attention layers see a
    kv_prior cost. ``kv_prior=0`` (the default) is the monolithic
    prefill, bit-identical to the pre-chunking trace.
    """
    assert phase in ("prefill", "decode"), phase
    assert kv_prior == 0 or phase == "prefill", "kv_prior is prefill-only"
    B, S = batch, seq
    T = B * S if phase == "prefill" else B
    name = (f"{cfg.name}:{phase}:b{B}s{S}" if not kv_prior
            else f"{cfg.name}:{phase}:b{B}k{kv_prior}+{S}")
    tr = WorkloadTrace(name=name, core=core)

    d = cfg.d_model
    n_streams = max(cfg.n_codebooks, 1)
    tr.ops.append(
        memory_op("embed", hbm_bytes=float(T * n_streams * d * DTYPE),
                  core=core, ve_elems=T * d * n_streams)
    )
    if cfg.frontend == "vit_stub" and phase == "prefill" and not kv_prior:
        # vision patches ride in the first chunk only
        tr.ops.append(
            memory_op("patch_embeds", hbm_bytes=float(B * cfg.n_patches * d * DTYPE),
                      core=core)
        )

    for layer in range(cfg.n_layers):
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            tr.extend(_attention_ops(cfg, B, S, T, phase, core, kv_prior))
            if cfg.family == "moe":
                tr.extend(_moe_ops(cfg, T, core))
            else:
                tr.extend(_dense_mlp_ops(cfg, T, core))
        elif cfg.family == "ssm" and cfg.xlstm_pattern:
            kind = cfg.xlstm_pattern[layer % len(cfg.xlstm_pattern)]
            tr.extend(_xlstm_ops(cfg, kind, B, S, T, phase, core))
        elif cfg.family in ("ssm", "hybrid"):
            tr.extend(_mamba2_ops(cfg, B, S, T, phase, core))
            if (
                cfg.family == "hybrid"
                and cfg.hybrid_attn_every
                and (layer + 1) % cfg.hybrid_attn_every == 0
            ):
                tr.extend(_attention_ops(cfg, B, S, T, phase, core, kv_prior))
                tr.extend(_dense_mlp_ops(cfg, T, core))
        else:  # pragma: no cover
            raise ValueError(f"unknown family {cfg.family}")

    tr.ops.append(vector_op("final_norm", T * d, core, flops_per_elem=4.0))
    if include_head:
        # decode emits logits for T tokens; prefill typically also does
        # (training/scoring) — matches XLA traces.
        tr.ops.append(
            matmul_op("lm_head", T, d, cfg.vocab_size * n_streams, core)
        )

    # resident footprint: weights + KV/state cache (a chunk's cache
    # covers the full context ingested so far, not just its tokens)
    ctx = kv_prior + S
    kv = 0.0
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        kv = 2.0 * B * cfg.n_kv_heads * cfg.d_head * ctx * cfg.n_layers * DTYPE
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.hybrid_attn_every, 1)
        kv = 2.0 * B * cfg.n_kv_heads * cfg.d_head * ctx * n_attn * DTYPE
        kv += B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * cfg.n_layers * DTYPE
    tr.hbm_footprint = cfg.param_count() * DTYPE + kv
    return tr


def kv_bytes_per_token(cfg: ModelConfig, batch: int = 1) -> float:
    """HBM bytes the KV cache grows by per ingested/generated token
    (the K and V rows every attention layer writes). Matches the
    per-context cache term in :func:`lm_trace`'s footprint; SSM /
    recurrent families carry fixed-size state instead of a growing
    cache, so they return 0 and the live KV ledger stays inert."""
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        n_attn = cfg.n_layers
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.hybrid_attn_every, 1)
    else:
        return 0.0
    return 2.0 * batch * cfg.n_kv_heads * cfg.d_head * n_attn * DTYPE


def kv_swapin_trace(
    cfg: ModelConfig,
    batch: int,
    context: int,
    core: NPUCoreConfig = DEFAULT_CORE,
) -> WorkloadTrace:
    """HBM re-read an evicted request pays to restore its swapped-out
    KV cache before rejoining decode: one memory operator streaming
    the cache at ``context`` tokens back into the tenant's segments
    (no compute — the paper's §III-B tensor-swapping cost, applied to
    per-request KV instead of weights)."""
    bytes_ = kv_bytes_per_token(cfg, batch) * context
    tr = WorkloadTrace(name=f"{cfg.name}:swapin:b{batch}@{context}",
                       core=core)
    tr.ops.append(memory_op("kv_swapin", float(bytes_), core))
    return tr


def kv_prefix_trace(
    cfg: ModelConfig,
    batch: int,
    prompt_len: int,
    cached_tokens: int,
    core: NPUCoreConfig = DEFAULT_CORE,
    include_head: bool = True,
) -> WorkloadTrace:
    """Prefix-aware prefill: the first ``cached_tokens`` of the prompt
    are already resident in a SHARED KV entry (cross-request prefix
    cache hit), so only the unshared suffix is ingested. The suffix
    still attends causally over the cached prefix — identical cost
    structure to a SARATHI chunk at prior context ``cached_tokens``
    (causal-fraction attention + the per-chunk KV re-read streaming
    the cached prefix), so a hit pays exactly what a chunked prefill
    resuming at that position would, never less.

    ``cached_tokens`` is clamped to ``prompt_len - 1``: even a fully
    cached prompt runs a 1-token pass to emit the first token."""
    cached = max(min(int(cached_tokens), prompt_len - 1), 0)
    suffix = prompt_len - cached
    tr = lm_trace(cfg, batch, suffix, "prefill", core,
                  include_head=include_head, kv_prior=cached)
    tr.name = f"{cfg.name}:prefix:b{batch}c{cached}+{suffix}"
    return tr


def piggyback_trace(
    cfg: ModelConfig,
    batch: int,
    chunk_tokens: int,
    kv_prior: int,
    decode_batch: int,
    decode_ctx: int,
    core: NPUCoreConfig = DEFAULT_CORE,
    include_head: bool = True,
    final: bool = True,
    decode_groups: Optional[Sequence[Tuple[int, int]]] = None,
) -> WorkloadTrace:
    """One SARATHI-SF *piggybacked iteration*: a prefill chunk of
    ``chunk_tokens`` prompt tokens at prior context ``kv_prior`` fused
    with ``decode_batch`` live decode tokens at context bucket
    ``decode_ctx`` — a single program, so a decoding request's token
    cadence no longer waits out a whole chunk iteration.

    Costing (the paper's fine-grained operator mixing, §V-F):

    * the chunk slice keeps causal-fraction attention over
      ``kv_prior + chunk_tokens`` keys plus the per-chunk KV re-read
      (identical ops to :func:`lm_trace` with ``kv_prior``);
    * each decode token pays its per-token attention against the KV
      stream at ITS OWN context bucket: ``decode_groups`` is a
      sequence of ``(batch, ctx_bucket)`` pairs — one decode
      sub-trace per bucket with live riders, so a small-context rider
      no longer pays the largest live bucket's KV stream. The legacy
      ``(decode_batch, decode_ctx)`` pair is the single-group case
      (kept as the calling convention when every rider shares one
      bucket, so those programs — and their cache keys — are
      byte-identical to the pre-grouping builder);
    * **shared weight reads are counted once**: every decode operator
      whose weights were already streamed by a same-named operator
      earlier in the fused program (the chunk, or a previous decode
      group) drops its :attr:`Operator.weight_bytes` HBM share
      (KV-cache / state / embedding traffic is per-token and stays).

    ``final`` marks the slice that completes the prompt — only then
    does the chunk side carry the lm_head that emits the first token
    (mirroring the static-chunk rule). An empty decode side degrades
    to a plain chunk trace. Units: all token counts; context values
    are bucket ceilings in tokens.
    """
    chunk = lm_trace(cfg, batch, chunk_tokens, "prefill", core,
                     include_head=include_head and final,
                     kv_prior=kv_prior)
    if decode_groups is not None:
        groups = [(b, c) for b, c in decode_groups if b > 0]
    else:
        groups = [(decode_batch, decode_ctx)] if decode_batch > 0 else []
    if not groups:
        return chunk
    groups.sort(key=lambda g: g[1])
    tag = "".join(f"+d{b}@{c}" for b, c in groups)
    tr = WorkloadTrace(
        name=(f"{cfg.name}:piggy:b{batch}k{kv_prior}+{chunk_tokens}"
              f"{'f' if final else ''}{tag}"),
        core=core)
    tr.ops.extend(chunk.ops)
    tr.hbm_footprint = chunk.hbm_footprint
    streamed = {op.name for op in chunk.ops if op.weight_bytes > 0}
    for db, ctx in groups:
        dec = lm_trace(cfg, batch * db, ctx, "decode", core,
                       include_head=include_head)
        tr.ops.extend(op.without_weight_stream() if op.name in streamed
                      else op for op in dec.ops)
        streamed.update(op.name for op in dec.ops if op.weight_bytes > 0)
        tr.hbm_footprint = max(tr.hbm_footprint, dec.hbm_footprint)
    return tr


def request_plan(
    cfg: ModelConfig,
    batch: int,
    prompt_len: int,
    gen_len: int,
    core: NPUCoreConfig = DEFAULT_CORE,
    max_gen: int = 0,
    bucket: int = 512,
    include_head: bool = True,
    prefill_chunk_tokens: int = 0,
    iteration_token_budget: int = 0,
    prefix_len: int = 0,
) -> RequestPlan:
    """Phase-structured generation request: prefill over ``prompt_len``
    tokens (emits token 1) + decode steps against a growing KV cache.

    Decode traces are emitted once per power-of-two context bucket
    (``bucket``, 2x, 4x, ...) covering ``prompt_len + max_gen`` — the
    compiler caches one program per bucket, and the simulator picks a
    step's bucket from its live context. ``gen_len`` is the default
    tokens-per-request; per-request lengths (a generation-length
    distribution) are supplied at injection time and may use any
    length up to ``max_gen`` (defaults to ``gen_len``).

    ``prefill_chunk_tokens`` > 0 splits the prefill into SARATHI-style
    chunk phases of that many prompt tokens (the last chunk takes the
    remainder and carries the lm_head that emits token 1). Each chunk
    attends to the KV of the chunks before it, so chunk traces differ
    per position — the compiler still builds each one exactly once per
    (model shape, chunk size, ISA) through the shared ProgramCache.
    Prompts no longer than one chunk stay monolithic.

    ``iteration_token_budget`` > 0 *replaces* the static chunk knob
    with adaptive piggybacked iterations: the simulator sizes each
    prefill slice to (budget - live decode batch) and fuses it with
    the tenant's decode tokens into one :func:`piggyback_trace`
    program. The two knobs are mutually exclusive. The piggyback
    builder is attached for every generative plan so the budget can
    also be raised from 0 live (``ServingSession.
    set_iteration_token_budget``); with the budget at 0 it is never
    invoked.

    ``prefix_len`` > 0 declares the shared-prefix length (tokens) of
    this tenant's prompts: requests tagged with a prefix key attach to
    a refcounted shared KV entry of that many tokens, and a cache HIT
    prefills only the unshared suffix via :func:`kv_prefix_trace` (the
    ``prefix_builder`` attached here). 0 keeps the plan prefix-free —
    bit-identical to the pre-sharing engine.
    """
    if iteration_token_budget and prefill_chunk_tokens:
        raise ValueError(
            "iteration_token_budget replaces prefill_chunk_tokens "
            "(adaptive vs static chunking) — set at most one")
    if iteration_token_budget < 0:
        raise ValueError(
            f"iteration_token_budget must be >= 0 tokens, "
            f"got {iteration_token_budget}")
    if prefix_len < 0:
        raise ValueError(f"prefix_len must be >= 0 tokens, got {prefix_len}")
    if prefix_len >= prompt_len > 0:
        raise ValueError(
            f"prefix_len={prefix_len} must leave at least one unshared "
            f"suffix token of the {prompt_len}-token prompt")
    max_gen = max(max_gen, gen_len, 1)
    prefill = lm_trace(cfg, batch, prompt_len, "prefill", core,
                       include_head=include_head)
    chunk = int(prefill_chunk_tokens)
    chunks = []
    if chunk > 0 and prompt_len > chunk:
        start = 0
        while start < prompt_len:
            tokens = min(chunk, prompt_len - start)
            last_chunk = start + tokens >= prompt_len
            chunks.append(
                lm_trace(cfg, batch, tokens, "prefill", core,
                         include_head=include_head and last_chunk,
                         kv_prior=start))
            start += tokens
    decode = []
    if max_gen > 1:
        ctx = decode_bucket(prompt_len + 2, bucket)
        last = decode_bucket(prompt_len + max_gen, bucket)
        while True:
            decode.append((ctx, lm_trace(cfg, batch, ctx, "decode", core,
                                         include_head=include_head)))
            if ctx >= last:
                break
            ctx <<= 1
    def _piggyback(chunk_tokens: int, kv_prior: int, decode_batch: int,
                   decode_ctx: int, final: bool,
                   decode_groups=None) -> WorkloadTrace:
        return piggyback_trace(cfg, batch, chunk_tokens, kv_prior,
                               decode_batch, decode_ctx, core,
                               include_head=include_head, final=final,
                               decode_groups=decode_groups)

    kv_tok = kv_bytes_per_token(cfg, batch)

    def _swapin(context: int) -> WorkloadTrace:
        return kv_swapin_trace(cfg, batch, context, core)

    def _prefix(cached: int) -> WorkloadTrace:
        return kv_prefix_trace(cfg, batch, prompt_len, cached, core,
                               include_head=include_head)

    return RequestPlan(
        name=f"{cfg.name}:gen:b{batch}p{prompt_len}g{gen_len}",
        prefill=prefill, decode=decode, prompt_len=prompt_len,
        gen_len=gen_len, max_gen=max_gen, bucket_base=bucket,
        prefill_chunk_tokens=chunk if chunks else 0,
        prefill_chunks=chunks,
        iteration_token_budget=int(iteration_token_budget),
        piggyback_builder=_piggyback,
        kv_token_bytes=kv_tok,
        weight_bytes=float(cfg.param_count() * DTYPE),
        swapin_builder=_swapin if kv_tok > 0 else None,
        prefix_len=int(prefix_len) if kv_tok > 0 else 0,
        prefix_builder=_prefix if kv_tok > 0 else None,
    )


def train_trace(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    core: NPUCoreConfig = DEFAULT_CORE,
) -> WorkloadTrace:
    """One training step ~ fwd + 2x bwd ME work + optimizer VE sweep."""
    fwd = lm_trace(cfg, batch, seq, "prefill", core)
    tr = WorkloadTrace(name=f"{cfg.name}:train:b{batch}s{seq}", core=core)
    for op in fwd.ops:
        tr.ops.append(op)
    for op in fwd.ops:
        if op.me_cycles > 0 or op.ve_cycles > 0:
            tr.ops.append(op.scaled(2.0))  # backward
    n_params = cfg.param_count()
    tr.ops.append(
        vector_op("adamw_update", n_params, core, flops_per_elem=12.0,
                  hbm_bytes=n_params * 12.0)
    )
    tr.hbm_footprint = n_params * (2 + 4 + 4 + 4)  # bf16 w + fp32 m,v,master
    return tr
