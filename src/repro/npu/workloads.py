"""Paper Table I workload proxies + assigned-arch tenants.

The paper evaluates MLPerf v2.1 + TPU reference models traced on real
TPUv4s. Without TPU access we rebuild each workload's *operator-level
character* (ME:VE intensity ratio, HBM traffic, op-length
distribution — Figs. 2–7) from its published architecture, through
the same analytic cost model used for the assigned archs. Conv nets
use im2col-GEMM ME costs (how XLA maps convs to the MXU); depthwise
convs and BN/ReLU/SE epilogues land on the VEs; DLRM/NCF embedding
lookups are HBM gathers.

Everything returns a ``WorkloadTrace`` — the same schema the Neu10
simulator replays.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig
from repro.npu.cost_model import (
    Operator,
    WorkloadTrace,
    matmul_op,
    memory_op,
    vector_op,
)
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig
from repro.npu.trace import lm_trace

DTYPE = 2


# ----------------------------------------------------------------------
# conv building blocks (im2col-GEMM on the MXU)
# ----------------------------------------------------------------------
def _conv(
    name: str, B: int, hw: int, cin: int, cout: int, k: int,
    core: NPUCoreConfig, stride: int = 1, ve_epilogue: float = 4.0,
) -> Operator:
    out_hw = max(hw // stride, 1)
    m = B * out_hw * out_hw
    op = matmul_op(name, m, cin * k * k, cout, core,
                   ve_post_elems=m * cout * ve_epilogue)
    return op


def _depthwise(
    name: str, B: int, hw: int, c: int, k: int, core: NPUCoreConfig,
    stride: int = 1,
) -> Operator:
    out_hw = max(hw // stride, 1)
    # no reduction dim -> lands on the VEs (k*k MACs per element)
    return vector_op(name, B * out_hw * out_hw * c, core,
                     flops_per_elem=float(k * k + 4))


# ----------------------------------------------------------------------
# Table I proxies
# ----------------------------------------------------------------------
def bert_like(B: int = 32, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    cfg = ModelConfig(
        name="BERT", family="dense", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=4096, vocab_size=30522, mlp_gated=False,
    )
    tr = lm_trace(cfg, B, 384, "prefill", core, include_head=False)
    tr.name = f"BERT:b{B}"
    tr.hbm_footprint = 1.27 * 1024**3  # Table I
    return tr


def tfmr_like(B: int = 32, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    cfg = ModelConfig(
        name="TFMR", family="dense", n_layers=6, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=4096, vocab_size=33708, mlp_gated=False,
    )
    tr = lm_trace(cfg, B, 256, "prefill", core, include_head=True)
    tr.name = f"TFMR:b{B}"
    tr.hbm_footprint = 1.54 * 1024**3
    return tr


def dlrm_like(B: int = 32, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    tr = WorkloadTrace(name=f"DLRM:b{B}", core=core)
    n_tables, dim = 26, 128
    # embedding gathers: pooled multi-hot (avg ~40 ids/table) — pure HBM
    ids_per_table = 40
    tr.ops.append(
        memory_op("emb_gather", B * n_tables * ids_per_table * dim * 4.0,
                  core, ve_elems=B * n_tables * ids_per_table * dim)
    )
    # bottom MLP 13-512-256-128
    for i, (a, b) in enumerate([(13, 512), (512, 256), (256, 128)]):
        tr.ops.append(matmul_op(f"bot_mlp{i}", B, a, b, core,
                                ve_post_elems=B * b * 2))
    # pairwise interaction: (27x128) dot products per sample — VE
    tr.ops.append(vector_op("interact", B * 27 * 27 * dim / 2, core,
                            flops_per_elem=2.0))
    # top MLP 1024-1024-512-256-1
    for i, (a, b) in enumerate([(479, 1024), (1024, 1024), (1024, 512),
                                (512, 256), (256, 1)]):
        tr.ops.append(matmul_op(f"top_mlp{i}", B, a, b, core,
                                ve_post_elems=B * b * 2))
    tr.hbm_footprint = 22.38 * 1024**3
    return tr


def ncf_like(B: int = 32, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    tr = WorkloadTrace(name=f"NCF:b{B}", core=core)
    dim = 64
    tr.ops.append(memory_op("emb_gather", B * 4 * dim * 4.0 * 256, core,
                            ve_elems=B * 4 * dim * 64))
    for i, (a, b) in enumerate([(256, 256), (256, 128), (128, 64), (64, 1)]):
        tr.ops.append(matmul_op(f"mlp{i}", B, a, b, core,
                                ve_post_elems=B * b * 2))
    tr.ops.append(vector_op("gmf_mul", B * dim * 4, core))
    tr.hbm_footprint = 11.10 * 1024**3
    return tr


def _resnet_stages(tr: WorkloadTrace, B: int, core: NPUCoreConfig,
                   width: float = 1.0) -> None:
    w = lambda c: int(c * width)
    tr.ops.append(_conv("stem", B, 112, 3, w(64), 7, core, stride=1))
    tr.ops.append(vector_op("maxpool", B * 56 * 56 * w(64), core))
    stages = [(56, 64, 256, 3), (28, 128, 512, 4), (14, 256, 1024, 6),
              (7, 512, 2048, 3)]
    for hw, mid, out, blocks in stages:
        for b in range(blocks):
            tr.ops.append(_conv(f"c1_{hw}_{b}", B, hw, w(out), w(mid), 1, core))
            tr.ops.append(_conv(f"c3_{hw}_{b}", B, hw, w(mid), w(mid), 3, core))
            tr.ops.append(_conv(f"c1b_{hw}_{b}", B, hw, w(mid), w(out), 1, core))
            tr.ops.append(vector_op(f"res_{hw}_{b}", B * hw * hw * w(out) * 2,
                                    core))


def resnet_like(B: int = 32, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    tr = WorkloadTrace(name=f"RsNt:b{B}", core=core)
    _resnet_stages(tr, B, core)
    tr.ops.append(matmul_op("fc", B, 2048, 1000, core))
    tr.hbm_footprint = 216.02 * 1024**2
    return tr


def resnetrs_like(B: int = 32, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    tr = WorkloadTrace(name=f"RNRS:b{B}", core=core)
    _resnet_stages(tr, B, core, width=1.3)
    # ResNet-RS adds SE blocks -> extra VE
    tr.ops.append(vector_op("se_blocks", B * 16 * 2048 * 8, core))
    tr.ops.append(matmul_op("fc", B, 2662, 1000, core))
    tr.hbm_footprint = 458.17 * 1024**2
    return tr


def efficientnet_like(B: int = 32, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    tr = WorkloadTrace(name=f"ENet:b{B}", core=core)
    # MBConv stages (B0-ish): expand 1x1 (ME), depthwise (VE), SE (VE),
    # project 1x1 (ME) — the paper's canonical mixed/high-contention load.
    stages = [(112, 16, 24, 2, 3), (56, 24, 40, 2, 5), (28, 40, 80, 3, 3),
              (14, 80, 112, 3, 5), (14, 112, 192, 4, 5), (7, 192, 320, 1, 3)]
    for hw, cin, cout, blocks, k in stages:
        for b in range(blocks):
            exp = cin * 6
            tr.ops.append(_conv(f"expand_{hw}_{b}", B, hw, cin, exp, 1, core))
            tr.ops.append(_depthwise(f"dw_{hw}_{b}", B, hw, exp, k, core))
            tr.ops.append(vector_op(f"se_{hw}_{b}", B * exp * 32, core,
                                    flops_per_elem=4.0))
            tr.ops.append(_conv(f"proj_{hw}_{b}", B, hw, exp, cout, 1, core))
            cin = cout
    tr.ops.append(matmul_op("head", B, 1280, 1000, core))
    tr.hbm_footprint = 99.06 * 1024**2
    return tr


def _det_head(tr: WorkloadTrace, B: int, core: NPUCoreConfig,
              levels=(64, 32, 16, 8), c: int = 256, ve_scale: float = 4.0) -> None:
    for hw in levels:
        for i in range(4):
            tr.ops.append(_conv(f"head{hw}_{i}", B, hw, c, c, 3, core))
        tr.ops.append(vector_op(f"post{hw}", B * hw * hw * c * ve_scale, core))


def retinanet_like(B: int = 32, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    tr = WorkloadTrace(name=f"RtNt:b{B}", core=core)
    _resnet_stages(tr, B, core)
    _det_head(tr, B, core)
    tr.hbm_footprint = 860.51 * 1024**2
    return tr


def maskrcnn_like(B: int = 8, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    tr = WorkloadTrace(name=f"MRCN:b{B}", core=core)
    _resnet_stages(tr, B, core)
    _det_head(tr, B, core, ve_scale=8.0)
    # ROIAlign + per-ROI mask head: gather-heavy VE work
    tr.ops.append(vector_op("roi_align", B * 512 * 14 * 14 * 256, core,
                            flops_per_elem=4.0))
    for i in range(4):
        tr.ops.append(_conv(f"mask{i}", B * 4, 14, 256, 256, 3, core))
    tr.hbm_footprint = 3.21 * 1024**3
    return tr


def shapemask_like(B: int = 8, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    tr = WorkloadTrace(name=f"SMask:b{B}", core=core)
    _resnet_stages(tr, B, core)
    _det_head(tr, B, core, ve_scale=6.0)
    tr.ops.append(vector_op("shape_prior", B * 1024 * 32 * 32, core,
                            flops_per_elem=6.0))
    tr.hbm_footprint = 6.04 * 1024**3
    return tr


def mnist_like(B: int = 32, core: NPUCoreConfig = DEFAULT_CORE) -> WorkloadTrace:
    tr = WorkloadTrace(name=f"MNIST:b{B}", core=core)
    tr.ops.append(_conv("c1", B, 28, 1, 32, 3, core))
    tr.ops.append(_conv("c2", B, 14, 32, 64, 3, core))
    tr.ops.append(matmul_op("fc1", B, 3136, 128, core, ve_post_elems=B * 128))
    tr.ops.append(matmul_op("fc2", B, 128, 10, core))
    tr.hbm_footprint = 10.59 * 1024**2
    return tr


def llama2_13b_decode(B: int = 8, core: NPUCoreConfig = DEFAULT_CORE,
                      S: int = 512) -> WorkloadTrace:
    """§V-F case study: HBM-bound LLM decode tenant."""
    cfg = ModelConfig(
        name="LLaMA2-13B", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=40, d_ff=13824, vocab_size=32000,
        rope_theta=10_000.0,
    )
    tr = lm_trace(cfg, B, S, "decode", core)
    tr.name = f"LLaMA:b{B}s{S}"
    return tr


# ----------------------------------------------------------------------
WORKLOADS = {
    "BERT": bert_like,
    "TFMR": tfmr_like,
    "DLRM": dlrm_like,
    "NCF": ncf_like,
    "MRCN": maskrcnn_like,
    "RtNt": retinanet_like,
    "SMask": shapemask_like,
    "MNIST": mnist_like,
    "RsNt": resnet_like,
    "RNRS": resnetrs_like,
    "ENet": efficientnet_like,
    "LLaMA": llama2_13b_decode,
}

# §V-A pairs: (low | medium | high) ME/VE contention
PAPER_PAIRS: List[Tuple[str, str, str]] = [
    ("DLRM", "SMask", "low"),
    ("DLRM", "RtNt", "low"),
    ("NCF", "RsNt", "low"),
    ("ENet", "SMask", "medium"),
    ("BERT", "ENet", "medium"),
    ("ENet", "MRCN", "medium"),
    ("ENet", "TFMR", "high"),
    ("MNIST", "RtNt", "high"),
    ("RNRS", "RtNt", "high"),
]

_BATCH_OVERRIDES = {"MRCN": 8, "SMask": 8, "LLaMA": 8}


def get_workload(name: str, core: NPUCoreConfig = DEFAULT_CORE,
                 batch: int = 32) -> WorkloadTrace:
    b = _BATCH_OVERRIDES.get(name, batch)
    return WORKLOADS[name](b, core)


def assigned_arch_tenant(
    cfg: ModelConfig, phase: str = "prefill", batch: int = 8, seq: int = 512,
    core: NPUCoreConfig = DEFAULT_CORE,
) -> WorkloadTrace:
    """Any assigned architecture as a Neu10 vNPU tenant."""
    return lm_trace(cfg, batch, seq, phase, core)
