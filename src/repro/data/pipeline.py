"""Data pipeline: deterministic synthetic LM token streams with
background prefetch.

Synthetic data is generated with a counter-based PRNG keyed on
(epoch, step, shard), so restarts and elastic resharding reproduce
the exact same global batch order — the property checkpoint/restore
tests rely on. Structure (Zipf-ish unigram + short-range repetition)
gives the LM a learnable signal so loss curves actually descend in
the end-to-end example.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class SyntheticLMData:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    # data-parallel shard of this host
    shard: int = 0
    n_shards: int = 1

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)

    def sample(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        local_b = self.batch // self.n_shards
        V = cfg.vocab_size
        shape = ((local_b, cfg.n_codebooks, self.seq + 1)
                 if cfg.family == "audio" else (local_b, self.seq + 1))
        # Zipf-ish unigram distribution capped to vocab
        toks = rng.zipf(1.3, size=shape).astype(np.int64) % V
        # short-range structure: repeat the previous token with p=0.3
        rep = rng.random(shape) < 0.3
        rolled = np.roll(toks, 1, axis=-1)
        toks = np.where(rep, rolled, toks).astype(np.int32)
        batch = {
            "tokens": toks[..., :-1],
            "targets": toks[..., 1:],
        }
        if cfg.family == "vlm":
            batch["patch_embeds"] = rng.standard_normal(
                (local_b, cfg.n_patches, cfg.d_model)).astype(np.float32)
        return batch


def make_batch_iterator(data: SyntheticLMData, start_step: int = 0,
                        prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetching iterator (overlaps host datagen
    with device compute)."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put(data.sample(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
