"""Training driver: jit'd train_step + data prefetch + async
checkpointing + crash-resume + straggler monitoring.

Single-process (this container has one CPU device); the same loop
drives the production mesh when `mesh` is passed — steps are jit'd
with the sharding rules from repro.distributed.sharding.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLMData, make_batch_iterator
from repro.distributed.fault import StragglerMonitor
from repro.launch.steps import init_train_state, make_train_step
from repro.models.registry import build_model


@dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 256
    peak_lr: float = 3e-4
    warmup: int = 20
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    dtype: Any = jnp.float32
    remat: bool = True


def train(cfg: ModelConfig, tc: TrainConfig,
          log_fn=print) -> Dict[str, Any]:
    model = build_model(cfg, remat=tc.remat)
    key = jax.random.PRNGKey(tc.seed)
    state = init_train_state(model, key, tc.dtype)

    start_step = 0
    ckpt = None
    if tc.ckpt_dir:
        ckpt = Checkpointer(tc.ckpt_dir)
        restored, step = ckpt.restore(state)
        if restored is not None:
            state = restored
            start_step = step
            log_fn(f"[train] resumed from step {step}")

    step_fn = jax.jit(
        make_train_step(model, peak_lr=tc.peak_lr, warmup=tc.warmup,
                        stable=max(tc.steps - tc.warmup - tc.steps // 5, 1),
                        decay=max(tc.steps // 5, 1)),
        donate_argnums=(0,))

    data = SyntheticLMData(cfg, tc.batch, tc.seq, seed=tc.seed)
    it = make_batch_iterator(data, start_step=start_step)
    monitor = StragglerMonitor(n_hosts=1)

    losses: List[float] = []
    t_start = time.time()
    tokens_per_step = tc.batch * tc.seq
    try:
        for step in range(start_step, tc.steps):
            batch = next(it)
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            monitor.observe(step, 0, dt)
            losses.append(loss)
            if step % tc.log_every == 0 or step == tc.steps - 1:
                log_fn(f"[train] step {step:5d} loss {loss:.4f} "
                       f"lr {float(metrics['lr']):.2e} "
                       f"{tokens_per_step / dt:,.0f} tok/s")
            if ckpt and (step + 1) % tc.ckpt_every == 0:
                ckpt.save(step + 1, state)
        if ckpt:
            ckpt.save(tc.steps, state, blocking=True)
    finally:
        it.close()
    return {
        "final_loss": losses[-1] if losses else float("nan"),
        "losses": losses,
        "wall_s": time.time() - t_start,
        "state": state,
    }
