from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import wsd_schedule, cosine_schedule

__all__ = ["adamw_init", "adamw_update", "wsd_schedule", "cosine_schedule"]
