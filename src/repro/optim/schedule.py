"""LR schedules. WSD (warmup-stable-decay) is the MiniCPM schedule
[arXiv:2404.06395] — assigned arch minicpm-2b trains with it."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, peak_lr: float, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    in_decay = jnp.clip((s - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
    # exponential-style decay to final_frac (MiniCPM uses ~0.1 * peak)
    decayed = peak_lr * jnp.exp(jnp.log(final_frac) * in_decay)
    return jnp.where(s < warmup + stable, warm, decayed)


def cosine_schedule(step, peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
    t = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < warmup, warm, peak_lr * cos)
