"""AdamW, hand-rolled (no optax in this container).

Moments are fp32 regardless of param dtype; the update math runs in
fp32 and casts back, so bf16 training (the dry-run configuration) is
numerically sane. Optionally applies int8 gradient compression with
error feedback before the update — see repro/distributed/compress.py.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


def adamw_init(params: Params) -> Dict[str, Params]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params: Params,
    grads: Params,
    opt_state: Dict[str, Params],
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Params, Dict[str, Params]]:
    step = opt_state["step"] + 1
    # global-norm clip
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
