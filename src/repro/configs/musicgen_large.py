"""MusicGen-large  [arXiv:2306.05284; hf]

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048 — decoder-only
transformer over EnCodec tokens: 4 parallel codebook streams
(delay-pattern interleaving), per-codebook vocab 2048, GELU MLP
(not gated) per the original architecture.

The EnCodec frontend is a STUB per the brief: ``input_specs()``
provides the 4-stream codebook token ids; the backbone sums the four
codebook embeddings per frame and emits 4 logit heads.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_gated=False,
    frontend="encodec_stub",
    n_codebooks=4,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    mlp_gated=False,
    frontend="encodec_stub",
    n_codebooks=4,
)
