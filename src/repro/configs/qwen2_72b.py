"""Qwen2-72B  [arXiv:2407.10671; hf]

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — GQA, QKV
bias. Largest assigned dense arch; stresses the vNPU mapper's
EU-vs-memory balancing and the TP/DP sharding rules.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-72b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    qkv_bias=True,
)
