"""Qwen1.5-MoE-A2.7B  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936,
MoE: 4 shared + 60 routed experts, top-4. Fine-grained experts
(d_expert = 1408). QKV bias per Qwen1.5 lineage.

Sharding note: 60 routed experts are NOT divisible by the 16-way
`model` axis -> experts replicated across `model`, expert ffn dim
sharded ("ffn" mode); the 4 shared experts are TP-sharded like a
dense MLP.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    n_experts=60,
    n_experts_per_tok=4,
    n_shared_experts=4,
    d_expert=1408,
    moe_shard="ffn",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=512,
    qkv_bias=True,
    n_experts=6,
    n_experts_per_tok=2,
    n_shared_experts=2,
    d_expert=96,
    moe_shard="ffn",
)
