"""DBRX-132B  [hf:databricks/dbrx-base; unverified]

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE: 16 routed experts, top-4, fine-grained.

Sharding note: 16 experts over the 16-way `model` axis -> pure
expert parallelism (1 expert per model shard), "expert" mode.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    n_experts_per_tok=4,
    n_shared_experts=0,
    d_expert=10752,
    moe_shard="expert",
    rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    n_experts=4,
    n_experts_per_tok=2,
    n_shared_experts=0,
    d_expert=128,
    moe_shard="expert",
)
