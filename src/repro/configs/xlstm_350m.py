"""xLSTM-350M  [arXiv:2405.04517; unverified]

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304 — sLSTM + mLSTM blocks.
d_ff = 0: xLSTM blocks carry their own up/down projections
(proj_factor 2 for mLSTM). Pattern follows the paper's mLSTM-dominant
ratio (7 mLSTM : 1 sLSTM).

Attention-free -> `long_500k` decode RUNS (recurrent state, O(1) per
token).
"""
from repro.configs.base import ModelConfig

_PATTERN = tuple("m" if i % 8 != 7 else "s" for i in range(24))

ARCH = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm_pattern=_PATTERN,
    xlstm_proj_factor=2.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    xlstm_pattern=("m", "s"),
    xlstm_proj_factor=2.0,
    tie_embeddings=True,
)
