"""Architecture configuration schema shared by the model zoo, the NPU
cost model / trace generator, and the launch layer.

Every assigned architecture gets one ``<id>.py`` exposing:
  * ``ARCH``      — full published config (exercised only via dry-run)
  * ``SMOKE``     — reduced same-family config (runs on CPU in tests)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. Field groups are family-specific; unused
    groups stay at their zero defaults."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # ---- attention flags ----
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False
    mlp_gated: bool = True  # SwiGLU (3 mats) vs GELU MLP (2 mats)

    # ---- MoE ----
    n_experts: int = 0           # routed experts
    n_experts_per_tok: int = 0   # top-k
    n_shared_experts: int = 0
    d_expert: int = 0            # per routed expert ffn dim (fine-grained)

    # ---- SSM (Mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # ---- xLSTM ----
    # layer kinds used when family == "ssm" and xlstm_pattern non-empty:
    # "m" -> mLSTM block, "s" -> sLSTM block
    xlstm_pattern: Tuple[str, ...] = ()
    xlstm_proj_factor: float = 2.0

    # ---- hybrid (zamba2-style): shared attention block applied every
    #      `hybrid_attn_every` mamba layers with one shared param set ----
    hybrid_attn_every: int = 0

    # ---- modality frontends (STUBS: precomputed embeddings) ----
    frontend: str = ""           # "" | "vit_stub" | "encodec_stub"
    n_patches: int = 0           # vlm: patches prepended to the text seq
    n_codebooks: int = 0         # audio: parallel codebook streams

    norm_eps: float = 1e-6
    max_seq: int = 1 << 20

    # pad embedding/lm_head vocab up to a multiple of 256 so the vocab
    # dim TP-shards (minicpm 122753 / internvl 151655 are otherwise
    # replicated — the collective-bound dry-run cells). Padded logit
    # slots are masked to -inf in unembed. Perf-iteration knob.
    pad_vocab: bool = False

    # ---- sharding hints (consumed by repro.distributed.sharding) ----
    # how to shard MoE experts over the "model" axis:
    #   "expert"  -> shard expert dim (requires n_experts % model == 0)
    #   "ffn"     -> replicate experts, shard their ffn dim
    moe_shard: str = "ffn"

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        if not self.pad_vocab:
            return self.vocab_size
        return -(-self.vocab_size // 256) * 256

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM / hybrid archs only (per brief)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and the
        NPU cost model; cross-checked against real init in tests)."""
        d, L = self.d_model, self.n_layers
        n_embed = self.vocab_padded * d
        if self.family == "audio":
            n_embed = self.n_codebooks * self.vocab_padded * d
        # per-layer counts by family
        per_layer = 0
        attn = d * self.d_q + 2 * d * self.d_kv + self.d_q * d
        if self.qkv_bias:
            attn += self.d_q + 2 * self.d_kv
        if self.qk_norm:
            attn += 2 * self.d_head
        n_mlp_mats = 3 if self.mlp_gated else 2
        dense_mlp = n_mlp_mats * d * self.d_ff  # SwiGLU gate/up/down | GELU up/down
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn + dense_mlp + 2 * d
        elif self.family == "moe":
            d_e = self.d_expert or self.d_ff
            routed = self.n_experts * 3 * d * d_e
            shared = self.n_shared_experts * 3 * d * d_e
            router = d * self.n_experts
            per_layer = attn + routed + shared + router + 2 * d
        elif self.family == "ssm" and self.xlstm_pattern:
            total_layers = sum(
                _xlstm_layer_params(self, k) for k in self.xlstm_pattern)
            per_layer = total_layers // L if L else 0
            # avoid integer-division drift: compute exactly below
            n_embed_ = n_embed
            total = n_embed_ + total_layers + d
            if not self.tie_embeddings:
                total += self.vocab_padded * d
            return total
        elif self.family == "ssm":
            per_layer = _mamba2_layer_params(self) + d
        elif self.family == "hybrid":
            per_layer = _mamba2_layer_params(self) + d
        total = n_embed + L * per_layer + d  # final norm
        if self.family == "hybrid" and self.hybrid_attn_every:
            total += attn + dense_mlp + 2 * d  # one shared block
        if not self.tie_embeddings:
            lm_head = self.vocab_padded * d
            if self.family == "audio":
                lm_head = self.n_codebooks * self.vocab_padded * d
            total += lm_head
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k routed)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        d_e = self.d_expert or self.d_ff
        inactive = L * (self.n_experts - self.n_experts_per_tok) * 3 * d * d_e
        return self.param_count() - inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def _mamba2_layer_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    nh = cfg.ssm_heads or max(d_inner // max(cfg.ssm_head_dim, 1), 1)
    # separate projections: w_z, w_x, w_B, w_C, w_dt
    d_in_proj = 2 * d_inner + 2 * cfg.ssm_state + nh
    return (
        d * d_in_proj
        + (cfg.ssm_conv + 1) * (d_inner + 2 * cfg.ssm_state)  # conv w + b
        + nh  # A_log
        + nh  # D
        + nh  # dt_bias
        + d_inner  # gated norm
        + d_inner * d  # out_proj
    )


def _xlstm_layer_params(cfg: ModelConfig, kind: str) -> int:
    d = cfg.d_model
    up = int(cfg.xlstm_proj_factor * d)
    H = cfg.n_heads
    hd = up // H
    if kind == "m":
        # norm, w_u, w_z, wq, wk, wv, w_if, out_norm, w_down
        return (d + 2 * d * up + 3 * up * up + up * 2 * H + up
                + up * d)
    # sLSTM: norm, w_up, w_gates, r_gates, out_norm, w_down
    return d + d * up + up * 4 * up + H * hd * 4 * hd + up + up * d


# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell from the assignment."""

    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeCell("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeCell("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeCell("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeCell("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Whether a shape cell runs for an arch, per the brief."""
    if cell.name == "long_500k" and not cfg.supports_long_context:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
