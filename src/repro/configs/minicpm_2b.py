"""MiniCPM-2B  [arXiv:2404.06395; hf]

40L d_model=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753 —
llama-like arch; trained with the WSD (warmup-stable-decay) schedule,
implemented in repro.optim.schedule and used by the training example.
Tied embeddings per MiniCPM.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    tie_embeddings=True,
)
