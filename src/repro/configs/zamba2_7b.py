"""Zamba2-7B  [arXiv:2411.15242; unverified]

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64 —
Mamba2 backbone + a SHARED attention+MLP block (one parameter set)
applied every `hybrid_attn_every` Mamba2 layers.

Hybrid -> `long_500k` decode RUNS: Mamba2 state is O(1) per token;
the shared-attention KV cache is sequence-sharded over the `data`
mesh axis.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=112,       # (expand*d_model)/head_dim = 7168/64
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    hybrid_attn_every=6,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    ssm_state=16,
    ssm_heads=8,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_chunk=32,
    hybrid_attn_every=2,
)
