"""InternVL2-1B  [arXiv:2404.16821; hf]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655 —
InternViT frontend + Qwen2-0.5B-lineage LM backbone.

Per the brief the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, n_patches, d_model) which
the backbone prepends to the text token embeddings.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    tie_embeddings=True,
    frontend="vit_stub",
    n_patches=256,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    tie_embeddings=True,
    frontend="vit_stub",
    n_patches=8,
)
