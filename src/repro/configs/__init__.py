"""Architecture registry: ``--arch <id>`` ids map 1:1 to modules here."""
from __future__ import annotations

from typing import Dict, Tuple

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ModelConfig,
    ShapeCell,
    shape_applicable,
)

from repro.configs import (  # noqa: E402
    dbrx_132b,
    internvl2_1b,
    minicpm_2b,
    musicgen_large,
    qwen2_0_5b,
    qwen2_72b,
    qwen2_moe_a2_7b,
    qwen3_14b,
    xlstm_350m,
    zamba2_7b,
)

_MODULES = {
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "dbrx-132b": dbrx_132b,
    "xlstm-350m": xlstm_350m,
    "qwen3-14b": qwen3_14b,
    "minicpm-2b": minicpm_2b,
    "qwen2-0.5b": qwen2_0_5b,
    "qwen2-72b": qwen2_72b,
    "internvl2-1b": internvl2_1b,
    "zamba2-7b": zamba2_7b,
    "musicgen-large": musicgen_large,
}

ARCHS: Dict[str, ModelConfig] = {k: m.ARCH for k, m in _MODULES.items()}
SMOKES: Dict[str, ModelConfig] = {k: m.SMOKE for k, m in _MODULES.items()}
ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def get_arch(arch_id: str, smoke: bool = False) -> ModelConfig:
    table = SMOKES if smoke else ARCHS
    if arch_id not in table:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(table)}")
    return table[arch_id]


def dryrun_cells():
    """Yield every live (arch, shape) dry-run cell plus skip records."""
    for arch_id, cfg in ARCHS.items():
        for cell in ALL_SHAPES:
            ok, why = shape_applicable(cfg, cell)
            yield arch_id, cfg, cell, ok, why


__all__ = [
    "ARCHS",
    "SMOKES",
    "ARCH_IDS",
    "ModelConfig",
    "ShapeCell",
    "ALL_SHAPES",
    "SHAPES_BY_NAME",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "get_arch",
    "dryrun_cells",
    "shape_applicable",
]
