"""Qwen2-0.5B  [arXiv:2407.10671; hf]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 — GQA, QKV bias,
tied embeddings. Smallest assigned tenant; the default collocation
workload in the Neu10 multi-tenant benchmarks.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    tie_embeddings=True,
)
