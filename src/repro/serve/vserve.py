"""Deprecated closed-loop facade over the online control plane.

`MultiTenantServer` predates the :mod:`repro.serve.session` API: it
registers every tenant up front, then runs a closed-loop batch
(`simulate(n_requests)`). It is kept as a thin shim so existing call
sites keep working — new code should use
:class:`~repro.serve.session.NPUCluster` (resource plane) plus
:class:`~repro.serve.session.ServingSession` (open-loop request
plane, mid-run register/deregister/resize, SLO autoscale hook).

The pipeline it drives is unchanged:

  tenant workload -> compile-time (m, v) profile -> vNPU allocator
  (Eq. 1-4) -> vNPU manager mapping (spatial/temporal) -> NeuISA
  compilation -> μTOp scheduler simulation -> SLO accounting.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.allocator import allocate_for_trace, estimate_memory
from repro.core.simulator import SimResult
from repro.core.vnpu import VNPUConfig
from repro.npu.cost_model import WorkloadTrace
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig
from repro.serve.session import (NPUCluster, TenantHandle, TenantReport,
                                 run_closed_loop)

# back-compat names: the old dataclasses are the new ones
Tenant = TenantHandle
__all__ = ["MultiTenantServer", "Tenant", "TenantReport"]


class MultiTenantServer:
    """Closed-loop batch server (deprecated shim over NPUCluster)."""

    def __init__(self, core: NPUCoreConfig = DEFAULT_CORE,
                 n_pnpus: int = 1, policy: str = "neu10"):
        self.cluster = NPUCluster(core=core, n_pnpus=n_pnpus, policy=policy)
        self.core = core
        self.policy = self.cluster.policy_name

    @property
    def manager(self):
        return self.cluster.manager

    @property
    def tenants(self) -> List[TenantHandle]:
        return self.cluster.tenants

    # ------------------------------------------------------------------
    def register(self, name: str, trace: WorkloadTrace, eu_budget: int,
                 priority: float = 1.0,
                 slo_p95_ms: Optional[float] = None) -> TenantHandle:
        return self.cluster.register(name, trace, eu_budget,
                                     priority=priority,
                                     slo_p95_ms=slo_p95_ms)

    def register_model(self, cfg: ModelConfig, phase: str = "prefill",
                       batch: int = 8, seq: int = 512, eu_budget: int = 4,
                       **kw) -> TenantHandle:
        return self.cluster.register_model(cfg, phase=phase, batch=batch,
                                           seq=seq, eu_budget=eu_budget, **kw)

    def register_generative(self, name: str, cfg: ModelConfig,
                            **kw) -> TenantHandle:
        """Phase-structured LLM tenant (prefill -> decode chain). The
        closed loop replays whole requests back to back; continuous
        batching across arrivals needs the open-loop
        :class:`~repro.serve.session.ServingSession`."""
        return self.cluster.register_generative(name, cfg, **kw)

    def deregister(self, tenant: TenantHandle) -> None:
        self.cluster.deregister(tenant)

    # ------------------------------------------------------------------
    def simulate(self, n_requests: int = 8, hbm_scale: float = 1.0,
                 ) -> Tuple[SimResult, List[TenantReport]]:
        """Run the multi-tenant schedule; returns per-tenant SLO report."""
        return run_closed_loop(self.cluster, n_requests=n_requests,
                               hbm_scale=hbm_scale)

    def autoscale_to_slo(self, n_requests: int = 6,
                         max_eus: int = 8) -> List[TenantReport]:
        """Grow a tenant's EU budget until its SLO holds (or cap).

        Deprecated: online sessions do this with an
        :class:`~repro.serve.session.SLOAutoscaler` hook instead of
        re-running the whole batch."""
        while True:
            _, reports = self.simulate(n_requests)
            violators = [
                (t, r) for t, r in zip(self.tenants, reports)
                if r.slo_ok is False and t.eu_budget < max_eus
            ]
            if not violators:
                return reports
            for t, _ in violators:
                t.eu_budget += 2
                alloc = allocate_for_trace(t.trace, t.eu_budget, self.core)
                sram, hbm = estimate_memory(t.trace, alloc.n_me, self.core)
                t.allocation = alloc
                t.vnpu = self.manager.reconfigure(
                    t.vnpu, VNPUConfig(
                        n_me=alloc.n_me, n_ve=alloc.n_ve,
                        sram_bytes=sram, hbm_bytes=hbm,
                        priority=t.priority))
