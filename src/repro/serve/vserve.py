"""Multi-tenant vNPU serving — the paper's system, end to end:

  tenant workload -> compile-time (m, v) profile -> vNPU allocator
  (Eq. 1-4) -> vNPU manager mapping (spatial/temporal) -> NeuISA
  compilation -> μTOp scheduler simulation -> SLO accounting.

`MultiTenantServer` is the control plane a cloud operator would run
per NPU host. Tenants register with an EU budget (pay-as-you-go) and
an optional latency SLO; the server picks their ME/VE split, places
them, and reports per-tenant p95/throughput under any scheduling
policy (pmt / v10 / neu10_nh / neu10).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.allocator import Allocation, allocate_for_trace, estimate_memory
from repro.core.compiler import compile_neuisa, compile_vliw
from repro.core.mapper import VNPUManager
from repro.core.simulator import SimResult, Simulator, TenantSpec
from repro.core.vnpu import VNPU, VNPUConfig
from repro.npu.cost_model import WorkloadTrace
from repro.npu.hw_config import DEFAULT_CORE, NPUCoreConfig
from repro.npu.trace import lm_trace


@dataclass
class Tenant:
    name: str
    trace: WorkloadTrace
    eu_budget: int
    priority: float = 1.0
    slo_p95_ms: Optional[float] = None
    allocation: Optional[Allocation] = None
    vnpu: Optional[VNPU] = None


@dataclass
class TenantReport:
    name: str
    n_me: int
    n_ve: int
    p95_ms: float
    mean_ms: float
    throughput_rps: float
    slo_ok: Optional[bool]
    harvested_me_ms: float
    blocked_ms: float


class MultiTenantServer:
    def __init__(self, core: NPUCoreConfig = DEFAULT_CORE,
                 n_pnpus: int = 1, policy: str = "neu10"):
        assert policy in ("pmt", "v10", "neu10_nh", "neu10")
        self.core = core
        self.policy = policy
        self.manager = VNPUManager(n_pnpus=n_pnpus, core=core)
        self.tenants: List[Tenant] = []

    # ------------------------------------------------------------------
    def register(self, name: str, trace: WorkloadTrace, eu_budget: int,
                 priority: float = 1.0,
                 slo_p95_ms: Optional[float] = None) -> Tenant:
        """Pay-as-you-go entry point: the tenant buys `eu_budget` EUs;
        the allocator picks the ME/VE split from the compile-time
        profile (§III-B)."""
        alloc = allocate_for_trace(trace, eu_budget, self.core)
        sram, hbm = estimate_memory(trace, alloc.n_me, self.core)
        mapping = "spatial" if self.policy.startswith("neu10") else "temporal"
        try:
            vnpu = self.manager.create(
                VNPUConfig(n_me=alloc.n_me, n_ve=alloc.n_ve,
                           sram_bytes=sram, hbm_bytes=hbm,
                           priority=priority),
                name=name, mapping=mapping)
        except RuntimeError:
            # admission control: the unconstrained Eq.-4 pick doesn't
            # fit next to existing tenants — re-allocate over the
            # FEASIBLE splits, still maximizing Eq. 2. Harvesting
            # recovers most of the gap at runtime (§III-B).
            alloc, vnpu = self._constrained_register(
                trace, alloc, eu_budget, priority, name, mapping)
        t = Tenant(name=name, trace=trace, eu_budget=eu_budget,
                   priority=priority, slo_p95_ms=slo_p95_ms,
                   allocation=alloc, vnpu=vnpu)
        self.tenants.append(t)
        return t

    def _constrained_register(self, trace, alloc, eu_budget, priority,
                              name, mapping):
        from repro.core.allocator import Allocation, eu_utilization

        feasible = []
        for cs in self.manager.cores:
            free_me, free_ve = len(cs.free_mes), len(cs.free_ves)
            for n_me in range(1, free_me + 1):
                for n_ve in range(1, free_ve + 1):
                    if n_me + n_ve <= eu_budget:
                        feasible.append((n_me, n_ve))
        if not feasible:
            raise RuntimeError(
                f"admission denied for {name}: no free EUs on any pNPU")
        n_me, n_ve = max(
            set(feasible),
            key=lambda s: eu_utilization(alloc.m, alloc.v, *s))
        sram, hbm = estimate_memory(trace, n_me, self.core)
        # cap the memory ask to what remains (§III-B: oversized models
        # fall back to tensor swapping / multi-vNPU allocation)
        free_hbm = max(len(cs.free_hbm_segs) for cs in self.manager.cores)
        free_sram = max(len(cs.free_sram_segs) for cs in self.manager.cores)
        hbm = min(hbm, free_hbm * self.core.hbm_segment)
        sram = min(sram, free_sram * self.core.sram_segment)
        vnpu = self.manager.create(
            VNPUConfig(n_me=n_me, n_ve=n_ve, sram_bytes=sram,
                       hbm_bytes=hbm, priority=priority),
            name=name, mapping=mapping)
        new_alloc = Allocation(
            n_me, n_ve, eu_utilization(alloc.m, alloc.v, n_me, n_ve),
            alloc.k_star, alloc.m, alloc.v)
        return new_alloc, vnpu

    def register_model(self, cfg: ModelConfig, phase: str = "prefill",
                       batch: int = 8, seq: int = 512, eu_budget: int = 4,
                       **kw) -> Tenant:
        trace = lm_trace(cfg, batch, seq, phase, self.core)
        return self.register(cfg.name, trace, eu_budget, **kw)

    def deregister(self, tenant: Tenant) -> None:
        if tenant.vnpu is not None:
            self.manager.destroy(tenant.vnpu)
        self.tenants.remove(tenant)

    # ------------------------------------------------------------------
    def simulate(self, n_requests: int = 8,
                 hbm_scale: float = 1.0) -> Tuple[SimResult, List[TenantReport]]:
        """Run the multi-tenant schedule; returns per-tenant SLO report."""
        specs = []
        for t in self.tenants:
            if self.policy.startswith("neu10"):
                prog = compile_neuisa(t.trace, self.core)
            else:
                prog = compile_vliw(t.trace, self.core)
            specs.append(TenantSpec(prog, t.vnpu, n_requests,
                                    weight=t.priority))
        res = Simulator(specs, policy=self.policy, core=self.core,
                        hbm_scale=hbm_scale).run()
        ms = 1e3 / self.core.freq_hz
        reports = []
        for i, t in enumerate(self.tenants):
            st = res.tenants[i]
            p95 = st.p95() * ms
            reports.append(TenantReport(
                name=t.name,
                n_me=t.vnpu.config.n_me,
                n_ve=t.vnpu.config.n_ve,
                p95_ms=p95,
                mean_ms=st.mean() * ms,
                throughput_rps=res.throughput(i),
                slo_ok=(p95 <= t.slo_p95_ms) if t.slo_p95_ms else None,
                harvested_me_ms=st.harvested_me_work * ms,
                blocked_ms=st.reclaim_blocked * ms,
            ))
        return res, reports

    def autoscale_to_slo(self, n_requests: int = 6,
                         max_eus: int = 8) -> List[TenantReport]:
        """Grow a tenant's EU budget until its SLO holds (or cap) —
        the pay-as-you-go loop a cloud operator automates."""
        while True:
            _, reports = self.simulate(n_requests)
            violators = [
                (t, r) for t, r in zip(self.tenants, reports)
                if r.slo_ok is False and t.eu_budget < max_eus
            ]
            if not violators:
                return reports
            for t, _ in violators:
                t.eu_budget += 2
                alloc = allocate_for_trace(t.trace, t.eu_budget, self.core)
                sram, hbm = estimate_memory(t.trace, alloc.n_me, self.core)
                t.allocation = alloc
                t.vnpu = self.manager.reconfigure(
                    t.vnpu, VNPUConfig(
                        n_me=alloc.n_me, n_ve=alloc.n_ve,
                        sram_bytes=sram, hbm_bytes=hbm,
                        priority=t.priority))
