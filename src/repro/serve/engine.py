"""Serving engine: batched prefill + greedy/temperature decode over
the KV cache, for any zoo architecture.

This is the functional layer (real JAX compute). Multi-tenant NPU
scheduling — the paper's subject — sits above it in vserve.py, which
maps engines onto vNPUs and uses the Neu10 simulator as the timing
model for SLO accounting.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import Model, build_model


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_new) | (B, K, n_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Optional[Any] = None,
                 max_seq: int = 512, seed: int = 0,
                 dtype=jnp.float32) -> None:
        self.cfg = cfg
        self.model: Model = build_model(cfg, remat=False)
        self.max_seq = max_seq
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed), dtype)
        self.params = params
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    def _sample(self, logits: jax.Array, key, temperature: float):
        # logits: (B, 1, V) or (B, 1, K, V)
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompt_tokens: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0
                 ) -> GenerationResult:
        cfg = self.cfg
        toks = jnp.asarray(prompt_tokens, jnp.int32)
        audio = cfg.family == "audio"
        B = toks.shape[0]
        S = toks.shape[-1]
        assert S + n_new <= self.max_seq, "increase max_seq"
        cache = self.model.init_cache(B, self.max_seq)
        key = jax.random.PRNGKey(seed)

        t0 = time.time()
        batch: Dict[str, Any] = {"tokens": toks}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (B, cfg.n_patches, cfg.d_model), jnp.float32)
        logits, cache = self._prefill(self.params, batch, cache)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        t0 = time.time()
        outs = []
        last = logits[:, -1:]
        if audio:
            pass  # (B, 1, K, V)
        for i in range(n_new):
            key, sub = jax.random.split(key)
            nxt = self._sample(last, sub, temperature)  # (B,1) | (B,1,K)
            if audio:
                nxt_in = jnp.moveaxis(nxt, -1, 1)       # (B,K,1)
            else:
                nxt_in = nxt
            outs.append(np.asarray(nxt_in))
            idx = jnp.asarray(S + i, jnp.int32)
            last, cache = self._decode(
                self.params, cache, {"tokens": nxt_in, "cache_index": idx})
        t_decode = time.time() - t0
        new = np.concatenate(outs, axis=-1)
        # tokens/s counts generated TIMESTEPS per sequence: an audio
        # model emits K parallel codebook streams per step, which is
        # still one token of audio — new.size would over-count by K
        n_tok = new.shape[0] * new.shape[-1]
        return GenerationResult(
            tokens=new,
            prefill_s=t_prefill,
            decode_s=t_decode,
            tokens_per_s=n_tok / max(t_decode, 1e-9),
        )
